"""Export a merged RunTrace as Chrome trace-event JSON (Perfetto-loadable).

    PYTHONPATH=src python tools/trace_export.py <run-trace.json | trace-dir> \
        [-o out.trace.json]

Input is either a saved ``RunTrace`` document (``RunTrace.save``) or a
trace *directory* of per-process ``spans-*.jsonl`` files (the form a
``repro.core.obs.trace(dir=...)`` run leaves behind), which is merged on
the fly.  Output follows the Chrome trace-event format's "JSON object"
flavor: complete ("ph": "X") duration events with microsecond ``ts``/
``dur``, one row per process — so the pipelined build/score overlap is
*visible* as parallel tracks instead of a single ``pipeline_overlap``
scalar.  Load the file at https://ui.perfetto.dev or chrome://tracing.

Timestamps: spans record wall-clock ``time.time_ns()`` starts (the only
clock comparable across processes) and ``perf_counter`` durations; the
export rebases ``ts`` to the earliest span so the timeline starts at 0.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def chrome_trace(run_trace) -> dict:
    """A RunTrace as a Chrome trace-event document (dict, JSON-ready).

    Extra top-level keys (``schema``, ``trace_id``, ``manifest``,
    ``metrics``) ride along — the trace-event spec instructs viewers to
    ignore unknown keys, and they make the exported file self-describing
    for ``benchmarks/figures.py`` and humans.
    """
    t0 = min((s.ts for s in run_trace.spans), default=0)
    events = []
    for pid, proc in run_trace.processes():
        events.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": pid,
                "tid": 0,
                "args": {"name": f"{proc}:{pid}"},
            }
        )
    for s in run_trace.spans:
        args = dict(s.attrs)
        if s.parent_id:
            args["parent"] = s.parent_id
        events.append(
            {
                "ph": "X",
                "name": s.name,
                "cat": s.proc,
                "ts": (s.ts - t0) / 1000.0,  # µs
                "dur": s.dur * 1e6,  # µs
                "pid": s.pid,
                "tid": 0,
                "id": s.span_id,
                "args": args,
            }
        )
    return {
        "schema": "chrome-trace",
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "trace_id": run_trace.trace_id,
        "manifest": run_trace.manifest,
        "metrics": run_trace.metrics,
    }


def load_run_trace(path: str):
    from repro.core.obs import RunTrace

    if os.path.isdir(path):
        return RunTrace.load(path)
    return RunTrace.read(path)


def main(argv=None) -> int:
    sys.path.insert(0, "src")
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("input", help="run-trace JSON file or trace directory")
    ap.add_argument(
        "-o",
        "--out",
        default=None,
        help="output path (default: <input>.trace.json)",
    )
    args = ap.parse_args(argv)

    rt = load_run_trace(args.input)
    if not rt.spans:
        print(f"[trace_export] no spans found in {args.input}", file=sys.stderr)
        return 1
    out = args.out or (args.input.rstrip("/").rsplit(".", 1)[0] + ".trace.json")
    doc = chrome_trace(rt)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    n_proc = len(rt.processes())
    print(
        f"[trace_export] {len(rt.spans)} spans across {n_proc} "
        f"process(es) -> {out}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
