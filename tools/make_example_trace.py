"""Regenerate the committed example telemetry artifacts.

    PYTHONPATH=src python tools/make_example_trace.py

Runs one mixed grid + stream + serve experiment with ``workers=2`` under
a span trace (``docs/OBSERVABILITY.md``) and writes the merged
cross-process ``RunTrace`` plus its Chrome trace-event rendering to:

- ``results/example_run.trace.json``
- ``results/example_run.chrome.json``

The artifact cache and span directory are ephemeral; only the two
results files are produced.  Span timings are host-dependent, so the
committed copies are illustrative, not gated — CI gates the trace
*machinery* via ``tests/test_obs.py`` instead.
"""
from __future__ import annotations

import json
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))


def main() -> int:
    from repro.core import ArtifactCache, Experiment, WorkloadCache
    from repro.core.driver import WorkloadSpec
    from repro.core.obs import spans as obs
    from repro.serve import ServeSpec, TenantSpec
    from repro.stream import SlidingWindow, StreamSpec
    from tools.trace_export import chrome_trace

    out_dir = REPO / "results"
    with tempfile.TemporaryDirectory() as tmp:
        cache = WorkloadCache(artifacts=ArtifactCache(Path(tmp) / "arts"))
        exp = Experiment(
            workloads=[
                WorkloadSpec(kernel="pgd", dataset="tiny"),
                StreamSpec(
                    kernel="pgd",
                    dataset="tiny",
                    churn=SlidingWindow(),
                    epochs=3,
                ),
                ServeSpec(
                    tenants=(TenantSpec("pgd", "tiny"), TenantSpec("cc", "tiny"))
                ),
            ],
            prefetchers=["amc", "nextline2"],
            cache=cache,
        )
        with obs.trace(dir=Path(tmp) / "trace") as t:
            result = exp.run(workers=2)
        rt = t.result

    assert result.telemetry.get("trace_id") == t.trace_id
    rt.save(out_dir / "example_run.trace.json")
    doc = chrome_trace(rt)
    with open(out_dir / "example_run.chrome.json", "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    names = sorted({s.name for s in rt.spans})
    print(f"[example-trace] {len(rt.spans)} spans, {rt.processes()}")
    print(f"[example-trace] span names: {', '.join(names)}")
    print(f"[example-trace] wrote {out_dir}/example_run.{{trace,chrome}}.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
