"""Diff the two most recent BENCH documents and gate on regressions.

    PYTHONPATH=src python tools/bench_diff.py [--threshold 1.5] \
        [--min-seconds 0.05] [--out results/bench_diff.json] [old new]

With no explicit paths, picks the two most recent *comparable*
``BENCH_*.json`` at the repo root — chronological order (the
``benchmarks.perf_report.bench_sort_key`` ordering, not lexicographic),
and comparable meaning the same ``smoke`` flag and the same grid, so a
CI smoke run never diffs against a committed full run.  Every stage/cell
key from ``benchmarks.perf_report.flatten_stages`` is compared; a
*regression* is a stage that is both ``threshold``x slower than the
baseline and at least ``min-seconds`` absolutely slower (the floor keeps
sub-millisecond noise cells from tripping a ratio gate).

Exit status: 0 clean or no comparable baseline (a note is printed — the
first run of a new configuration has nothing to diff against), 1 on any
regression.  ``--out`` writes the full diff as JSON for CI artifacts.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def comparable(a: dict, b: dict) -> bool:
    """Same benchmark configuration: smoke flag and grid shape."""
    return bool(a.get("smoke")) == bool(b.get("smoke")) and a.get("grid") == b.get(
        "grid"
    )


# Stage-key alias map for schema transitions that *replace* keys rather
# than add them.  Schema v9's fused hierarchy engine collapses the
# demand walk's per-level ``cache_pass[l1|l2|llc]`` launches into one
# ``...[fused]`` stage (scoring keeps the per-level ``l2``/``llc`` keys
# — its launches batch across prefetchers but stay per level); a naive
# diff would show the fused key as new (never gated) and the per-level
# keys as vanished.  When exactly one side of the diff has a fused key,
# the other side is synthesized as the SUM of its per-level predecessor
# keys, so the fused stage is compared against the work it replaced.
# Both spellings are handled: the nested ``stages_s`` dict form
# (``cache_pass.fused``) and the bracketed raw-key form the sharded
# section uses (``cache_pass[fused]``).
_ALIAS_LEVELS = ("l1", "l2", "llc")


def _alias_sum(flat: dict, fused_key: str):
    """Sum of ``fused_key``'s per-level predecessors in ``flat``, or None."""
    if fused_key.endswith("cache_pass.fused"):
        base = fused_key[: -len("fused")]
        parts = [base + p for p in _ALIAS_LEVELS]
    elif fused_key.endswith("cache_pass[fused]"):
        base = fused_key[: -len("[fused]")]
        parts = [f"{base}[{p}]" for p in _ALIAS_LEVELS]
    else:
        return None
    vals = [flat[p] for p in parts if p in flat]
    return sum(vals) if vals else None


def diff_stages(
    old: dict,
    new: dict,
    threshold: float,
    min_seconds: float,
) -> dict:
    """Per-stage comparison of two BENCH documents.

    Returns ``{"rows": [...], "regressions": [...]}`` where each row has
    the stage key, both timings, and the ratio; regressions are the rows
    breaching both the ratio threshold and the absolute floor.  A fused
    cache-pass key present on only one side diffs against the sum of the
    other side's per-level keys (``"aliased": true`` on the row).
    """
    from benchmarks.perf_report import flatten_stages

    f_old, f_new = flatten_stages(old), flatten_stages(new)
    rows, regressions = [], []
    for key in sorted(set(f_old) | set(f_new)):
        o, n = f_old.get(key), f_new.get(key)
        row = {"stage": key, "old_s": o, "new_s": n}
        if o is None and n is not None:
            o = _alias_sum(f_old, key)
            if o is not None:
                row["old_s"], row["aliased"] = o, True
        elif n is None and o is not None:
            n = _alias_sum(f_new, key)
            if n is not None:
                row["new_s"], row["aliased"] = n, True
        if o is not None and n is not None and o > 0:
            row["ratio"] = n / o
            if n / o > threshold and (n - o) > min_seconds:
                row["regression"] = True
                regressions.append(row)
        rows.append(row)
    return {"rows": rows, "regressions": regressions}


def pick_latest_pair(root: str):
    """The two most recent mutually-comparable BENCH docs, oldest first.

    The newest document anchors the diff; the baseline is the most
    recent older document with the same configuration.  Returns
    ``(old_path, old_doc, new_path, new_doc)`` or None.
    """
    from benchmarks.perf_report import bench_sort_key

    paths = sorted(
        glob.glob(os.path.join(root, "BENCH_*.json")), key=bench_sort_key
    )
    docs = []
    for p in paths:
        try:
            with open(p) as f:
                docs.append((p, json.load(f)))
        except (OSError, json.JSONDecodeError) as e:
            print(f"[bench_diff] skipping unreadable {p}: {e}", file=sys.stderr)
    if len(docs) < 2:
        return None
    new_path, new_doc = docs[-1]
    for old_path, old_doc in reversed(docs[:-1]):
        if comparable(old_doc, new_doc):
            return old_path, old_doc, new_path, new_doc
    return None


def main(argv=None) -> int:
    sys.path.insert(0, "src")
    sys.path.insert(0, ".")
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", help="explicit [old new] BENCH paths")
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.5,
        help="regression ratio gate: new/old above this fails (default 1.5)",
    )
    ap.add_argument(
        "--min-seconds",
        type=float,
        default=0.05,
        help="absolute slowdown floor below which a ratio breach is noise",
    )
    ap.add_argument("--out", default=None, help="write the diff JSON here")
    ap.add_argument("--root", default=".", help="directory of BENCH_*.json")
    args = ap.parse_args(argv)

    if args.paths:
        if len(args.paths) != 2:
            ap.error("give exactly two explicit paths: old new")
        old_path, new_path = args.paths
        with open(old_path) as f:
            old_doc = json.load(f)
        with open(new_path) as f:
            new_doc = json.load(f)
        if not comparable(old_doc, new_doc):
            print(
                f"[bench_diff] warning: {old_path} and {new_path} differ in "
                "smoke flag or grid; ratios may not be meaningful",
                file=sys.stderr,
            )
    else:
        pair = pick_latest_pair(args.root)
        if pair is None:
            print(
                "[bench_diff] no comparable BENCH pair found (need two "
                "documents with the same smoke flag and grid) — nothing to "
                "diff, passing"
            )
            if args.out:
                with open(args.out, "w") as f:
                    json.dump({"comparable": False, "rows": []}, f, indent=1)
            return 0
        old_path, old_doc, new_path, new_doc = pair

    result = diff_stages(old_doc, new_doc, args.threshold, args.min_seconds)
    result.update(
        comparable=True,
        old=os.path.basename(old_path),
        new=os.path.basename(new_path),
        threshold=args.threshold,
        min_seconds=args.min_seconds,
    )
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
            f.write("\n")

    print(f"[bench_diff] {result['old']} -> {result['new']}")
    for row in result["rows"]:
        if row.get("old_s") is None or row.get("new_s") is None:
            continue
        mark = " REGRESSION" if row.get("regression") else ""
        print(
            f"  {row['stage']}: {row['old_s']:.3f}s -> {row['new_s']:.3f}s "
            f"({row.get('ratio', 0):.2f}x){mark}"
        )
    if result["regressions"]:
        print(
            f"[bench_diff] FAIL: {len(result['regressions'])} stage(s) "
            f"regressed beyond {args.threshold:.2f}x (+{args.min_seconds}s)"
        )
        return 1
    print("[bench_diff] OK: no regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
