"""Docs gate: link-check the documentation and execute its quickstarts.

    PYTHONPATH=src python tools/check_docs.py

Checks, over ``README.md`` and every ``docs/*.md``:

1. **Markdown links** ``[text](target)``: every relative target (no URL
   scheme) must exist on disk (``#anchor`` suffixes are stripped;
   pure-anchor and external links are skipped).
2. **Backticked paths**: inline-code tokens that look like repo paths
   (``src/...``, ``docs/...``, ``tests/...``, ``benchmarks/...``,
   ``tools/...``, ``examples/...``, ``results/...``, or an UPPERCASE
   root ``*.md``) must exist — the guard against docs rotting as modules
   move.  Tokens containing glob/placeholder characters are skipped.
3. **Quickstart blocks**: every fenced code block whose info string is
   ``python exec`` runs in a fresh interpreter (``PYTHONPATH=src``, repo
   root cwd) and must exit 0 — the documented examples are executed
   against the tiny dataset on every push, not trusted.

Exits non-zero listing every failure; CI's ``docs`` job runs this.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```([^\n]*)\n(.*?)^```", re.M | re.S)
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_PREFIXES = (
    "src/",
    "docs/",
    "tests/",
    "benchmarks/",
    "tools/",
    "examples/",
    "results/",
)
PATH_TOKEN_RE = re.compile(r"^[A-Za-z0-9_][A-Za-z0-9_./-]*$")
ROOT_MD_RE = re.compile(r"^[A-Z][A-Z_]*\.md$")


def doc_files() -> list[Path]:
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def strip_fences(text: str) -> str:
    """Drop fenced code blocks so their contents aren't link/path-checked."""
    return FENCE_RE.sub("", text)


def check_links(path: Path, text: str) -> list[str]:
    errors = []
    for target in LINK_RE.findall(strip_fences(text)):
        if "://" in target or target.startswith(("mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not (path.parent / rel).exists() and not (REPO / rel).exists():
            errors.append(f"{path.name}: broken link -> {target}")
    return errors


def check_paths(path: Path, text: str) -> list[str]:
    errors = []
    for token in CODE_RE.findall(strip_fences(text)):
        is_repo_path = token.startswith(PATH_PREFIXES) and PATH_TOKEN_RE.match(
            token
        )
        if not (is_repo_path or ROOT_MD_RE.match(token)):
            continue
        if not (REPO / token).exists():
            errors.append(f"{path.name}: dangling path reference `{token}`")
    return errors


def run_quickstarts(path: Path, text: str) -> list[str]:
    errors = []
    for n, (info, body) in enumerate(FENCE_RE.findall(text), 1):
        if info.strip() != "python exec":
            continue
        with tempfile.NamedTemporaryFile(
            "w", suffix=".py", prefix=f"docs_{path.stem}_", delete=False
        ) as fh:
            fh.write(body)
            script = fh.name
        env = dict(os.environ)
        env["PYTHONPATH"] = str(REPO / "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        try:
            proc = subprocess.run(
                [sys.executable, script],
                cwd=REPO,
                env=env,
                capture_output=True,
                text=True,
                timeout=600,
            )
        finally:
            os.unlink(script)
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
            errors.append(
                f"{path.name}: quickstart block #{n} exited "
                f"{proc.returncode}:\n    " + "\n    ".join(tail)
            )
        else:
            print(f"[check_docs] {path.name} block #{n}: OK")
    return errors


def main() -> int:
    errors: list[str] = []
    for path in doc_files():
        text = path.read_text()
        errors += check_links(path, text)
        errors += check_paths(path, text)
        errors += run_quickstarts(path, text)
    if errors:
        print(f"[check_docs] {len(errors)} failure(s):", file=sys.stderr)
        for e in errors:
            print("  " + e, file=sys.stderr)
        return 1
    print(f"[check_docs] {len(doc_files())} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
