"""End-to-end evolving-graph analytics with AMC — the paper's own workload.

Runs BFS twice per the paper's §VI protocol (80% subgraph, then -10%/+10%
vertices), evaluates AMC on the second run, and demonstrates the TPU-native
AMC-gather path: the recorded property-gather index stream of run 1 drives
the double-buffered Pallas gather in run 2 (DESIGN.md §2.2).

    PYTHONPATH=src python examples/evolving_graph_analytics.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import Experiment
from repro.graphs import make_dataset, make_evolving_pair
from repro.kernels.amc_gather.ops import AMCGatherSession


def amc_gather_demo():
    """The TPU analogue: replay run-1's gather stream in run 2."""
    g = make_dataset("comdblp")
    pair = make_evolving_pair(g, seed=1)
    print(
        f"evolving pair: run1 {pair.run1.num_edges} edges, "
        f"run2 {pair.run2.num_edges} edges, overlap {pair.vertex_overlap:.0%}"
    )
    # property table + the two runs' gather streams. Streams are keyed by
    # VERTEX (like AMC's trigger-keyed entries), not by CSR position — raw
    # positional streams shift wholesale when edges are deleted.
    table = jnp.asarray(
        np.random.default_rng(0).normal(size=(g.num_vertices, 128)).astype(np.float32)
    )
    import numpy as _np

    def vertex_stream(run, vids, cap=8):
        out = []
        for v in vids:
            s, e = run.offsets[v], run.offsets[v + 1]
            row = run.neighbors[s:e][:cap]
            out.append(_np.pad(row, (0, cap - len(row)), constant_values=v))
        return _np.concatenate(out).astype(_np.int32)

    deg = _np.minimum(pair.run1.degrees, pair.run2.degrees)
    vids = _np.argsort(-deg)[:512]
    idx1 = vertex_stream(pair.run1, vids)
    idx2 = vertex_stream(pair.run2, vids)
    sess = AMCGatherSession(interpret=True)
    # run 1: record (cold)
    sess.gather(table, jnp.asarray(idx1))
    sess.update()  # AMC.update(): role swap
    # run 2: replayed stream drives the pipelined gather; changed rows fixed
    out2 = sess.gather(table, jnp.asarray(idx2))
    ref = table[idx2]
    match = float((idx1 == idx2).mean())
    print(
        f"amc_gather: replayed={sess.stats['replayed']} "
        f"fallback={sess.stats['fallback']} stream-stability={match:.0%} "
        f"exact={bool(jnp.allclose(out2, ref))}"
    )


def main():
    print("=== BFS on evolving graph (paper §VI protocol) ===")
    result = Experiment(
        kernels=["bfs"], datasets=["notredame"], prefetchers=["amc"]
    ).run()
    m = result.metrics(prefetcher="amc")
    print(
        f"run-2 evaluation: speedup {m.speedup:.2f}x, "
        f"coverage {m.coverage:.0%}, accuracy {m.accuracy:.0%}, "
        f"late {m.late/max(m.useful,1):.0%} of useful"
    )
    print("\n=== TPU-native recorded-stream gather ===")
    amc_gather_demo()


if __name__ == "__main__":
    main()
