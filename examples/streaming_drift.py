"""Streaming evolving-graph drift demo: AMC table lifecycle over E epochs.

Runs one kernel over a multi-epoch update stream (default: a 6-epoch
sliding-window stream on comdblp/PGD), scoring AMC under two table
lifecycle policies — ``persist`` (carry correlations across graph
versions, the paper's behavior) and ``reset`` (cold tables per version) —
alongside stateless baselines, and writes the drift-curve JSON
(``stream-drift`` schema, consumed by ``benchmarks/figures.fig_drift``).

    PYTHONPATH=src python examples/streaming_drift.py
    PYTHONPATH=src python examples/streaming_drift.py --tiny   # CI smoke
    PYTHONPATH=src python examples/streaming_drift.py --verify-parallel
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")

from repro.core import Experiment, WorkloadCache  # noqa: E402
from repro.core.exec.artifacts import ArtifactCache  # noqa: E402
from repro.core.exec.scheduler import rows_equal  # noqa: E402
from repro.stream import CHURN_MODELS, StreamSpec, drift_payload  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernel", default="pgd")
    ap.add_argument("--dataset", default="comdblp")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument(
        "--churn", default="sliding_window", choices=sorted(CHURN_MODELS)
    )
    ap.add_argument("--prefetchers", default="amc,vldp,nextline2")
    ap.add_argument("--policies", default="persist,reset")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke config: 3 epochs on the tiny dataset, amc+nextline2",
    )
    ap.add_argument(
        "--verify-parallel",
        action="store_true",
        help="re-run with workers=2 and assert byte-identical rows",
    )
    ap.add_argument("--out", default=None, help="drift JSON path (default: results/)")
    args = ap.parse_args(argv)

    if args.tiny:
        args.dataset, args.epochs = "tiny", 3
        args.prefetchers, args.policies = "amc,nextline2", "persist,reset"

    churn = CHURN_MODELS[args.churn]()
    policies = args.policies.split(",")
    prefetchers = args.prefetchers.split(",")
    streams = [
        StreamSpec(
            args.kernel,
            args.dataset,
            churn,
            epochs=args.epochs,
            lifecycle=pol,
            seed=args.seed,
        )
        for pol in policies
    ]
    # One cache: epoch traces are lifecycle-agnostic, so every policy (and
    # the parity re-run) shares the same E builds.
    cache = WorkloadCache(artifacts=ArtifactCache())

    print(
        f"=== {args.epochs}-epoch {args.churn} stream on "
        f"{args.kernel}/{args.dataset} ({', '.join(prefetchers)}) ==="
    )
    # Explicit workers: --workers 1 pins the serial reference run that the
    # --verify-parallel gate compares against.
    exp = Experiment(workloads=streams, prefetchers=prefetchers, cache=cache)
    result = exp.run(workers=args.workers)

    parity = None
    if args.verify_parallel:
        par = Experiment(
            workloads=streams, prefetchers=prefetchers, cache=cache
        ).run(workers=2)
        parity = rows_equal(result.rows(), par.rows())
        print(f"serial vs workers=2: {'byte-identical' if parity else 'DIVERGED'}")

    # Merge all policies into one drift document: AMC keyed per policy,
    # stateless baselines once (identical across policies, deduped).
    merged = None
    for spec in streams:
        epoch_set = set(spec.epoch_specs())
        seen, cells = set(), []
        for c in result.cells:
            if c.epoch is None or c.spec not in epoch_set:
                continue
            if c.lifecycle is not None and c.lifecycle != spec.lifecycle:
                continue  # another policy's lifecycle-carried cells
            key = (c.prefetcher, c.epoch)
            if key in seen:
                continue  # stateless baseline, already scored identically
            seen.add(key)
            cells.append(c)
        doc = drift_payload(spec, spec.sequence(), cells)
        if merged is None:
            merged = {**doc, "lifecycle": ",".join(policies), "prefetchers": {}}
        for name, pf in doc["prefetchers"].items():
            key = f"{name}[{pf['lifecycle']}]" if pf["lifecycle"] else name
            merged["prefetchers"][key] = pf
    if parity is not None:
        merged["parallel_matches_serial"] = parity

    for name, pf in sorted(merged["prefetchers"].items()):
        s = pf["summary"]
        cov = " ".join(f"{c:.2f}" for c in s["coverage"])
        print(
            f"{name:>22}: coverage by epoch [{cov}]  "
            f"tail mean {s['tail_mean_coverage']:.2f}  "
            f"accuracy {s['mean_accuracy']:.2f}"
        )
    overlap = merged["overlap"]["cumulative_overlap"]
    print(f"{'cumulative overlap':>22}: " + " ".join(f"{v:.2f}" for v in overlap))

    pa, pr = (
        merged["prefetchers"].get("amc[persist]"),
        merged["prefetchers"].get("amc[reset]"),
    )
    if pa and pr:
        gain = (
            pa["summary"]["tail_mean_coverage"] - pr["summary"]["tail_mean_coverage"]
        )
        print(
            f"persist vs reset (mean epoch>=2 coverage): "
            f"{pa['summary']['tail_mean_coverage']:.2f} vs "
            f"{pr['summary']['tail_mean_coverage']:.2f} (+{gain:.2f})"
        )

    out = args.out or os.path.join(
        "results", f"drift_{args.kernel}_{args.dataset}_{args.churn}.json"
    )
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(merged, f, indent=1)
        f.write("\n")
    print(f"wrote {out}")
    return 0 if parity in (None, True) else 1


if __name__ == "__main__":
    raise SystemExit(main())
