"""Batched serving example: prefill + decode against a KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral_8x22b]
(reduced configs on CPU; the same entry point drives full configs on TPU).
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "qwen3_4b"]
    serve_main(args + ["--reduced", "--batch", "4", "--prompt-len", "16", "--gen", "16"])
