"""End-to-end LM training driver: a few hundred steps with checkpoint/resume.

Uses the full production train path (config system, AdamW + cosine,
CheckpointManager with atomic commit, straggler monitor) on a reduced
smollm config sized for CPU. Pass --arch/--steps to scale up on real
hardware; the same entry point drives the full configs.

    PYTHONPATH=src python examples/train_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


if __name__ == "__main__":
    losses = train_main(
        [
            "--arch", "smollm_360m",
            "--reduced",
            "--steps", "200",
            "--batch", "8",
            "--seq", "128",
            "--ckpt-dir", "/tmp/repro_ckpt_example",
            "--ckpt-every", "100",
        ]
    )
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
