"""Multi-tenant serving demo: K concurrent query traces, one shared LLC.

Runs K tenant workloads (default: a 3-tenant mixed kernel/seed scenario on
comdblp) interleaved onto a shared LLC, scoring AMC under both table modes
— ``per_tenant`` (private correlation tables, the provisioned-isolation
upper bound) and ``shared`` (one table store for everyone, the
correlation-aliasing failure mode) — alongside stateless baselines, and
writes the contention JSON (``serve-contention`` schema, consumed by
``benchmarks/figures.fig_contention``).

    PYTHONPATH=src python examples/serving_contention.py
    PYTHONPATH=src python examples/serving_contention.py --tiny   # CI smoke
    PYTHONPATH=src python examples/serving_contention.py --verify-parallel
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")

from repro.core import Experiment, WorkloadCache  # noqa: E402
from repro.core.exec.artifacts import ArtifactCache  # noqa: E402
from repro.core.exec.scheduler import rows_equal  # noqa: E402
from repro.serve import (  # noqa: E402
    TABLE_MODES,
    ServeCell,
    ServeSpec,
    TenantSpec,
    contention_payload,
)


def parse_tenants(s: str):
    """``kernel:dataset:seed[:rate]`` comma list -> TenantSpecs."""
    tenants = []
    for part in s.split(","):
        bits = part.split(":")
        if len(bits) not in (3, 4):
            raise SystemExit(
                f"bad tenant {part!r}: expected kernel:dataset:seed[:rate]"
            )
        tenants.append(
            TenantSpec(
                kernel=bits[0],
                dataset=bits[1],
                seed=int(bits[2]),
                rate=float(bits[3]) if len(bits) == 4 else 1.0,
            )
        )
    return tuple(tenants)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--tenants",
        default="pgd:comdblp:0,cc:comdblp:0,pgd:comdblp:1",
        help="comma list of kernel:dataset:seed[:rate] tenant specs",
    )
    ap.add_argument("--policy", default="round_robin")
    ap.add_argument("--prefetchers", default="amc,vldp,nextline2")
    ap.add_argument(
        "--table-modes",
        default=",".join(TABLE_MODES),
        help="AMC table modes to score (stateless baselines ignore this)",
    )
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="CI smoke config: K=3 mixed tenants on the tiny dataset, "
        "amc+nextline2, both table modes",
    )
    ap.add_argument(
        "--verify-parallel",
        action="store_true",
        help="re-run with workers=2 and assert byte-identical rows",
    )
    ap.add_argument(
        "--out", default=None, help="contention JSON path (default: results/)"
    )
    args = ap.parse_args(argv)

    if args.tiny:
        args.tenants = "pgd:tiny:0,cc:tiny:0,pgd:tiny:1"
        args.prefetchers = "amc,nextline2"

    tenants = parse_tenants(args.tenants)
    prefetchers = args.prefetchers.split(",")
    spec = ServeSpec(
        tenants=tenants,
        policy=args.policy,
        table_modes=tuple(args.table_modes.split(",")),
    )
    # One cache: tenant traces are mode/policy-agnostic, so the parity
    # re-run (and any repeat scenario) shares the same K builds.
    cache = WorkloadCache(artifacts=ArtifactCache())

    label = "+".join(f"{t.kernel}/{t.dataset}#s{t.seed}" for t in tenants)
    print(
        f"=== K={spec.num_tenants} serving [{args.policy}] {label} "
        f"({', '.join(prefetchers)}) ==="
    )
    # Explicit workers: --workers 1 pins the serial reference run that the
    # --verify-parallel gate compares against.
    exp = Experiment(workloads=[spec], prefetchers=prefetchers, cache=cache)
    result = exp.run(workers=args.workers)

    parity = None
    if args.verify_parallel:
        par = Experiment(
            workloads=[spec], prefetchers=prefetchers, cache=cache
        ).run(workers=2)
        parity = rows_equal(result.rows(), par.rows())
        print(f"serial vs workers=2: {'byte-identical' if parity else 'DIVERGED'}")

    wspecs = spec.tenant_workloads()
    cells = [
        ServeCell(
            tenant=c.tenant,
            prefetcher=c.prefetcher,
            table_mode=c.table_mode,
            metrics=c.metrics,
            spec=wspecs[c.tenant],
        )
        for c in result.cells
    ]
    doc = contention_payload(spec, cells)
    if parity is not None:
        doc["parallel_matches_serial"] = parity

    for name, modes in sorted(doc["prefetchers"].items()):
        for mode, d in sorted(modes.items()):
            cov = " ".join(
                f"{r['coverage']:.2f}" for r in d["per_tenant_rows"]
            )
            extras = ""
            if mode == "shared":
                st = [
                    r["serve"].get("shared_table", {})
                    for r in d["per_tenant_rows"]
                ]
                extras = (
                    f"  aliased {sum(s.get('aliased_hits', 0) for s in st)}"
                    f"  overwrites "
                    f"{st[0].get('cross_tenant_overwrites', 0) if st else 0}"
                )
            print(
                f"{name + '[' + mode + ']':>22}: coverage by tenant [{cov}]  "
                f"mean cov {d['mean_coverage']:.2f}  "
                f"acc {d['mean_accuracy']:.2f}  "
                f"speedup {d['mean_speedup']:.2f}{extras}"
            )

    for name, modes in sorted(doc["prefetchers"].items()):
        if "per_tenant" in modes and "shared" in modes:
            gap = (
                modes["per_tenant"]["mean_coverage"]
                - modes["shared"]["mean_coverage"]
            )
            print(
                f"{name} per-tenant vs shared tables (mean coverage): "
                f"{modes['per_tenant']['mean_coverage']:.2f} vs "
                f"{modes['shared']['mean_coverage']:.2f} (+{gap:.2f} "
                f"from table isolation)"
            )

    dataset = tenants[0].dataset
    out = args.out or os.path.join(
        "results", f"contention_{dataset}_k{spec.num_tenants}.json"
    )
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out}")
    return 0 if parity in (None, True) else 1


if __name__ == "__main__":
    raise SystemExit(main())
