"""Quickstart: AMC prefetcher on PageRankDelta, 2 minutes on CPU.

Declares one `Experiment` cell (PGD on comdblp, AMC vs VLDP), runs the
composite simulation (baseline next-line vs next-line + X), and prints the
paper's headline metrics. Workload construction — including the AMC
programming interface exactly as Algorithm 1 uses it — is owned by the
declarative `WorkloadSpec` inside the experiment.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import Experiment


def main():
    # comdblp is the smallest Table VII dataset — fast on CPU.
    result = Experiment(
        kernels=["pgd"], datasets=["comdblp"], prefetchers=["amc", "vldp"]
    ).run()
    w = result.workload("pgd", "comdblp")
    print(
        f"workload: PGD on {w.dataset} "
        f"({w.num_accesses:,} accesses, {len(w.iter_epochs)} iterations)"
    )
    # The programming model (paper Table V) was configured by the workload
    # spec exactly as Algorithm 1 lines 7-8, 21, 27:
    sess = w.session
    print(
        f"AMC registers: target@0x{sess.regs.target_base:x} "
        f"frontier@0x{sess.regs.frontier_base:x}"
    )

    print(f"\n{'prefetcher':<10} {'speedup':>8} {'coverage':>9} {'accuracy':>9}")
    for cell in result.cells:
        m = cell.metrics
        print(f"{cell.prefetcher:<10} {m.speedup:>8.2f} {m.coverage:>9.2%} {m.accuracy:>9.2%}")
    amc = result.metrics(prefetcher="amc")
    print(
        f"\nAMC metadata: compression ratio "
        f"{amc.info['compression_ratio']:.2f}, "
        f"storage peak {amc.info['storage_peak_bytes']/1024:.0f} KB "
        f"({amc.info['storage_peak_bytes']/w.input_bytes:.0%} of input)"
    )


if __name__ == "__main__":
    main()
