"""Quickstart: AMC prefetcher on PageRankDelta, 2 minutes on CPU.

Builds a small evolving-graph workload, runs the composite simulation
(baseline next-line vs next-line + AMC), and prints the paper's headline
metrics. Uses the AMC programming interface exactly as Algorithm 1 does.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import build_workload, run_prefetcher_suite
from repro.core.amc import AMCConfig, AMCPrefetcher
from repro.core.prefetchers import SUITE


def main():
    # comdblp is the smallest Table VII dataset — fast on CPU.
    w = build_workload("pgd", "comdblp")
    print(
        f"workload: PGD on {w.dataset} "
        f"({w.num_accesses:,} accesses, {len(w.iter_epochs)} iterations)"
    )
    # The programming model (paper Table V) is already configured by the
    # driver exactly as Algorithm 1 lines 7-8, 21, 27:
    sess = w.session
    print(
        f"AMC registers: target@0x{sess.regs.target_base:x} "
        f"frontier@0x{sess.regs.frontier_base:x}"
    )

    suite = {
        "amc": AMCPrefetcher(AMCConfig()).generate,
        "vldp": SUITE["vldp"],
    }
    results = run_prefetcher_suite(w, suite)
    print(f"\n{'prefetcher':<10} {'speedup':>8} {'coverage':>9} {'accuracy':>9}")
    for name, m in results.items():
        print(f"{name:<10} {m.speedup:>8.2f} {m.coverage:>9.2%} {m.accuracy:>9.2%}")
    amc = results["amc"]
    print(
        f"\nAMC metadata: compression ratio "
        f"{amc.info['compression_ratio']:.2f}, "
        f"storage peak {amc.info['storage_peak_bytes']/1024:.0f} KB "
        f"({amc.info['storage_peak_bytes']/w.input_bytes:.0%} of input)"
    )


if __name__ == "__main__":
    main()
