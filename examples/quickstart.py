"""Quickstart: AMC prefetcher on PageRankDelta, 2 minutes on CPU.

Declares one `Experiment` cell (PGD on comdblp, AMC vs VLDP), runs the
composite simulation (baseline next-line vs next-line + X), and prints the
paper's headline metrics. Workload construction — including the AMC
programming interface exactly as Algorithm 1 uses it — is owned by the
declarative `WorkloadSpec` inside the experiment.

`--workers N` runs the same cells on the parallel execution engine (same
results, bit-identical); either way the built trace persists in the
workload artifact cache, so the second invocation skips the build.

    PYTHONPATH=src python examples/quickstart.py [--workers 2]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import ArtifactCache, Experiment, WorkloadCache


def main(workers: int = 1):
    # comdblp is the smallest Table VII dataset — fast on CPU.
    result = Experiment(
        kernels=["pgd"], datasets=["comdblp"], prefetchers=["amc", "vldp"],
        cache=WorkloadCache(artifacts=ArtifactCache()),
    ).run(workers=workers if workers > 1 else None)
    w = result.workload("pgd", "comdblp")
    print(
        f"workload: PGD on {w.dataset} "
        f"({w.num_accesses:,} accesses, {len(w.iter_epochs)} iterations)"
    )
    # The programming model (paper Table V) was configured by the workload
    # spec exactly as Algorithm 1 lines 7-8, 21, 27:
    sess = w.session
    print(
        f"AMC registers: target@0x{sess.regs.target_base:x} "
        f"frontier@0x{sess.regs.frontier_base:x}"
    )

    print(f"\n{'prefetcher':<10} {'speedup':>8} {'coverage':>9} {'accuracy':>9}")
    for cell in result.cells:
        m = cell.metrics
        print(f"{cell.prefetcher:<10} {m.speedup:>8.2f} {m.coverage:>9.2%} {m.accuracy:>9.2%}")
    amc = result.metrics(prefetcher="amc")
    print(
        f"\nAMC metadata: compression ratio "
        f"{amc.info['compression_ratio']:.2f}, "
        f"storage peak {amc.info['storage_peak_bytes']/1024:.0f} KB "
        f"({amc.info['storage_peak_bytes']/w.input_bytes:.0%} of input)"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=1)
    main(workers=ap.parse_args().workers)
