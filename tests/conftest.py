"""Test config. NOTE: no XLA_FLAGS device-count override here by design —
smoke tests and benches must see the real (single) device; only the
dry-run process emulates 512 devices (see repro.launch.dryrun)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
