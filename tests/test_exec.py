"""The parallel execution engine: scheduler, artifact cache, timers.

Covers the engine's contracts: parallel (``workers=2``) results are
bit-identical to serial in the same cell order; the workload artifact
cache round-trips a built trace to metrics-identical scoring; cache keys
move when the spec or the trace-code version changes (invalidation);
corrupt artifacts read as misses; unpicklable prefetchers are rejected
with a useful error before any process spawns.
"""

import numpy as np
import pytest

from repro.core import (
    ArtifactCache,
    Experiment,
    WorkloadCache,
    WorkloadSpec,
    score_prefetcher,
)
from repro.core.exec.scheduler import _plan, _split, rows_equal
from repro.core.exec.timers import collect_stages, stage, time_s
from repro.core.registry import get_prefetcher

SPEC = WorkloadSpec("pgd", "comdblp")
PREFETCHERS = ["rnr", "nextline2", "ideal"]


@pytest.fixture(scope="module")
def arts(tmp_path_factory):
    return ArtifactCache(tmp_path_factory.mktemp("workload-artifacts"))


@pytest.fixture(scope="module")
def built(arts):
    """One real trace, built cold and persisted (collecting stage times)."""
    with collect_stages() as stages:
        trace = SPEC.build()
    arts.save(SPEC, trace)
    assert stages["trace_gen"] > 0 and stages["demand_sim"] > 0
    return trace


# ---------------------------------------------------------------- artifacts


def test_artifact_roundtrip_is_bit_identical(arts, built):
    loaded = arts.load(SPEC)
    assert loaded is not None and loaded is not built
    for field in ("block", "iter_id", "elem", "nl_blocks", "nl_pos"):
        np.testing.assert_array_equal(getattr(loaded, field), getattr(built, field))
    assert loaded.iter_epochs == built.iter_epochs
    assert loaded.eval_from_pos == built.eval_from_pos
    assert loaded.session.regs == built.session.regs
    # the contract that matters: scoring a loaded trace reproduces the
    # fresh-build metrics exactly
    gen = get_prefetcher("rnr").instantiate()
    fresh = score_prefetcher(built, "rnr", gen).row()
    reloaded = score_prefetcher(loaded, "rnr", gen).row()
    fresh_info, reloaded_info = fresh.pop("info"), reloaded.pop("info")
    assert fresh == reloaded
    assert set(fresh_info) == set(reloaded_info)
    for k in fresh_info:
        np.testing.assert_array_equal(fresh_info[k], reloaded_info[k])


def test_artifact_key_moves_with_spec_and_code_version(arts, built, monkeypatch):
    other = WorkloadSpec("pgd", "comdblp", target_elem_size=16)
    assert arts.path_for(other) != arts.path_for(SPEC)
    assert arts.load(other) is None  # content-addressed: no false sharing
    # bumping the trace-code version invalidates every persisted artifact
    path_v1 = arts.path_for(SPEC)
    monkeypatch.setattr("repro.core.driver.TRACE_CODE_VERSION", "test-bump")
    assert arts.path_for(SPEC) != path_v1
    assert arts.load(SPEC) is None
    monkeypatch.undo()
    assert arts.load(SPEC) is not None


def test_corrupt_artifact_reads_as_miss(arts):
    bad = WorkloadSpec("pgd", "comdblp", frontier_elem_size=2)
    arts.root.mkdir(parents=True, exist_ok=True)
    arts.path_for(bad).write_bytes(b"not an npz")
    misses = arts.misses
    assert arts.load(bad) is None
    assert arts.misses == misses + 1


def test_workload_cache_disk_backing(arts, built):
    cache = WorkloadCache(artifacts=arts)
    trace = cache.get_or_build(SPEC)
    assert cache.loads == 1 and cache.builds == 0  # disk hit, no rebuild
    assert cache.get_or_build(SPEC) is trace
    assert cache.hits == 1  # second call is an in-memory hit


# ---------------------------------------------------------------- scheduler


def test_parallel_matches_serial_bit_identical(arts, built):
    serial = Experiment(
        workloads=[SPEC], prefetchers=PREFETCHERS, cache=WorkloadCache(artifacts=arts)
    ).run()
    parallel = Experiment(
        workloads=[SPEC], prefetchers=PREFETCHERS, cache=WorkloadCache(artifacts=arts)
    ).run(workers=2)
    assert rows_equal(serial.rows(), parallel.rows())
    # deterministic cell order: workload-major, prefetcher-minor, as serial
    assert [c.prefetcher for c in parallel.cells] == PREFETCHERS
    # the result surface still exposes the built workload
    assert parallel.workload("pgd", "comdblp").num_accesses == built.num_accesses
    # the lazy view materializes real traces through every access path,
    # including dict()'s C-level iteration
    assert SPEC in parallel.workloads and len(parallel.workloads) == 1
    as_dict = dict(parallel.workloads)
    assert all(t.num_accesses == built.num_accesses for t in as_dict.values())


def test_parallel_rejects_unpicklable_prefetcher():
    exp = Experiment(workloads=[SPEC], prefetchers=[("lam", lambda workload: None)])
    with pytest.raises(ValueError, match="not picklable"):
        exp.run(workers=2)


def test_plan_splits_only_materialized_workloads(arts, built, tmp_path):
    pairs = [(n, lambda w: None) for n in ("a", "b", "c")]
    specs = [SPEC, WorkloadSpec("cc", "comdblp")]
    # cold store: one task per workload regardless of workers — the build
    # must happen exactly once, in the worker that scores it
    cold = ArtifactCache(tmp_path / "empty")
    unique, tasks = _plan(specs, pairs, workers=4, artifacts=cold)
    assert len(unique) == 2 and len(tasks) == 2
    assert all(len(chunk) == 3 for _, chunk in tasks)
    # SPEC is materialized in ``arts``: its prefetcher list splits, the
    # unmaterialized cc workload stays whole
    unique, tasks = _plan(specs, pairs, workers=4, artifacts=arts)
    split = [chunk for spec, chunk in tasks if spec == SPEC]
    whole = [chunk for spec, chunk in tasks if spec != SPEC]
    assert len(split) > 1 and len(whole) == 1
    assert sorted(n for chunk in split for n, _ in chunk) == ["a", "b", "c"]
    assert [n for n, _ in whole[0]] == ["a", "b", "c"]
    # duplicate specs collapse to one workload
    unique, tasks = _plan([specs[1], specs[1]], pairs, workers=1, artifacts=cold)
    assert len(unique) == 1 and len(tasks) == 1


def test_split_covers_all_items_in_order():
    assert _split([1, 2, 3, 4, 5], 2) == [[1, 2, 3], [4, 5]]
    assert _split([1], 4) == [[1]]
    assert _split([1, 2], 2) == [[1], [2]]


def test_rows_equal_detects_divergence():
    a = [{"speedup": 1.0, "info": {"x": np.arange(3)}}]
    b = [{"speedup": 1.0, "info": {"x": np.arange(3)}}]
    assert rows_equal(a, b)
    b[0]["info"]["x"] = np.arange(4)
    assert not rows_equal(a, b)
    assert not rows_equal(a, [{"speedup": 1.5, "info": {"x": np.arange(3)}}])
    assert not rows_equal(a, [])


# ------------------------------------------------------------------- timers


def test_stage_collection_accumulates_and_is_noop_when_inactive():
    with collect_stages() as times:
        with stage("phase"):
            pass
        with stage("phase"):
            pass
    assert times["phase"] >= 0 and len(times) == 1
    with stage("orphan"):  # no active collector: must not raise or record
        pass
    assert "orphan" not in times
    assert time_s(lambda: None, repeats=2, warmup=1) >= 0
