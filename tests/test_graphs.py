"""Graph substrate tests: CSR invariants, generators, dynamics, partition."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st

from repro.graphs import (
    from_edges,
    make_dataset,
    make_evolving_pair,
    partition_contiguous,
    rmat_graph,
    powerlaw_graph,
    road_graph,
)
from repro.graphs.csr import symmetrize
from repro.graphs.partition import edge_balance


@given(
    n=st.integers(4, 64),
    m=st.integers(0, 300),
    seed=st.integers(0, 10),
)
@settings(max_examples=25, deadline=None)
def test_from_edges_invariants(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = from_edges(src, dst, n)
    g.validate()
    # no self loops, deduped
    es = g.edge_sources()
    assert not np.any(es == g.neighbors)
    keys = es * n + g.neighbors
    assert len(np.unique(keys)) == len(keys)


def test_rmat_shape():
    g = rmat_graph(1000, 5000, seed=1)
    assert g.num_vertices == 1000
    assert g.num_edges <= 5000
    assert g.num_edges > 3000  # dedup should not eat half
    # power-law-ish: max degree much larger than mean
    assert g.degrees.max() > 5 * g.avg_degree


def test_rmat_skewed_a_valid():
    # regression: a=0.65 used to produce negative quadrant probability
    g = rmat_graph(500, 2000, a=0.65, seed=2)
    assert g.num_edges > 1000


def test_powerlaw_and_road():
    g = powerlaw_graph(2000, 6000, seed=3)
    assert g.num_vertices == 2000
    r = road_graph(2500, seed=4)
    assert abs(r.avg_degree - 4.0) < 1.0  # lattice ~4 + shortcuts


def test_datasets_materialize():
    for name in ["amazon", "comdblp"]:
        g = make_dataset(name)
        g.validate()
        assert g.num_vertices > 1000


def test_evolving_pair_protocol():
    g = make_dataset("comdblp")
    pair = make_evolving_pair(g, seed=0)
    n = g.num_vertices
    assert abs(pair.mask1.sum() - 0.8 * n) < 2
    # run2 = run1 - 10% + 10%: total roughly preserved
    assert abs(pair.mask2.sum() - (0.8 * n - 0.08 * n + 0.1 * n)) < 3
    assert 0.8 < pair.vertex_overlap < 0.95
    # id space preserved: edges only among masked vertices
    for run, mask in [(pair.run1, pair.mask1), (pair.run2, pair.mask2)]:
        src = run.edge_sources()
        assert mask[src].all() and mask[run.neighbors].all()


def test_vertex_overlap_empty_and_fully_churned():
    """Degenerate overlaps must be well-defined 0.0, not a ZeroDivision."""
    from repro.graphs import EvolvingGraphPair

    g = from_edges([0, 1, 2], [1, 2, 3], 6)
    empty = np.zeros(6, dtype=bool)
    half = np.array([True, True, True, False, False, False])
    other = ~half
    # run-1 empty: denominator is max(0, 1)
    pair = EvolvingGraphPair(base=g, run1=g, run2=g, mask1=empty, mask2=half)
    assert pair.vertex_overlap == 0.0
    # fully churned: disjoint vertex sets share nothing
    pair = EvolvingGraphPair(base=g, run1=g, run2=g, mask1=half, mask2=other)
    assert pair.vertex_overlap == 0.0
    # both empty
    pair = EvolvingGraphPair(base=g, run1=g, run2=g, mask1=empty, mask2=empty)
    assert pair.vertex_overlap == 0.0


def test_partition_balance_and_coverage():
    g = make_dataset("comdblp")
    parts, assign = partition_contiguous(g, num_parts=4)
    assert sum(p.num_edges for p in parts) == g.num_edges
    assert edge_balance(parts) < 1.6
    assert set(np.unique(assign)) <= {0, 1, 2, 3}


def test_symmetrize():
    g = from_edges([0, 1], [1, 2], 3)
    u = symmetrize(g)
    assert u.num_edges == 4
