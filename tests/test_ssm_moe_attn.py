"""Layer-level correctness: SSD scan, MoE dispatch, blocked attention."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.attention import blocked_attention, decode_attention
from repro.models.moe import MoEParams, moe_ffn, route_topk
from repro.models.ssm import SSMParams, ssd_chunked, ssm_block, ssm_decode_step


# ----------------------------- SSD -----------------------------


def _ssd_naive(x, dt, a, b, c):
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    y = np.zeros((bsz, s, h, p), np.float32)
    for bi in range(bsz):
        state = np.zeros((h, p, n), np.float32)
        for t in range(s):
            for hi in range(h):
                decay = np.exp(dt[bi, t, hi] * a[hi])
                state[hi] = state[hi] * decay + np.outer(
                    x[bi, t, hi] * dt[bi, t, hi], b[bi, t]
                )
                y[bi, t, hi] = state[hi] @ c[bi, t]
    return y


def test_ssd_chunked_matches_naive_recurrence():
    rng = np.random.default_rng(0)
    bsz, s, h, p, n = 2, 50, 3, 8, 4
    x = rng.normal(size=(bsz, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.1, 0.8, (bsz, s, h)).astype(np.float32)
    a = -rng.uniform(0.3, 1.5, h).astype(np.float32)
    b = rng.normal(size=(bsz, s, n)).astype(np.float32)
    c = rng.normal(size=(bsz, s, n)).astype(np.float32)
    y, state = ssd_chunked(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a), jnp.asarray(b),
        jnp.asarray(c), chunk=16,
    )
    ref = _ssd_naive(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_ssm_decode_consistent_with_chunked():
    """Running T decode steps == running the chunked scan over T tokens."""
    cfg = get_config("mamba2_780m").reduced()
    from repro.models.model import init_params

    params = init_params(cfg, jax.random.PRNGKey(1))["blocks"]["ssm"]
    lp = SSMParams(**{k: params[k][0] for k in SSMParams._fields})
    rng = np.random.default_rng(2)
    T, B = 12, 2
    x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)) * 0.3, jnp.float32)
    y_full, _ = ssm_block(lp, x, cfg)
    d_inner = cfg.ssm_expand * cfg.d_model
    h = d_inner // cfg.ssm_head_dim
    state = jnp.zeros((B, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    ys = []
    for t in range(T):
        yt, state = ssm_decode_step(lp, x[:, t : t + 1], state, cfg)
        ys.append(yt)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_full), rtol=3e-3, atol=3e-3
    )


# ----------------------------- MoE -----------------------------


def _moe_dense_ref(x, p: MoEParams, top_k):
    """Reference: run every expert densely, combine top-k."""
    logits = x.astype(np.float32) @ np.asarray(p.router, np.float32)
    order = np.argsort(-logits, axis=-1)[:, :top_k]
    w = np.take_along_axis(logits, order, axis=-1)
    w = np.exp(w - w.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    y = np.zeros_like(np.asarray(x, np.float32))
    for e in range(p.router.shape[1]):
        g = x @ np.asarray(p.w_gate[e])
        u = x @ np.asarray(p.w_up[e])
        h = (g / (1 + np.exp(-g))) * u
        ye = h @ np.asarray(p.w_down[e])
        for k in range(top_k):
            sel = order[:, k] == e
            y[sel] += w[sel, k : k + 1] * ye[sel]
    return y


def test_moe_dispatch_matches_dense_reference():
    rng = np.random.default_rng(3)
    n, d, f, e, k = 32, 16, 24, 4, 2
    p = MoEParams(
        router=jnp.asarray(rng.normal(size=(d, e)) * 0.5, jnp.float32),
        w_gate=jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        w_up=jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32),
    )
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y, aux, plan = moe_ffn(x, p, k, capacity_factor=4.0)  # no drops
    ref = _moe_dense_ref(np.asarray(x), p, k)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_recorded_plan_exact_when_routing_stable():
    """AMC recorded-dispatch: replaying the previous step's plan is exact
    when the routing did not change (DESIGN.md §2.2)."""
    rng = np.random.default_rng(4)
    n, d, f, e, k = 16, 8, 12, 4, 2
    p = MoEParams(
        router=jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        w_gate=jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        w_up=jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32),
    )
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y1, _, plan = moe_ffn(x, p, k, capacity_factor=4.0)
    y2, _, _ = moe_ffn(x, p, k, capacity_factor=4.0, recorded_plan=plan)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)
    # changed input: stale slots are zero-weighted, never wrong values
    x3 = x.at[0].set(-x[0])
    y3, _, _ = moe_ffn(x3, p, k, capacity_factor=4.0, recorded_plan=plan)
    y3_exact, _, _ = moe_ffn(x3, p, k, capacity_factor=4.0)
    # rows whose routing is unchanged agree exactly
    idx1, _, _ = route_topk(x3, p.router, k)
    idx0, _, _ = route_topk(x, p.router, k)
    stable = np.asarray((idx1 == idx0).all(axis=1))
    np.testing.assert_allclose(
        np.asarray(y3)[stable[: n]], np.asarray(y3_exact)[stable[: n]],
        rtol=1e-5, atol=1e-6,
    )


# --------------------------- attention ---------------------------


def test_blocked_attention_matches_naive():
    rng = np.random.default_rng(5)
    b, s, h, kv, hd = 2, 100, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    out = blocked_attention(q, k, v, causal=True, block_size=32)
    # naive
    from repro.kernels.flash_attn.ref import attention_ref

    kr = jnp.repeat(k, h // kv, axis=2)
    vr = jnp.repeat(v, h // kv, axis=2)
    ref = attention_ref(
        jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd),
        jnp.moveaxis(kr, 2, 1).reshape(b * h, s, hd),
        jnp.moveaxis(vr, 2, 1).reshape(b * h, s, hd),
        causal=True,
    )
    ref = jnp.moveaxis(ref.reshape(b, h, s, hd), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_sliding_window_blocked_vs_ref():
    rng = np.random.default_rng(6)
    b, s, h, hd, win = 1, 90, 2, 8, 24
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    out = blocked_attention(q, k, v, causal=True, sliding_window=win, block_size=32)
    from repro.kernels.flash_attn.ref import attention_ref

    ref = attention_ref(
        jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd),
        jnp.moveaxis(k, 2, 1).reshape(b * h, s, hd),
        jnp.moveaxis(v, 2, 1).reshape(b * h, s, hd),
        causal=True,
        sliding_window=win,
    )
    ref = jnp.moveaxis(ref.reshape(b, h, s, hd), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_full():
    """Decode-with-cache at position t == full causal attention row t."""
    rng = np.random.default_rng(7)
    b, s, h, kv, hd = 2, 24, 4, 2, 8
    q_all = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k_all = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v_all = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    full = blocked_attention(q_all, k_all, v_all, causal=True, block_size=8)
    t = s - 1
    out = decode_attention(
        q_all[:, t : t + 1],
        k_all,
        v_all,
        cache_len=jnp.full((b,), t + 1, jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=1e-4, atol=1e-5
    )
