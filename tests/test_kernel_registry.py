"""The declarative KernelSpec registry + direction-optimizing traversal +
whole-run batched trace emission.

Covers: registry metadata/lookup/duplicate errors, the push-vs-pull value
property (both traversal directions compute the same kernel values on
randomized graphs), batched-vs-reference emission bit-identity across all
kernels x directions, the converged-stop (done-flag) iteration counts, the
vectorized ``TraceConfig.addr`` lookup tables, and the direction variants
(``bfs_do``, ``pgd_pull``) running end-to-end through ``Experiment`` and
the stream protocol.
"""
import numpy as np
import pytest

from repro.apps import (
    bellman_ford,
    bfs,
    connected_components,
    get_kernel,
    kernel_traits,
    list_kernels,
    pagerank_delta,
    register_kernel,
    register_kernel_variant,
)
from repro.apps.registry import (
    DuplicateKernelError,
    KernelSpec,
    UnknownKernelError,
)
from repro.apps.trace import (
    ARRAYS,
    NI_ID,
    P_ID,
    TraceConfig,
    current_emitter,
    set_emitter,
    trace_run,
    use_emitter,
)
from repro.graphs import from_edges, make_dataset

ALL_KERNELS = ("pgd", "cc", "bfs", "bellmanford")
VARIANTS = ("bfs_do", "pgd_pull")


def _random_graph(seed, n=120, m=500, weighted=False):
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 9, m).astype(np.float32) if weighted else None
    return from_edges(
        rng.integers(0, n, m), rng.integers(0, n, m), n, weights=w
    )


# ---------------------------------------------------------------- registry


def test_kernel_registry_metadata():
    names = set(list_kernels())
    assert set(ALL_KERNELS) | set(VARIANTS) <= names
    assert get_kernel("bellmanford").weighted
    assert not get_kernel("bfs").weighted
    for k in ("bfs", "bellmanford", "bfs_do"):
        spec = get_kernel(k)
        assert spec.two_run and spec.needs_root
        assert spec.epoch_protocol == "per_run"
    for k in ("pgd", "cc", "pgd_pull"):
        spec = get_kernel(k)
        assert not spec.two_run
        assert spec.epoch_protocol == "per_iteration"
    # variants share the base implementation, differ in direction
    assert get_kernel("bfs_do").fn is get_kernel("bfs").fn
    assert get_kernel("bfs_do").direction == "auto"
    assert get_kernel("pgd_pull").fn is get_kernel("pgd").fn
    assert get_kernel("pgd_pull").direction == "pull"
    assert get_kernel("pgd").direction == "push"


def test_kernel_registry_errors():
    with pytest.raises(DuplicateKernelError, match="already registered"):

        @register_kernel("pgd")
        def other(graph):
            raise NotImplementedError

    with pytest.raises(UnknownKernelError, match="pgd"):
        get_kernel("does-not-exist")
    with pytest.raises(DuplicateKernelError):
        register_kernel_variant("cc", base="pgd", direction="pull")
    # spec-level validation
    with pytest.raises(ValueError, match="direction"):
        KernelSpec(name="x", fn=lambda g: None, directions=("push",), direction="pull")
    with pytest.raises(ValueError, match="epoch_protocol"):
        KernelSpec(name="x", fn=lambda g: None, epoch_protocol="sometimes")


def test_kernel_traits_default_for_adhoc_names():
    t = kernel_traits("my-custom-runs")
    assert not t.two_run and not t.weighted and t.direction == "push"


# ------------------------------------------- push == pull value property


def test_push_pull_value_parity_randomized():
    """Both traversal directions compute the same kernel values: min-based
    kernels exactly, PGD up to float summation order."""
    for seed in (0, 1, 2):
        g = _random_graph(seed)
        gw = _random_graph(seed, weighted=True)
        root = int(np.argmax(g.degrees))
        wroot = int(np.argmax(gw.degrees))
        np.testing.assert_array_equal(
            connected_components(g, direction="push").values,
            connected_components(g, direction="pull").values,
        )
        np.testing.assert_array_equal(
            bfs(g, root=root, direction="push").values,
            bfs(g, root=root, direction="pull").values,
        )
        np.testing.assert_array_equal(
            bellman_ford(gw, root=wroot, direction="push").values,
            bellman_ford(gw, root=wroot, direction="pull").values,
        )
        np.testing.assert_allclose(
            pagerank_delta(g, direction="push").values,
            pagerank_delta(g, direction="pull").values,
            rtol=1e-4,
            atol=1e-7,
        )


def test_direction_optimizing_bfs_matches_push():
    """bfs_do switches direction mid-run but parents are identical (min-id
    offer wins in every direction), and it genuinely goes dense."""
    g = make_dataset("tiny")
    root = int(np.argmax(g.degrees))
    push = bfs(g, root=root, direction="push")
    do = bfs(g, root=root, direction="auto")
    np.testing.assert_array_equal(push.values, do.values)
    assert [len(f) for f in push.frontiers] == [len(f) for f in do.frontiers]
    assert "pull" in do.directions and "push" in do.directions
    assert do.stats["dense_iters"] == do.directions.count("pull")
    assert set(push.directions) == {"push"}


# ------------------------------ batched emission == per-iteration oracle


def test_batched_emission_bit_identical_all_kernels_and_directions():
    g = make_dataset("tiny")
    fields = ("array_id", "elem", "addr", "block", "src_vertex", "iter_bounds")
    for name in ALL_KERNELS + VARIANTS:
        ks = get_kernel(name)
        gg = make_dataset("tiny", weighted=ks.weighted)
        for direction in ks.directions:
            run = ks.run(gg, direction=direction)
            cfg = TraceConfig(gg.num_vertices, gg.num_edges)
            assert current_emitter() == "batched"
            batched = trace_run(run, cfg)
            with use_emitter("reference"):
                ref = trace_run(run, cfg)
            for f in fields:
                np.testing.assert_array_equal(
                    getattr(batched, f),
                    getattr(ref, f),
                    err_msg=f"{name}/{direction}.{f}",
                )
            assert batched.directions == ref.directions == run.directions
            # per-iteration views slice back out of the flat arrays
            for i in (0, batched.num_iters - 1):
                it = batched.iteration(i)
                assert len(it) == batched.iter_sizes[i]


def test_emitter_selection_plumbing():
    assert current_emitter() == "batched"
    with use_emitter("reference"):
        assert current_emitter() == "reference"
    assert current_emitter() == "batched"
    set_emitter("reference")
    try:
        assert current_emitter() == "reference"
    finally:
        set_emitter(None)
    with pytest.raises(ValueError, match="unknown trace emitter"):
        set_emitter("fast")


def test_pull_trace_structure():
    """A dense iteration: n-long frontier scan, then per-destination
    T,V + interleaved in-edge/source-property reads."""
    g = _random_graph(7)
    run = pagerank_delta(g, direction="pull", max_iters=2)
    cfg = TraceConfig(g.num_vertices, g.num_edges)
    rt = trace_run(run, cfg)
    it = rt.iteration(0)
    n, m = g.num_vertices, g.num_edges
    assert len(it) == 3 * n + 2 * m
    from repro.apps.trace import F_ID, N_ID, T_ID, V_ID

    # dense frontier scan is sequential over all vertices
    np.testing.assert_array_equal(it.array_id[:n], np.full(n, F_ID))
    np.testing.assert_array_equal(it.elem[:n], np.arange(n))
    assert (it.array_id == T_ID).sum() == n
    assert (it.array_id == V_ID).sum() == n
    assert (it.array_id == NI_ID).sum() == m
    assert (it.array_id == P_ID).sum() == m
    assert (it.array_id == N_ID).sum() == 0  # pull never touches out-edges
    # in-edge reads appear in sequential CSC order
    ni = it.elem[it.array_id == NI_ID]
    np.testing.assert_array_equal(ni, np.arange(m))
    # P reads gather the in-edge *sources*
    t = g.transpose()
    np.testing.assert_array_equal(
        it.elem[it.array_id == P_ID], t.neighbors.astype(np.int64)
    )


# ---------------------------------------------------- TraceConfig layout


def test_addr_lut_matches_per_array_loop():
    cfg = TraceConfig(num_vertices=1000, num_edges=5000)
    rng = np.random.default_rng(0)
    array_id = rng.integers(0, len(ARRAYS), 5000).astype(np.int8)
    elem = rng.integers(0, 1000, 5000).astype(np.int64)
    # the per-array loop this satellite vectorized away
    ref = np.zeros(len(elem), dtype=np.int64)
    for aid, (_, esz) in ARRAYS.items():
        base, _ = cfg.region(aid)
        sel = array_id == aid
        ref[sel] = base + elem[sel].astype(np.int64) * esz
    got = cfg.addr(array_id, elem)
    assert got.dtype == np.int64
    np.testing.assert_array_equal(got, ref)


def test_ni_region_appended_after_push_arrays():
    """Appending NI preserved every push-array address; input_bytes stays
    the paper's V+N+P+F+T footprint (NI is runtime-derived)."""
    cfg = TraceConfig(num_vertices=1000, num_edges=5000)
    regions = [cfg.region(a) for a in sorted(ARRAYS)]
    for (b0, s0), (b1, _) in zip(regions, regions[1:]):
        assert b0 + s0 <= b1  # disjoint, id-ordered
    ni_base, ni_size = cfg.region(NI_ID)
    p_base, p_size = cfg.region(P_ID)
    assert ni_base > p_base + p_size
    assert cfg.input_bytes == sum(cfg.region(a)[1] for a in range(NI_ID))


# -------------------------------------------- converged-stop (done flag)


def test_iteration_counts_unchanged_by_converged_stop():
    """The done-flag branch now breaks instead of evaluating an extra
    host-side step; iteration counts for the four paper kernels must match
    the pre-fix values (recorded on this commit's parent)."""
    expected = {"pgd": 11, "cc": 12, "bfs": 12, "bellmanford": 15}
    for name, want in expected.items():
        ks = get_kernel(name)
        g = make_dataset("comdblp", weighted=ks.weighted)
        run = ks.run(g)
        assert run.num_iters == want, name
        assert len(run.frontiers) == run.num_iters


# ------------------------------------------------- end-to-end scenarios


def test_direction_variants_run_through_experiment():
    from repro.core import Experiment

    res = Experiment(
        kernels=["bfs_do", "pgd_pull"],
        datasets=["tiny"],
        prefetchers=["nextline2", "rnr"],
    ).run()
    assert len(res.cells) == 4
    for cell in res.cells:
        assert np.isfinite(cell.metrics.speedup)
    w = res.workload("bfs_do", "tiny")
    # the trace really contains pull-mode accesses
    assert (w.array_id == NI_ID).any()
    assert w.eval_from_pos > 0  # two-run protocol inherited from bfs


def test_direction_kernel_artifact_keys_distinct(tmp_path):
    """bfs and bfs_do must never collide in the artifact cache; push
    kernels keep their pre-registry key material."""
    import json

    from repro.core import WorkloadSpec
    from repro.core.exec.artifacts import ArtifactCache

    cache = ArtifactCache(tmp_path)
    k_bfs = cache.key(WorkloadSpec("bfs", "tiny"))
    k_do = cache.key(WorkloadSpec("bfs_do", "tiny"))
    assert k_bfs != k_do
    assert "direction" not in json.loads(k_bfs)
    assert json.loads(k_do)["direction"] == "auto"


def test_direction_variant_runs_through_stream_protocol():
    from repro.core.registry import resolve_prefetchers
    from repro.stream import SlidingWindow, StreamSpec
    from repro.stream.protocol import run_stream

    spec = StreamSpec("bfs_do", "tiny", SlidingWindow(), epochs=2)
    result = run_stream(spec, resolve_prefetchers(["nextline2"]))
    assert len(result.cells) == 2
    for c in result.cells:
        assert np.isfinite(c.metrics.speedup)
