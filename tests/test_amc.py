"""AMC prefetcher core: recording semantics (paper Table IV), role swap,
BaseΔ compression, capacity, programming API, and an end-to-end run."""
import numpy as np
import pytest

from repro.core.amc.api import AMCSession
from repro.core.amc.compression import (
    CompressionStats,
    basedelta_compress,
    basedelta_decompress,
    compressed_entry_bytes,
    select_modes,
)
from repro.core.amc.prefetcher import AMCConfig, AMCPrefetcher, IterationView
from repro.core.amc.storage import AMCStorage

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st


def make_view(it, within, tpos, tvid, mpos, mblocks):
    return IterationView(
        iteration=it,
        within_epoch=within,
        target_pos=np.asarray(tpos, np.int64),
        target_vid=np.asarray(tvid, np.int64),
        miss_pos=np.asarray(mpos, np.int64),
        miss_blocks=np.asarray(mblocks, np.int64),
    )


def test_recording_groups_misses_by_target_pairs():
    """The Table IV structure: misses between two consecutive target
    accesses form one entry keyed by (prev, cur) target."""
    amc = AMCPrefetcher()
    storage = AMCStorage(10**9)
    # targets: V1@0, V2@10, V3@20; misses tagged by preceding target
    view = make_view(
        0, 0,
        tpos=[0, 10, 20],
        tvid=[1, 2, 3],
        mpos=[1, 2, 3, 11, 25, 26],
        mblocks=[100, 101, 102, 200, 300, 301],
    )
    amc._record(view, storage, CompressionStats())
    t = storage.recording[0]
    assert t.num_entries == 3
    np.testing.assert_array_equal(t.trigger_vid, [1, 2, 3])
    np.testing.assert_array_equal(t.prev_vid, [-1, 1, 2])
    np.testing.assert_array_equal(t.nmiss, [3, 1, 2])
    np.testing.assert_array_equal(t.miss_blocks, [100, 101, 102, 200, 300, 301])


def test_entry_split_at_20_misses():
    amc = AMCPrefetcher()
    storage = AMCStorage(10**9)
    view = make_view(
        0, 0, [0], [5], np.arange(1, 48), 1000 + np.arange(47)
    )
    amc._record(view, storage, CompressionStats())
    t = storage.recording[0]
    assert t.num_entries == 3  # 20 + 20 + 7
    np.testing.assert_array_equal(t.nmiss, [20, 20, 7])
    assert (t.trigger_vid == 5).all()


def test_role_swap_and_replay():
    cfg = AMCConfig(lookahead_accesses=4)
    amc = AMCPrefetcher(cfg)
    storage = AMCStorage(10**9)
    v0 = make_view(0, 0, [0, 10, 20], [1, 2, 3], [1, 11, 21], [100, 200, 300])
    amc._record(v0, storage, CompressionStats())
    storage.swap()  # AMC.update()
    # iteration 1: vertex 2 dropped out (evolving frontier)
    v1 = make_view(1, 0, [0, 10], [1, 3], [], [])
    out = amc._prefetch(v1, storage.lookup(0), storage)
    assert out is not None
    blocks, pos = out
    np.testing.assert_array_equal(np.sort(blocks), [100, 300])  # no 200
    # issue positions precede the matching targets (lookahead)
    assert (pos <= np.array([0, 10])).all()


def test_capacity_cap_drops_tail():
    storage = AMCStorage(capacity_bytes=200)
    amc = AMCPrefetcher()
    view = make_view(
        0, 0, np.arange(0, 500, 10), np.arange(50),
        np.arange(1, 500, 10), 1000 + np.arange(50),
    )
    amc._record(view, storage, CompressionStats())
    t = storage.recording[0]
    assert t.truncated
    assert storage.dropped_entries > 0
    assert t.total_bytes <= 200


@given(
    st.lists(
        st.integers(0, 2**40), min_size=1, max_size=20
    )
)
@settings(max_examples=50, deadline=None)
def test_basedelta_roundtrip(blocks):
    blocks = np.asarray(blocks, np.int64)
    mode, packed = basedelta_compress(blocks)
    rec = basedelta_decompress(packed)
    np.testing.assert_array_equal(rec, blocks)
    assert len(packed) <= compressed_entry_bytes(mode, len(blocks)) + 1


def test_select_modes_vectorized_matches_scalar():
    rng = np.random.default_rng(0)
    entries = [
        rng.integers(0, 2**30, rng.integers(1, 21)) for _ in range(40)
    ]
    blocks = np.concatenate(entries)
    seg = np.repeat(np.arange(40), [len(e) for e in entries])
    mode, nmiss, bits = select_modes(blocks, seg, 40)
    for i, e in enumerate(entries):
        m_scalar, _ = basedelta_compress(e)
        assert mode[i] == m_scalar, i
        assert nmiss[i] == len(e)


def test_compression_ratio_regime():
    """2-byte-delta-dominated entries compress ~2.5x (paper §V-B)."""
    rng = np.random.default_rng(1)
    base = rng.integers(0, 2**30, 100)
    blocks = np.concatenate(
        [b + rng.integers(-5000, 5000, 20) for b in base]
    )
    seg = np.repeat(np.arange(100), 20)
    stats = CompressionStats()
    stats.add(*select_modes(blocks, seg, 100))
    assert 2.0 < stats.ratio < 3.2
    assert stats.mode_counts[1] > 80  # 2-byte dominant


def test_amc_session_api():
    s = AMCSession()
    s.init(asid=3)
    s.addr_t_base(0x1000, 800, elem_size=8)
    s.addr_f_base(0x4000, 100, elem_size=1)
    assert s.configured
    assert s.in_target_range(0x1000) and not s.in_target_range(0x1321)
    # §V-C2 address calculation
    assert s.address_calculation(0x4005) == 0x1000 + 5 * 8
    s.update()
    assert s.regs.prefetch_phase and s.iteration == 1
    s.end()
    assert not s.active


def test_amc_session_rejects_non_divisible_elem_sizes():
    """§V-C2 scales by target_elem_size // frontier_elem_size; a
    non-divisible pair would silently truncate — must raise instead."""
    s = AMCSession()
    s.init()
    s.addr_t_base(0x1000, 800, elem_size=6)
    with pytest.raises(ValueError, match="integer multiple"):
        s.addr_f_base(0x4000, 100, elem_size=4)
    # the rejected call must not half-commit the frontier registers
    assert s.regs.frontier_base is None and s.regs.frontier_elem_size == 1
    # same check regardless of declaration order
    s.init()
    s.addr_f_base(0x4000, 100, elem_size=4)
    with pytest.raises(ValueError, match="integer multiple"):
        s.addr_t_base(0x1000, 800, elem_size=6)
    # divisible sizes pass and compute the scaled address
    s.init()
    s.addr_f_base(0x4000, 100, elem_size=4)
    s.addr_t_base(0x1000, 800, elem_size=8)
    assert s.address_calculation(0x4004) == 0x1000 + 4 * 2
    # elem_size=0 is rejected up front, not as ZeroDivisionError later
    s.init()
    with pytest.raises(ValueError, match=">= 1"):
        s.addr_f_base(0x4000, 100, elem_size=0)


@pytest.mark.slow
def test_amc_end_to_end_beats_baselines():
    from repro.core import build_workload, get_prefetcher
    from repro.core.experiment import score_prefetcher

    w = build_workload("pgd", "comdblp")
    amc = score_prefetcher(w, "amc", AMCPrefetcher(AMCConfig()).generate)
    vldp = score_prefetcher(w, "vldp", get_prefetcher("vldp").instantiate())
    rnr = score_prefetcher(w, "rnr", get_prefetcher("rnr").instantiate())
    assert amc.accuracy > 0.45
    assert amc.coverage > 0.3
    assert amc.speedup > 1.1
    # the paper's ordering
    assert amc.coverage > vldp.coverage
    assert amc.speedup > rnr.speedup
    # metadata stays bounded
    assert amc.info["storage_peak_bytes"] < 0.6 * w.input_bytes
