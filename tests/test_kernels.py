"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — TPU is the target, CPU executes the kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st

from repro.kernels.amc_gather.amc_gather import (
    amc_gather,
    amc_gather_segment_sum,
)
from repro.kernels.amc_gather.ref import gather_ref, gather_segment_sum_ref
from repro.kernels.basedelta.basedelta import (
    basedelta_compress_tiles,
    basedelta_decompress_tiles,
)
from repro.kernels.basedelta.ops import roundtrip
from repro.kernels.basedelta.ref import compress_ref, decompress_ref
from repro.kernels.cache_sim.cache_sim import lru_hits
from repro.kernels.cache_sim.ops import cache_pass_pallas
from repro.kernels.cache_sim.ref import lru_hits_ref
from repro.kernels.flash_attn.ops import mha
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_naive
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


# --------------------------- flash_attn ---------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,hd,causal,win",
    [
        (2, 256, 4, 2, 64, True, 0),
        (1, 384, 2, 2, 128, True, 128),
        (2, 200, 4, 4, 64, False, 0),
        (1, 130, 2, 1, 64, True, 0),  # ragged tail block
    ],
)
def test_flash_attn_vs_oracle(b, s, h, kv, hd, causal, win, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd), dtype)
    out = mha(q, k, v, causal=causal, sliding_window=win, interpret=True)
    groups = h // kv
    kr = jnp.repeat(k, groups, axis=2)
    vr = jnp.repeat(v, groups, axis=2)
    ref = attention_ref(
        jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd),
        jnp.moveaxis(kr, 2, 1).reshape(b * h, s, hd),
        jnp.moveaxis(vr, 2, 1).reshape(b * h, s, hd),
        causal=causal,
        sliding_window=win,
    )
    ref = jnp.moveaxis(ref.reshape(b, h, s, hd), 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# --------------------------- amc_gather ---------------------------


@given(
    v=st.integers(8, 128),
    d=st.sampled_from([8, 128]),
    n=st.integers(1, 64),
    seed=st.integers(0, 20),
)
@settings(max_examples=12, deadline=None)
def test_amc_gather_vs_oracle(v, d, n, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, n), jnp.int32)
    out = amc_gather(table, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(table, idx)))


def test_amc_gather_segment_sum_vs_oracle():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    n, nseg = 50, 8
    idx = jnp.asarray(rng.integers(0, 64, n), jnp.int32)
    # every segment non-empty (kernel writes only flushed segments)
    segs = np.sort(np.concatenate([np.arange(nseg), rng.integers(0, nseg, n - nseg)]))
    segs = jnp.asarray(segs, jnp.int32)
    out = amc_gather_segment_sum(table, idx, segs, nseg, interpret=True)
    ref = gather_segment_sum_ref(table, idx, segs, nseg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_amc_gather_session_replay():
    from repro.kernels.amc_gather.ops import AMCGatherSession

    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    idx1 = rng.integers(0, 32, 20)
    idx2 = idx1.copy()
    idx2[[3, 7]] = (idx2[[3, 7]] + 5) % 32  # 10% churn, like the graphs
    sess = AMCGatherSession(interpret=True)
    sess.gather(table, jnp.asarray(idx1, jnp.int32))
    sess.update()
    out2 = sess.gather(table, jnp.asarray(idx2, jnp.int32))
    np.testing.assert_allclose(np.asarray(out2), np.asarray(table[idx2]), rtol=1e-6)
    assert sess.stats["replayed"] == 1


# --------------------------- basedelta ---------------------------


@given(
    e=st.integers(1, 30),
    width=st.sampled_from([8, 32]),
    spread=st.sampled_from([50, 5000, 10**6]),
    seed=st.integers(0, 30),
)
@settings(max_examples=15, deadline=None)
def test_basedelta_tiles_vs_ref(e, width, spread, seed):
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, width + 1, e).astype(np.int32)
    tiles = np.zeros((e, width), np.int32)
    for i in range(e):
        base = rng.integers(0, 2**24)
        tiles[i, : counts[i]] = base + rng.integers(-spread, spread, counts[i])
    d_k, m_k = basedelta_compress_tiles(
        jnp.asarray(tiles), jnp.asarray(counts), interpret=True
    )
    d_r, m_r = compress_ref(jnp.asarray(tiles), jnp.asarray(counts))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
    # decompress roundtrip
    rec = basedelta_decompress_tiles(
        jnp.asarray(tiles[:, 0]), d_k, interpret=True
    )
    ref = decompress_ref(jnp.asarray(tiles[:, 0]), d_r)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(ref))


def test_basedelta_ragged_roundtrip():
    rng = np.random.default_rng(2)
    mb = rng.integers(1 << 20, (1 << 20) + 4000, 300).astype(np.int64)
    # AMC invariant: entries are split at <=20 misses (paper Fig 16)
    sizes = rng.integers(1, 21, 40)
    off = np.concatenate([[0], np.cumsum(sizes)])
    off = off[off <= 300]
    if off[-1] != 300:
        off = np.append(off, 300)
    rec = roundtrip(mb, off)
    np.testing.assert_array_equal(rec, mb)


def test_pack_ragged_rejects_oversized_entries():
    with pytest.raises(AssertionError):
        roundtrip(np.arange(100, dtype=np.int64), np.array([0, 50, 100]))


# --------------------------- cache_sim ---------------------------


@given(
    sets=st.sampled_from([2, 8]),
    ways=st.sampled_from([1, 2, 4]),
    n=st.integers(1, 200),
    span=st.integers(1, 60),
    seed=st.integers(0, 20),
)
@settings(max_examples=12, deadline=None)
def test_cache_sim_kernel_vs_oracle(sets, ways, n, span, seed):
    from repro.memsim.engine import group_by_set

    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, span, n).astype(np.int64)
    padded, _, _, _ = group_by_set(blocks, sets)
    mat = np.ascontiguousarray(padded.T)  # (sets, L)
    got = np.asarray(lru_hits(jnp.asarray(mat), ways, set_tile=sets, interpret=True))
    ref = lru_hits_ref(mat, ways)
    real = mat >= 0  # oracle skips tail pads; the kernel runs over them
    np.testing.assert_array_equal(got[real], ref[real])


def test_cache_sim_full_stream_matches_reference_engine():
    from repro.memsim.scan_cache import cache_pass as cache_pass_reference

    rng = np.random.default_rng(5)
    blocks = rng.integers(0, 700, 2_000).astype(np.int64)
    out = cache_pass_pallas(blocks, 16, 4, set_tile=4, interpret=True)
    np.testing.assert_array_equal(out, cache_pass_reference(blocks, 16, 4))


# --------------------------- ssd_scan ---------------------------


@given(
    s=st.integers(8, 120),
    p=st.sampled_from([8, 32]),
    n=st.sampled_from([4, 16]),
    chunk=st.sampled_from([16, 32]),
    seed=st.integers(0, 20),
)
@settings(max_examples=10, deadline=None)
def test_ssd_kernel_vs_naive(s, p, n, chunk, seed):
    rng = np.random.default_rng(seed)
    bh = 2
    x = jnp.asarray(rng.normal(size=(bh, s, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (bh, s)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.3, 2.0, bh), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    out = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    ref = ssd_naive(np.asarray(x), np.asarray(dt), np.asarray(a), np.asarray(b), np.asarray(c))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)
