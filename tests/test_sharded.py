"""Sharded paper-scale scoring: split-at-any-boundary bit-exactness.

The streaming path's contract is that chopping a trace at ANY boundary —
including empty and single-access shards — changes nothing: carried
cache state resumes every engine bit-identically, chunked emission
concatenates to the whole-run trace, the streaming metric primitives
(spilled MLP, chained classification, the composite scorer) reproduce
their whole-trace counterparts exactly, and ``score_sharded`` returns
the same metric rows as the unsharded ``score_prefetcher`` path, both
standalone and through the Experiment scheduler.
"""

import dataclasses
import tempfile

import numpy as np
import pytest

from repro.apps import get_kernel
from repro.apps.trace import TraceConfig, iter_run_trace_chunks, trace_run
from repro.core import (
    ArtifactCache,
    Experiment,
    WorkloadCache,
    WorkloadSpec,
    score_prefetcher,
)
from repro.core.exec.scheduler import rows_equal
from repro.core.exec.sharded import (
    ShardedScoringError,
    ShardedSpec,
    score_sharded,
)
from repro.core.registry import resolve_prefetchers
from repro.graphs import make_dataset
from repro.memsim import simulate_demand, use_engine
from repro.memsim.config import SCALED
from repro.memsim.engine import ENGINES, cache_pass
from repro.memsim.hierarchy import simulate_with_prefetch
from repro.memsim.metrics import _outcome_cycles
from repro.memsim.streaming import (
    BlockPosTable,
    ClassifyCarry,
    CompositeRunScorer,
    SpillFile,
    classify_chunk,
    spilled_mlp,
)
from repro.memsim.timing import TimingModel, measure_mlp


def _boundaries(rng, n, n_cuts):
    """Chunk boundaries over [0, n] with empty and size-1 chunks forced.

    Returned sorted but NOT deduplicated: a repeated cut is an empty
    chunk, and the forced ``mid, mid, mid + 1`` triple yields both an
    empty and a single-access chunk.
    """
    cuts = rng.integers(0, n + 1, size=n_cuts)
    mid = int(rng.integers(0, n))
    extra = [mid, mid, min(mid + 1, n)]
    return np.sort(np.concatenate([[0], cuts, extra, [n]]))


# ------------------------------------------------------------ engine carry


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_cache_pass_carry_splits_at_any_boundary(engine):
    rng = np.random.default_rng(7)
    n, sets, ways = 3000, 16, 4
    blocks = rng.integers(0, 97, size=n).astype(np.int64) + (1 << 22)
    with use_engine(engine):
        whole, end = cache_pass(blocks, sets, ways, return_state=True)
        bounds = _boundaries(rng, n, 9)
        got, state = [], None
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            hits, state = cache_pass(
                blocks[lo:hi], sets, ways, state=state, return_state=True
            )
            got.append(hits)
    np.testing.assert_array_equal(np.concatenate(got), whole)
    np.testing.assert_array_equal(state.tags, end.tags)
    np.testing.assert_array_equal(state.age, end.age)


# ------------------------------------------------------- chunked emission


def test_chunked_emission_concatenates_to_whole_run():
    ks = get_kernel("bfs")
    g = make_dataset("tiny", weighted=ks.weighted)
    run = ks.run(g)
    cfg = TraceConfig(g.num_vertices, g.num_edges)
    whole = trace_run(run, cfg)
    for max_accesses in (1 << 12, 1 << 30):
        chunks = list(iter_run_trace_chunks(run, cfg, max_accesses))
        assert chunks[0][0] == 0
        if max_accesses == 1 << 30:
            assert len(chunks) == 1
        else:
            assert len(chunks) > 1
        for field in ("array_id", "elem", "addr", "block", "src_vertex"):
            np.testing.assert_array_equal(
                np.concatenate([getattr(t, field) for _, t in chunks]),
                getattr(whole, field),
            )
        sizes = np.concatenate([t.iter_sizes for _, t in chunks])
        np.testing.assert_array_equal(
            np.concatenate([[0], np.cumsum(sizes)]), whole.iter_bounds
        )


# ------------------------------------------------- streaming primitives


def test_spilled_mlp_matches_measure_mlp(tmp_path):
    rng = np.random.default_rng(3)
    for trial in range(8):
        n = int(rng.integers(0, 3000))
        pos = np.unique(rng.integers(0, 12000, size=n).astype(np.int64))
        window = int(rng.integers(1, 60))
        cap = float(rng.uniform(1.0, 8.0))
        sp = SpillFile(str(tmp_path / f"mlp{trial}.i64"), cols=1)
        i = 0
        while i < len(pos):
            step = int(rng.integers(0, 500))
            sp.append(pos[i : i + step])  # step == 0 is an empty append
            i += step if step else 1
        assert spilled_mlp(sp, window, cap, rows=257) == measure_mlp(
            pos, window, cap
        )
        sp.close()


def test_classify_chunk_chained_matches_single_call():
    rng = np.random.default_rng(11)
    for trial in range(10):
        n = int(rng.integers(2, 2500))
        blocks = rng.integers(0, 60, size=n).astype(np.int64) + (1 << 22)
        pos2 = np.cumsum(rng.integers(1, 3, size=n)).astype(np.int64)
        is_pf = rng.random(n) < 0.5
        issuer = rng.integers(0, 2, size=n).astype(np.int8)
        # A real LRU pass: classification assumes every per-block chain
        # segment starts at a fill, which random hit masks would violate.
        hit = cache_pass(blocks, 8, 2)
        fw2 = 2 * int(rng.integers(1, 40))
        t0 = int(rng.integers(0, int(pos2[-1] >> 1) + 1))

        single, _ = classify_chunk(
            ClassifyCarry.empty(), blocks, is_pf, pos2, hit, issuer, fw2, t0, 1
        )
        bounds = _boundaries(rng, n, 7)
        carry = ClassifyCarry.empty()
        total = None
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            counts, carry = classify_chunk(
                carry,
                blocks[lo:hi],
                is_pf[lo:hi],
                pos2[lo:hi],
                hit[lo:hi],
                issuer[lo:hi],
                fw2,
                t0,
                1,
            )
            if total is None:
                total = counts
            else:
                total = {k: total[k] + v for k, v in counts.items()}
        assert total == single, trial


def test_composite_scorer_chunked_matches_whole_trace(tmp_path):
    rng = np.random.default_rng(5)
    cfg, tm = SCALED, TimingModel()
    for trial in range(4):
        n = int(rng.integers(400, 4000))
        blocks = rng.integers(0, 150, size=n).astype(np.int64) + (1 << 22)
        iter_id = np.sort(rng.integers(0, 5, size=n)).astype(np.int32)
        profile = simulate_demand(blocks, iter_id, cfg)
        t0 = int(rng.integers(0, n))

        npf = int(rng.integers(0, 2 * len(profile.l2_pos) + 2))
        pf_pos = rng.integers(0, n, size=npf).astype(np.int64)
        pf_blocks = rng.integers(0, 150, size=npf).astype(np.int64) + (1 << 22)
        pf_issuer = rng.integers(0, 2, size=npf).astype(np.int8)
        # The sharded contract pre-sorts the prefetch stream globally
        # (stable), so per-chunk slices reproduce the whole-trace merge.
        o = np.argsort(pf_pos, kind="stable")
        pf_pos, pf_blocks, pf_issuer = pf_pos[o], pf_blocks[o], pf_issuer[o]

        outcome = simulate_with_prefetch(profile, pf_blocks, pf_pos, pf_issuer)
        base = profile.baseline_counts(t0)
        want_cycles, want_counts = _outcome_cycles(
            profile, outcome, t0, tm, base["dram"], 7.5, 3
        )

        table = BlockPosTable()
        for j in range(0, len(profile.l2_miss_blocks), 173):
            table.update(
                profile.l2_miss_blocks[j : j + 173],
                profile.l2_miss_pos[j : j + 173],
            )

        bounds = _boundaries(rng, n, 8)
        sc = CompositeRunScorer(
            cfg, t0, str(tmp_path), f"t{trial}", sel_issuer=1, no_future=table
        )
        for a0, a1 in zip(bounds[:-1], bounds[1:]):
            dlo, dhi = np.searchsorted(profile.l2_pos, [a0, a1])
            plo, phi = np.searchsorted(pf_pos, [a0, a1])
            sc.feed(
                profile.l2_pos[dlo:dhi],
                profile.l2_blocks[dlo:dhi],
                pf_blocks[plo:phi],
                pf_pos[plo:phi],
                pf_issuer[plo:phi],
            )
        got_cycles, got_counts = sc.finalize(base, base["dram"], 7.5, 3, tm)
        assert got_counts == want_counts, trial
        assert got_cycles == want_cycles, trial


def test_block_pos_table_sparse_span_falls_back():
    # Block ids spread past the dense-span cap demote to sorted rows and
    # keep answering identically.
    table = BlockPosTable()
    table.update(np.array([100, 200]), np.array([5, 9]))
    assert table._dense is not None
    table.update(np.array([100 + (1 << 30)]), np.array([12]))
    assert table._dense is None and len(table) == 3
    q = np.array([100, 200, 100 + (1 << 30), 77])
    np.testing.assert_array_equal(
        table.has_later(q, np.array([4, 9, 11, 0])),
        [True, False, True, False],
    )


# ------------------------------------------------------- sharded scoring


@pytest.mark.parametrize("kernel", ["bfs", "pgd"])
def test_score_sharded_matches_unsharded(kernel):
    base = WorkloadSpec(kernel, "tiny")
    trace = base.build()
    pairs = resolve_prefetchers(["nextline2", "amc"])
    un = [score_prefetcher(trace, n, g).row() for n, g in pairs]
    # 1 << 30 is the single-shard degenerate case; 4096 forces many seams.
    for shard_accesses in (4096, 1 << 30):
        with tempfile.TemporaryDirectory() as td:
            scored = score_sharded(
                ShardedSpec(base=base, shard_accesses=shard_accesses),
                pairs,
                ArtifactCache(td),
            )
        assert [n for n, _ in scored] == ["nextline2", "amc"]
        assert rows_equal(un, [m.row() for _, m in scored]), shard_accesses


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_score_sharded_matches_unsharded_per_engine(engine):
    base = WorkloadSpec("bfs", "tiny")
    pairs = resolve_prefetchers(["nextline2"])
    with use_engine(engine):
        trace = base.build()
        un = [score_prefetcher(trace, n, g).row() for n, g in pairs]
        with tempfile.TemporaryDirectory() as td:
            scored = score_sharded(
                ShardedSpec(base=base, shard_accesses=4096),
                pairs,
                ArtifactCache(td),
            )
    assert rows_equal(un, [m.row() for _, m in scored]), engine


def test_unsupported_prefetcher_raises():
    base = WorkloadSpec("bfs", "tiny")
    with tempfile.TemporaryDirectory() as td:
        with pytest.raises(ShardedScoringError, match="streaming adapter"):
            score_sharded(
                ShardedSpec(base=base, shard_accesses=4096),
                resolve_prefetchers(["rnr"]),
                ArtifactCache(td),
            )


def test_sharded_artifact_keys_move_with_shard_size(tmp_path):
    arts = ArtifactCache(tmp_path)
    base = WorkloadSpec("bfs", "tiny")
    a = ShardedSpec(base=base, shard_accesses=4096)
    b = ShardedSpec(base=base, shard_accesses=8192)
    c = dataclasses.replace(a)
    # Content-addressed: the manifest and every shard move when the spec
    # (including the shard size) changes, and only then.
    assert arts.path_for(a) != arts.path_for(b)
    assert arts.path_for(a) == arts.path_for(c)
    assert arts.shard_path(a, 0) != arts.shard_path(b, 0)
    assert arts.shard_path(a, 0) != arts.shard_path(a, 1)
    assert not arts.has(a)


def test_experiment_runs_sharded_specs_serial_and_parallel():
    base = WorkloadSpec("bfs", "tiny")
    workloads = [base, ShardedSpec(base=base, shard_accesses=1 << 12)]
    prefetchers = ["nextline2", "amc"]

    with tempfile.TemporaryDirectory() as td:
        serial = Experiment(
            workloads=workloads,
            prefetchers=prefetchers,
            cache=WorkloadCache(artifacts=ArtifactCache(td)),
        ).run(workers=1)
        rows_s = [c.metrics.row() for c in serial.cells]
        # The sharded cells must equal their unsharded twins in-run...
        assert rows_equal(rows_s[:2], rows_s[2:])
        assert len(serial.workloads) == 1  # lazy view skips sharded specs

    with tempfile.TemporaryDirectory() as td:
        par = Experiment(
            workloads=workloads,
            prefetchers=prefetchers,
            cache=WorkloadCache(artifacts=ArtifactCache(td)),
        ).run(workers=2)
        # ...and the scheduler path must equal serial bit-for-bit.
        assert rows_equal(rows_s, [c.metrics.row() for c in par.cells])
