"""Memory-hierarchy simulator: scan caches + chain classification vs
brute-force Python references (hypothesis property tests)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st

from repro.memsim import (
    SCALED,
    cache_pass,
    classify_prefetch_events,
    evaluate,
    simulate_demand,
    simulate_with_prefetch,
)


def _naive_cache(blocks, sets, ways):
    """Reference set-associative LRU cache."""
    state = [dict() for _ in range(sets)]  # set -> {block: last_use}
    t = 0
    hits = np.zeros(len(blocks), dtype=bool)
    for i, b in enumerate(blocks):
        s = int(b) & (sets - 1)
        d = state[s]
        t += 1
        if b in d:
            hits[i] = True
            d[b] = t
        else:
            if len(d) >= ways:
                lru = min(d, key=d.get)
                del d[lru]
            d[b] = t
    return hits


@given(
    n=st.integers(1, 400),
    span=st.integers(4, 200),
    sets=st.sampled_from([4, 8, 16]),
    ways=st.sampled_from([2, 4]),
    seed=st.integers(0, 100),
)
@settings(max_examples=30, deadline=None)
def test_cache_pass_matches_naive(n, span, sets, ways, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, span, n).astype(np.int64)
    got = cache_pass(blocks, sets, ways)
    ref = _naive_cache(blocks, sets, ways)
    np.testing.assert_array_equal(got, ref)


def _naive_pf_classify(blocks, is_pf, pos, hit, window):
    """Brute-force pf-bit machine over per-line state."""
    pf_bit, fill_pos, resident = {}, {}, {}
    useful = np.zeros(len(blocks), bool)
    late = np.zeros(len(blocks), bool)
    redundant = np.zeros(len(blocks), bool)
    for i, (b, f, p, h) in enumerate(zip(blocks, is_pf, pos, hit)):
        if h:
            if f:
                redundant[i] = True  # pf bit survives
            else:
                if pf_bit.get(b, False):
                    useful[i] = True
                    if fill_pos.get(b, -1) > p:
                        late[i] = True
                pf_bit[b] = False
        else:  # fill
            pf_bit[b] = bool(f)
            fill_pos[b] = p + window if f else 0
    return useful, late, redundant


@given(
    n=st.integers(1, 300),
    span=st.integers(2, 40),
    pf_frac=st.floats(0.1, 0.9),
    seed=st.integers(0, 50),
)
@settings(max_examples=30, deadline=None)
def test_classification_matches_bruteforce(n, span, pf_frac, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, span, n).astype(np.int64)
    is_pf = rng.random(n) < pf_frac
    pos = np.sort(rng.integers(0, 4 * n, n)).astype(np.int64)
    hit = cache_pass(blocks, 4, 2)
    useful, late, red, early = classify_prefetch_events(
        blocks, is_pf, pos, hit, window := 17
    )[:4]
    u2, l2, r2 = _naive_pf_classify(blocks, is_pf, pos, hit, window)
    np.testing.assert_array_equal(useful, u2)
    np.testing.assert_array_equal(late, l2)
    np.testing.assert_array_equal(red, r2)


@pytest.fixture(scope="module")
def profile():
    rng = np.random.default_rng(7)
    blocks = rng.integers(0, 3000, 30_000).astype(np.int64)
    iters = np.repeat(np.arange(3), 10_000).astype(np.int32)
    return simulate_demand(blocks, iters, SCALED)


def test_oracle_prefetcher_perfect(profile):
    mp, mb = profile.l2_miss_pos, profile.l2_miss_blocks
    out = simulate_with_prefetch(profile, mb, np.maximum(mp - 100, 0))
    m = evaluate("oracle", profile, out, baseline_outcome=_nopf(profile), issuer=0)
    assert m.accuracy > 0.95
    assert m.coverage > 0.9
    assert m.speedup > 1.0


def _nopf(profile):
    return simulate_with_prefetch(
        profile, np.zeros(0, np.int64), np.zeros(0, np.int64)
    )


def test_empty_prefetcher_neutral(profile):
    out = _nopf(profile)
    m = evaluate("none", profile, out, baseline_outcome=_nopf(profile), issuer=0)
    assert m.speedup == pytest.approx(1.0, abs=1e-6)
    assert m.issued == 0 and m.useful == 0


def test_garbage_prefetcher_hurts_traffic(profile):
    rng = np.random.default_rng(9)
    pf_b = rng.integers(10_000, 20_000, 5000).astype(np.int64)  # never demanded
    pf_p = np.sort(rng.integers(0, 30_000, 5000)).astype(np.int64)
    out = simulate_with_prefetch(profile, pf_b, pf_p)
    m = evaluate("garbage", profile, out, baseline_outcome=_nopf(profile), issuer=0)
    assert m.accuracy < 0.01
    assert m.extra_traffic > 0.0
    assert m.overpredicted > 4500
    assert m.speedup < 1.01


def test_eval_window_restricts_counts(profile):
    mp, mb = profile.l2_miss_pos, profile.l2_miss_blocks
    out = simulate_with_prefetch(profile, mb, np.maximum(mp - 100, 0))
    m_all = evaluate("o", profile, out, baseline_outcome=_nopf(profile), issuer=0)
    m_win = evaluate(
        "o", profile, out, baseline_outcome=_nopf(profile), eval_from_pos=20_000,
        issuer=0,
    )
    assert m_win.issued < m_all.issued
    assert m_win.baseline_l2_misses < m_all.baseline_l2_misses
