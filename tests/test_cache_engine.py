"""Set-parallel cache engine vs the serial ``lax.scan`` reference.

The engine contract is *bit identity*: every engine must produce the exact
hit mask of the reference scan, so ``TRACE_CODE_VERSION`` and all persisted
workload artifacts stay valid regardless of the active engine.  Covered
here: randomized streams x geometries (property test, including ways=1,
single-set, and repeated-block streams), degenerate inputs, engine
selection plumbing, and an end-to-end check that a small grid's
``ExperimentResult`` rows are byte-identical under both engines.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st

from repro.memsim import cache_pass, current_engine, set_engine, use_engine
from repro.memsim.engine import cache_pass_set_parallel, group_by_set
from repro.memsim.scan_cache import cache_pass as cache_pass_reference


@given(
    n=st.integers(1, 500),
    span=st.integers(1, 300),
    sets=st.sampled_from([1, 4, 16, 64]),
    ways=st.sampled_from([1, 2, 8]),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_engine_bit_identical_to_reference(n, span, sets, ways, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, span, n).astype(np.int64)
    if seed % 3 == 0:
        # repeated-block runs: same line touched many times back-to-back
        blocks = np.repeat(blocks, rng.integers(1, 4, n))[: max(n, 1)]
    ref = cache_pass_reference(blocks, sets, ways)
    got = cache_pass_set_parallel(blocks, sets, ways)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("engine", ["set_parallel", "pallas"])
def test_engine_edge_geometries(engine):
    rng = np.random.default_rng(0)
    cases = [
        (np.zeros(0, np.int64), 16, 8),  # empty stream
        (np.zeros(1, np.int64), 1, 1),  # single access, degenerate cache
        (np.full(50, 7, np.int64), 4, 1),  # one block repeated, direct-mapped
        (rng.integers(0, 9, 300).astype(np.int64), 1, 4),  # single set
        (np.arange(64, dtype=np.int64), 8, 2),  # all cold misses
    ]
    for blocks, sets, ways in cases:
        ref = cache_pass_reference(blocks, sets, ways)
        with use_engine(engine):
            got = cache_pass(blocks, sets, ways)
        np.testing.assert_array_equal(got, ref, err_msg=f"{engine} {sets}x{ways}")


def test_engine_selection_plumbing(monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_ENGINE", raising=False)
    assert current_engine() == "fused"  # the default
    with use_engine("reference"):
        assert current_engine() == "reference"
        with use_engine("pallas"):
            assert current_engine() == "pallas"
        assert current_engine() == "reference"
    assert current_engine() == "fused"
    monkeypatch.setenv("REPRO_CACHE_ENGINE", "reference")
    assert current_engine() == "reference"
    set_engine("fused")  # explicit override beats the env var
    assert current_engine() == "fused"
    set_engine(None)
    assert current_engine() == "reference"
    monkeypatch.setenv("REPRO_CACHE_ENGINE", "bogus")
    with pytest.raises(ValueError, match="unknown cache engine"):
        current_engine()
    with pytest.raises(ValueError, match="unknown cache engine"):
        set_engine("bogus")


def test_set_skewed_stream_falls_back_and_stays_identical():
    """A stream concentrated in one set at a large-sets geometry would pad
    to a max_len x sets matrix far larger than the stream; the engine must
    route it to the serial reference (bit-identical either way) instead of
    paying — or failing — that allocation."""
    rng = np.random.default_rng(2)
    sets = 4096
    blocks = (rng.integers(0, 500, 2_000) * sets).astype(np.int64)  # one set
    ref = cache_pass_reference(blocks, sets, 8)
    got = cache_pass_set_parallel(blocks, sets, 8)
    np.testing.assert_array_equal(got, ref)


def test_group_by_set_partition_roundtrip():
    rng = np.random.default_rng(1)
    blocks = rng.integers(0, 10_000, 5_000).astype(np.int64)
    sets = 32
    padded, order, col, row = group_by_set(blocks, sets)
    # every real access lands in its set's column, in stream order
    assert padded.shape[1] == sets and padded.shape[0] >= 1
    back = np.empty(len(blocks), dtype=np.int64)
    back[order] = padded[col, row]
    np.testing.assert_array_equal(back, blocks.astype(np.int32))
    np.testing.assert_array_equal(row, (blocks & (sets - 1))[order])
    # pads are tail-only: each column's real prefix length == its set count
    counts = np.bincount(blocks & (sets - 1), minlength=sets)
    real = padded >= 0
    np.testing.assert_array_equal(real.sum(axis=0), counts)
    np.testing.assert_array_equal(
        real, np.arange(padded.shape[0])[:, None] < counts[None, :]
    )


def test_experiment_rows_byte_identical_across_engines():
    """End-to-end: a small grid's result rows match bit-for-bit whether the
    demand profiles and prefetch simulations run on the set-parallel engine
    or the serial reference."""
    from repro.core import Experiment, WorkloadSpec
    from repro.core.exec.scheduler import rows_equal

    specs = [WorkloadSpec("pgd", "comdblp")]
    prefetchers = ["rnr", "nextline2"]
    with use_engine("set_parallel"):
        rows_eng = Experiment(workloads=specs, prefetchers=prefetchers).run().rows()
    with use_engine("reference"):
        rows_ref = Experiment(workloads=specs, prefetchers=prefetchers).run().rows()
    assert rows_equal(rows_eng, rows_ref)
