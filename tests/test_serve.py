"""The multi-tenant serving subsystem: deterministic interleaver, shared
LLC, per-tenant vs shared AMC tables, and the serving protocol on the
Experiment engine.

Covers the subsystem's contracts: interleave -> deinterleave is a
bit-exact roundtrip for any (lengths, rates, policy); the shared-LLC pass
is the identity at K=1 and can only *lose* hits under contention (LRU
stack distance grows monotonically when foreign accesses are inserted);
K=1 serving rows are byte-identical to the single-tenant grid path (the
acceptance anchor); shared tables degrade vs per-tenant provisioning with
the aliasing/thrash counters attached; and a serving scenario's serial and
``workers=2`` runs are byte-identical.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st

from repro.core import ArtifactCache, Experiment, WorkloadCache, WorkloadSpec
from repro.core.exec.scheduler import rows_equal
from repro.memsim import cache_pass
from repro.memsim.shared_llc import shared_llc_pass, tenant_shift
from repro.serve import (
    ServeCell,
    ServeSpec,
    TenantSpec,
    contention_payload,
    deinterleave,
    interleave,
)

TINY = "tiny"


# ------------------------------------------------------------ interleaver


def test_round_robin_alternates():
    il = interleave([3, 3, 3])
    np.testing.assert_array_equal(il.tenant_of, np.tile([0, 1, 2], 3))


def test_round_robin_unequal_lengths_drain():
    # The shorter tenant drains; the longer one keeps its tail slots.
    il = interleave([4, 2])
    np.testing.assert_array_equal(il.tenant_of, [0, 1, 0, 1, 0, 0])


def test_rate_policy_weights_slots():
    # rate 2:1 -> two tenant-0 accesses per tenant-1 access (AAB pattern).
    il = interleave([4, 2], rates=[2.0, 1.0], policy="rate")
    np.testing.assert_array_equal(il.tenant_of, [0, 0, 1, 0, 0, 1])


def test_interleave_validation():
    with pytest.raises(ValueError, match="unknown interleave policy"):
        interleave([3], policy="random")
    with pytest.raises(ValueError, match="must match"):
        interleave([3, 3], rates=[1.0], policy="rate")
    with pytest.raises(ValueError, match="positive"):
        interleave([3, 3], rates=[1.0, -2.0], policy="rate")
    with pytest.raises(ValueError, match="at least one tenant"):
        interleave([])


@settings(max_examples=30, deadline=None)
@given(
    k=st.integers(1, 4),
    seed=st.integers(0, 200),
    policy=st.sampled_from(["round_robin", "rate"]),
)
def test_interleave_deinterleave_roundtrip(k, seed, policy):
    """Property: the merge is a permutation that preserves per-tenant
    order, and scatter-by-gmaps / gather-by-deinterleave is bit-exact."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(0, 40, size=k).tolist()
    rates = rng.uniform(0.25, 4.0, size=k).tolist()
    il = interleave(lengths, rates=rates, policy=policy)
    total = sum(lengths)
    assert il.total == total and il.num_tenants == k
    # coverage: gmaps partition arange(total)
    allslots = np.concatenate(il.gmaps) if total else np.zeros(0, np.int64)
    np.testing.assert_array_equal(np.sort(allslots), np.arange(total))
    slots = deinterleave(il)
    for m, s, n in zip(il.gmaps, slots, lengths):
        assert len(m) == n
        # order preservation: global slots strictly increase privately
        assert np.all(np.diff(m) > 0)
        # both representations agree
        np.testing.assert_array_equal(m, s)
    # bit-exact payload roundtrip through the global stream
    payloads = [rng.integers(0, 2**40, size=n) for n in lengths]
    gstream = np.empty(total, dtype=np.int64)
    for m, p in zip(il.gmaps, payloads):
        gstream[m] = p
    for s, p in zip(slots, payloads):
        np.testing.assert_array_equal(gstream[s], p)


# ------------------------------------------------------------- shared LLC


def test_tenant_shift_preserves_set_mapping():
    for max_block, sets in [(1000, 64), (3, 64), (10**6, 1), (63, 64)]:
        shift = tenant_shift(max_block, sets)
        assert (1 << shift) > max_block  # namespaces disjoint
        for k in range(4):
            assert (k << shift) % max(sets, 1) == 0  # set index preserved


def test_shared_llc_single_tenant_is_identity():
    rng = np.random.default_rng(0)
    blocks = rng.integers(0, 300, size=2000)
    keys = np.arange(len(blocks))
    for sets, ways in [(64, 8), (16, 2), (1, 4)]:
        (hits,) = shared_llc_pass([(blocks, keys)], sets, ways)
        np.testing.assert_array_equal(hits, cache_pass(blocks, sets, ways))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), sets=st.sampled_from([4, 16, 64]))
def test_contention_only_loses_hits(seed, sets):
    """Property: inserting a foreign tenant's accesses can only grow a
    reuse's LRU stack distance — every shared-LLC hit was a solo hit."""
    rng = np.random.default_rng(seed)
    b0 = rng.integers(0, 200, size=rng.integers(1, 500))
    b1 = rng.integers(0, 200, size=rng.integers(1, 500))
    il = interleave([len(b0), len(b1)])
    shared = shared_llc_pass(
        [(b0, il.gmaps[0]), (b1, il.gmaps[1])], sets, ways=4
    )
    for blocks, sh in zip((b0, b1), shared):
        solo = cache_pass(blocks, sets, ways=4)
        assert not np.any(sh & ~solo)


# ------------------------------------------------------------- protocol


@pytest.fixture(scope="module")
def arts(tmp_path_factory):
    return ArtifactCache(tmp_path_factory.mktemp("serve-artifacts"))


@pytest.fixture(scope="module")
def serve_cache(arts):
    return WorkloadCache(artifacts=arts)


@pytest.fixture(scope="module")
def duo_result(serve_cache):
    spec = ServeSpec(tenants=(TenantSpec("pgd", TINY), TenantSpec("cc", TINY)))
    result = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=serve_cache
    ).run(workers=1)
    return spec, result


def test_serve_spec_validation():
    t = TenantSpec("pgd", TINY)
    with pytest.raises(ValueError, match=">= 1 tenant"):
        ServeSpec(tenants=())
    with pytest.raises(ValueError, match="unknown interleave policy"):
        ServeSpec(tenants=(t,), policy="chaos")
    with pytest.raises(ValueError, match="unknown table mode"):
        ServeSpec(tenants=(t,), table_modes=("global",))
    with pytest.raises(ValueError, match="rate must be positive"):
        TenantSpec("pgd", TINY, rate=0.0)
    with pytest.raises(ValueError, match="unknown dataset"):
        Experiment(
            workloads=[ServeSpec(tenants=(TenantSpec("pgd", "nope"),))],
            prefetchers=["amc"],
        )


def _strip_serving(row):
    """Drop the serving-only fields, leaving the single-tenant row."""
    row = dict(row)
    row.pop("tenant")
    row.pop("table_mode")
    row["info"] = {k: v for k, v in row["info"].items() if k != "serve"}
    return row


def test_k1_serving_byte_identical_to_grid(serve_cache):
    """Acceptance anchor: one tenant, identity interleave, zero-offset LLC
    namespace — every serving row (both AMC table modes and the stateless
    baseline) is byte-identical to the plain single-tenant grid row."""
    spec = ServeSpec(tenants=(TenantSpec("pgd", TINY),))
    serve = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=serve_cache
    ).run(workers=1)
    plain = Experiment(
        workloads=[WorkloadSpec("pgd", TINY)],
        prefetchers=["amc", "nextline2"],
        cache=serve_cache,
    ).run(workers=1)
    plain_by_pf = {r["prefetcher"]: r for r in plain.rows()}
    serve_rows = serve.rows()
    assert {r["table_mode"] for r in serve_rows} == {
        "per_tenant",
        "shared",
        None,
    }
    for row in serve_rows:
        assert rows_equal(
            [_strip_serving(row)], [plain_by_pf[row["prefetcher"]]]
        ), f"{row['prefetcher']}/{row['table_mode']} diverged from grid"


def test_serve_through_experiment(duo_result):
    spec, result = duo_result
    rows = result.rows()
    # 2 tenants x (2 AMC table modes + 1 stateless baseline)
    assert len(rows) == 6
    amc = [r for r in rows if r["prefetcher"] == "amc"]
    assert sorted((r["tenant"], r["table_mode"]) for r in amc) == [
        (0, "per_tenant"),
        (0, "shared"),
        (1, "per_tenant"),
        (1, "shared"),
    ]
    for r in rows:
        serve = r["info"]["serve"]
        assert serve["policy"] == "round_robin"
        assert serve["tenant"] == r["tenant"]
        assert serve["llc_demand_hits_lost"] >= 0
    nl = [r for r in rows if r["prefetcher"] == "nextline2"]
    assert all(r["table_mode"] is None for r in nl)
    # shared rows carry the shared-table contention counters
    st_info = [
        r["info"]["serve"]["shared_table"]
        for r in amc
        if r["table_mode"] == "shared"
    ]
    assert all(s["lookups"] > 0 for s in st_info)
    assert all("cross_tenant_overwrites" in s for s in st_info)


def test_shared_tables_degrade_vs_per_tenant(duo_result):
    """The tentpole's headline: one shared table store aliases both
    tenants' correlations, so mean coverage/accuracy drop below the
    per-tenant provisioning upper bound, with the damage itemized."""
    spec, result = duo_result
    by_mode = {}
    for r in result.rows():
        if r["prefetcher"] == "amc":
            by_mode.setdefault(r["table_mode"], []).append(r)
    mean = lambda rows, key: np.mean([r[key] for r in rows])  # noqa: E731
    assert mean(by_mode["shared"], "coverage") <= mean(
        by_mode["per_tenant"], "coverage"
    )
    shared_info = [r["info"]["serve"]["shared_table"] for r in by_mode["shared"]]
    # pgd and cc both key every iteration's table at within_epoch=0, so the
    # shared store thrashes: tenants overwrite and alias each other.
    assert sum(s["aliased_hits"] for s in shared_info) > 0
    assert shared_info[0]["cross_tenant_overwrites"] > 0
    assert shared_info[0]["thrashed_entries"] > 0


def test_contention_payload_schema(duo_result):
    spec, result = duo_result
    wspecs = spec.tenant_workloads()
    cells = [
        ServeCell(
            tenant=c.tenant,
            prefetcher=c.prefetcher,
            table_mode=c.table_mode,
            metrics=c.metrics,
            spec=wspecs[c.tenant],
        )
        for c in result.cells
    ]
    doc = contention_payload(spec, cells)
    assert doc["schema"] == "serve-contention"
    assert doc["num_tenants"] == 2 and doc["policy"] == "round_robin"
    assert [t["kernel"] for t in doc["tenants"]] == ["pgd", "cc"]
    amc = doc["prefetchers"]["amc"]
    assert set(amc) == {"per_tenant", "shared"}
    for mode in amc.values():
        assert [r["tenant"] for r in mode["per_tenant_rows"]] == [0, 1]
        assert 0.0 <= mode["mean_accuracy"] <= 1.0
    assert set(doc["prefetchers"]["nextline2"]) == {"stateless"}
    assert (
        amc["shared"]["mean_coverage"] <= amc["per_tenant"]["mean_coverage"]
    )


def test_serve_parallel_matches_serial(serve_cache, duo_result):
    spec, serial = duo_result
    parallel = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=serve_cache
    ).run(workers=2)
    assert rows_equal(serial.rows(), parallel.rows())


# ------------------------------------------------- auto-worker resolution


def test_auto_workers_follows_cost_model(monkeypatch):
    """``workers=None`` resolves through the scheduler's cost model: a
    pool is spawned only when its predicted time beats serial."""
    heavy = Experiment(
        workloads=[
            WorkloadSpec("pgd", "road-ca"),
            WorkloadSpec("pgd", "google"),
            WorkloadSpec("cc", "road-ca"),
        ],
        prefetchers=["amc"],
    )
    monkeypatch.setattr("os.cpu_count", lambda: 8)
    # Big cold builds: the makespan across workers beats serial + spawn.
    d = heavy._plan_schedule()
    assert d.mode == "pipeline" and 1 < d.workers <= 3
    assert heavy._auto_workers() == d.workers
    monkeypatch.setattr("os.cpu_count", lambda: 1)
    # Single core (the bench-host case): never spawn a pool.
    d1 = heavy._plan_schedule()
    assert d1.mode == "serial" and d1.workers == 1
    monkeypatch.setattr("os.cpu_count", lambda: 8)
    small = Experiment(
        workloads=[WorkloadSpec("pgd", TINY), WorkloadSpec("cc", TINY)],
        prefetchers=["amc"],
    )
    # Tiny builds: spawn overhead exceeds the parallel gain -> serial,
    # even with spare cores (the old blind min(cores, builds) said 2).
    ds = small._plan_schedule()
    assert ds.mode == "serial" and ds.workers == 1
    one = Experiment(workloads=[WorkloadSpec("pgd", TINY)], prefetchers=["amc"])
    assert one._auto_workers() == 1  # a single build gains nothing


def test_auto_workers_serial_for_unpicklable_prefetchers(monkeypatch):
    monkeypatch.setattr("os.cpu_count", lambda: 8)
    exp = Experiment(
        workloads=[WorkloadSpec("pgd", TINY), WorkloadSpec("cc", TINY)],
        prefetchers=[("adhoc", lambda w: None)],
    )
    # The default must tolerate what explicit workers=N rejects loudly.
    d = exp._plan_schedule()
    assert d.workers == 1 and "spawn boundary" in d.reason


def test_auto_workers_counts_serve_tenants(monkeypatch):
    monkeypatch.setattr("os.cpu_count", lambda: 8)
    spec = ServeSpec(
        tenants=(
            TenantSpec("pgd", TINY),
            TenantSpec("cc", TINY),
            TenantSpec("pgd", TINY, seed=1),
        )
    )
    exp = Experiment(workloads=[spec], prefetchers=["amc"])
    # One cost-model task per distinct tenant build.
    assert exp._plan_schedule().n_tasks == 3


# ----------------------------------------------------------- figures glue


def test_figures_load_serves_and_warns(tmp_path):
    """benchmarks.figures: serve-contention docs route to load_serves /
    fig_contention; load() skips them silently but WARNS (not silence) on
    anything else it drops."""
    import json
    import sys
    import warnings

    sys.path.insert(0, ".")
    from benchmarks import figures

    def row(tenant, cov, shared_table=None):
        serve = {"llc_demand_hits_lost": 3, "llc_pf_hits_lost": 1}
        if shared_table is not None:
            serve["shared_table"] = shared_table
        return {
            "tenant": tenant,
            "kernel": "pgd",
            "dataset": "tiny",
            "seed": tenant,
            "speedup": 1.1,
            "coverage": cov,
            "accuracy": 0.9,
            "useful": 10,
            "issued": 12,
            "serve": serve,
        }

    serve_doc = {
        "schema": "serve-contention",
        "policy": "round_robin",
        "num_tenants": 2,
        "table_modes": ["per_tenant", "shared"],
        "tenants": [
            {"kernel": "pgd", "dataset": "tiny", "seed": 0, "rate": 1.0},
            {"kernel": "pgd", "dataset": "tiny", "seed": 1, "rate": 1.0},
        ],
        "prefetchers": {
            "amc": {
                "per_tenant": {
                    "per_tenant_rows": [row(0, 0.6), row(1, 0.5)],
                    "mean_coverage": 0.55,
                    "mean_accuracy": 0.9,
                    "mean_speedup": 1.1,
                },
                "shared": {
                    "per_tenant_rows": [
                        row(0, 0.4, {"aliased_hits": 2, "cross_tenant_overwrites": 1}),
                        row(1, 0.3, {"aliased_hits": 3, "cross_tenant_overwrites": 1}),
                    ],
                    "mean_coverage": 0.35,
                    "mean_accuracy": 0.7,
                    "mean_speedup": 1.05,
                },
            }
        },
    }
    sweep_doc = {
        "kernel": "pgd",
        "dataset": "tiny",
        "prefetchers": {"amc": {"speedup": 1.2, "coverage": 0.5, "accuracy": 0.9}},
    }
    (tmp_path / "pgd_tiny.json").write_text(json.dumps(sweep_doc))
    (tmp_path / "contention_tiny_k2.json").write_text(json.dumps(serve_doc))
    (tmp_path / "unknown.json").write_text(json.dumps({"schema": "future-thing"}))
    (tmp_path / "corrupt.json").write_text('{"kernel": "pgd", "trunc')

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        data = figures.load(str(tmp_path))
    assert set(data) == {("pgd", "tiny")}
    skipped = [
        str(w.message)
        for w in caught
        if str(w.message).startswith("figures.load")
    ]
    assert any("unknown.json" in m for m in skipped)  # unknown doc warns
    assert any("corrupt.json" in m for m in skipped)  # corrupt file warns
    assert not any("contention" in m for m in skipped)  # known schema: silent

    serves = figures.load_serves(str(tmp_path))
    assert set(serves) == {("pgd/tiny#s0+pgd/tiny#s1", "round_robin")}
    headers, rows, derived = figures.fig_contention(serves)
    assert [r[2] for r in rows] == ["per_tenant", "shared"]
    shared_row = rows[1]
    assert shared_row[headers.index("aliased_hits")] == 5
    key = "table_isolation_coverage_gain/K=2[round_robin]pgd/tiny#s0+pgd/tiny#s1/amc"
    assert derived[key] == pytest.approx(0.2)
