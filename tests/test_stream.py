"""The multi-epoch streaming subsystem: update streams, delta snapshots,
AMC table lifecycle, and the stream protocol on the Experiment engine.

Covers the subsystem's contracts: churn models are deterministic from the
seed; delta application reproduces the induced-subgraph construction bit
for bit (so the §VI pair is truly the E=2 special case); the ``reset``
lifecycle equals an independent cold run of every epoch; ``persist`` with
zero churn reproduces the paper's same-graph re-run behavior; and a
stream's serial and ``workers=2`` runs are byte-identical.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import ArtifactCache, Experiment, WorkloadCache
from repro.core.amc.storage import AMCEntryTable, AMCStorage
from repro.core.exec.scheduler import rows_equal
from repro.core.experiment import score_prefetcher
from repro.core.registry import get_prefetcher
from repro.graphs import make_dataset, make_evolving_pair
from repro.graphs.csr import induced_subgraph
from repro.stream import (
    CommunityChurn,
    PreferentialGrowth,
    SlidingWindow,
    StreamSpec,
    TableLifecycle,
    UniformChurn,
    apply_delta,
    snapshot_sequence,
)

TINY = "tiny"
ALL_MODELS = [
    UniformChurn(),
    CommunityChurn(),
    SlidingWindow(),
    PreferentialGrowth(),
]


@pytest.fixture(scope="module")
def base():
    return make_dataset(TINY)


# ---------------------------------------------------------------- updates


@pytest.mark.parametrize("model", ALL_MODELS, ids=lambda m: type(m).kind)
def test_update_streams_deterministic(base, model):
    a = model.generate(base, epochs=4, seed=7)
    b = model.generate(base, epochs=4, seed=7)
    c = model.generate(base, epochs=4, seed=8)
    assert a.num_epochs == 4 and len(a.batches) == 3
    np.testing.assert_array_equal(a.init_src, b.init_src)
    for ba, bb in zip(a.batches, b.batches):
        np.testing.assert_array_equal(ba.add_src, bb.add_src)
        np.testing.assert_array_equal(ba.del_src, bb.del_src)
    # a different seed must actually change the stream
    assert any(
        len(x.add_src) != len(y.add_src) or not np.array_equal(x.add_src, y.add_src)
        for x, y in zip(a.batches, c.batches)
    ) or not np.array_equal(a.init_src, c.init_src)


def test_sliding_window_constant_size_and_churn(base):
    model = SlidingWindow(window_frac=0.5, step_frac=0.1)
    seq = snapshot_sequence(base, model, epochs=5, seed=1)
    sizes = {g.num_edges for g in seq.graphs}
    assert len(sizes) == 1  # circular window: every epoch the same size
    for batch in seq.batches:
        assert batch.num_inserts == batch.num_deletes > 0
    with pytest.raises(ValueError, match="lap itself"):
        SlidingWindow(window_frac=0.9, step_frac=0.2)


def test_sliding_window_always_slides_after_rounding():
    """Regression: integer rounding of window+step may exceed m (e.g.
    0.95+0.05 of 10 edges rounds to 10+1); the window must still move —
    reported churn has to be real churn."""
    from repro.graphs.csr import from_edges

    g = from_edges(np.arange(10), np.arange(10) + 1, 11)
    seq = snapshot_sequence(
        g, SlidingWindow(window_frac=0.95, step_frac=0.05), epochs=3, seed=0
    )
    for e in range(1, 3):
        batch = seq.batches[e - 1]
        # deleted and inserted edge sets are disjoint: the window moved
        del_keys = set(zip(batch.del_src, batch.del_dst))
        add_keys = set(zip(batch.add_src, batch.add_dst))
        assert batch.num_updates > 0 and not (del_keys & add_keys)
        assert not np.array_equal(
            seq.graphs[e].neighbors, seq.graphs[e - 1].neighbors
        )


def test_preferential_growth_monotone(base):
    seq = snapshot_sequence(base, PreferentialGrowth(), epochs=4, seed=2)
    sizes = [g.num_edges for g in seq.graphs]
    assert sizes == sorted(sizes) and sizes[-1] > sizes[0]
    assert all(b.num_deletes == 0 for b in seq.batches)


# --------------------------------------------------------------- snapshots


@pytest.mark.parametrize(
    "model", [UniformChurn(), CommunityChurn()], ids=lambda m: type(m).kind
)
def test_apply_delta_matches_induced_construction(base, model):
    """The vectorized delta path and the §VI induced-subgraph path must
    produce identical CSR arrays (canonical edge order)."""
    seq = snapshot_sequence(base, model, epochs=4, seed=5)
    for e in range(1, seq.num_epochs):
        d = apply_delta(seq.graphs[e - 1], seq.batches[e - 1], name="delta")
        np.testing.assert_array_equal(d.offsets, seq.graphs[e].offsets)
        np.testing.assert_array_equal(d.neighbors, seq.graphs[e].neighbors)
        if d.weights is not None:
            np.testing.assert_array_equal(d.weights, seq.graphs[e].weights)


def test_evolving_pair_is_the_e2_special_case(base):
    """make_evolving_pair == snapshot_sequence(UniformChurn(), epochs=2),
    bit for bit — masks, CSR arrays, and the rng draw sequence."""
    pair = make_evolving_pair(base, seed=3)
    seq = snapshot_sequence(base, UniformChurn(), epochs=2, seed=3)
    np.testing.assert_array_equal(pair.mask1, seq.masks[0])
    np.testing.assert_array_equal(pair.mask2, seq.masks[1])
    for run, g in [(pair.run1, seq.graphs[0]), (pair.run2, seq.graphs[1])]:
        np.testing.assert_array_equal(run.offsets, g.offsets)
        np.testing.assert_array_equal(run.neighbors, g.neighbors)
    # the legacy rng call sequence, replayed by hand
    rng = np.random.default_rng(3)
    n = base.num_vertices
    mask1 = np.zeros(n, dtype=bool)
    mask1[rng.choice(n, size=int(0.8 * n), replace=False)] = True
    np.testing.assert_array_equal(pair.mask1, mask1)
    run1 = induced_subgraph(base, mask1, "ref")
    np.testing.assert_array_equal(pair.run1.neighbors, run1.neighbors)


def test_snapshot_stats_and_changed_vertices(base):
    seq = snapshot_sequence(base, UniformChurn(), epochs=3, seed=0)
    s0, s1, _ = seq.stats
    assert s0.vertex_overlap == 1.0 and s0.edge_churn == 0.0
    assert 0.8 < s1.vertex_overlap < 0.95
    assert s1.edges_added >= 0 and s1.edges_deleted > 0
    changed = seq.changed_vertices(1)
    toggled = np.flatnonzero(seq.masks[0] != seq.masks[1])
    assert np.isin(toggled, changed).all()  # presence flips always count
    with pytest.raises(IndexError):
        seq.changed_vertices(0)


# --------------------------------------------------------- table lifecycle


def _table(iteration, trigger, nmiss_per_entry=2, age=0):
    n = len(trigger)
    nmiss = np.full(n, nmiss_per_entry, dtype=np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nmiss, out=offsets[1:])
    return AMCEntryTable(
        iteration=iteration,
        trigger_vid=np.asarray(trigger, dtype=np.int64),
        prev_vid=np.full(n, -1, dtype=np.int64),
        mode=np.zeros(n, np.int8),
        nmiss=nmiss,
        bits=np.full(n, 64, dtype=np.int64),
        miss_offsets=offsets,
        miss_blocks=np.arange(offsets[-1], dtype=np.int64),
        age=age,
    )


def test_invalidate_triggers_subsets_ragged_arrays():
    storage = AMCStorage(1 << 20)
    storage.recording[0] = _table(0, trigger=[1, 5, 9, 12])
    storage.swap()
    dropped = storage.invalidate_triggers(np.array([5, 12]))
    assert dropped == 2 and storage.invalidated_entries == 2
    t = storage.prefetching[0]
    np.testing.assert_array_equal(t.trigger_vid, [1, 9])
    # ragged miss streams re-packed: entry 0 keeps blocks [0,1], entry 1
    # (originally entry 2) keeps blocks [4,5]
    np.testing.assert_array_equal(t.miss_offsets, [0, 2, 4])
    np.testing.assert_array_equal(t.miss_blocks, [0, 1, 4, 5])


def test_swap_retaining_ages_and_drops():
    storage = AMCStorage(1 << 20)
    storage.recording[0] = _table(0, trigger=[1])
    storage.recording[1] = _table(1, trigger=[2])
    storage.swap()
    # next epoch re-records iteration 0 only
    storage.recording[0] = _table(0, trigger=[3])
    storage.swap_retaining(max_age=1)
    assert set(storage.prefetching) == {0, 1}
    assert storage.prefetching[0].age == 0  # fresh recording wins
    assert storage.prefetching[1].age == 1  # carried fallback, aged
    # one more epoch with nothing recorded: iteration 1 exceeds max_age
    storage.swap_retaining(max_age=1)
    assert set(storage.prefetching) == {0}
    assert storage.aged_out_tables == 1


def test_lookup_counters_and_staleness():
    storage = AMCStorage(1 << 20)
    storage.recording[0] = _table(0, trigger=[1], age=0)
    storage.swap()
    assert storage.lookup(0) is not None and storage.lookup(7) is None
    assert storage.lookup_hits == 1 and storage.lookup_misses == 1
    storage.prefetching[0].age = 2
    storage.lookup(0)
    assert storage.stale_hits == 1


def test_lifecycle_policy_validation():
    with pytest.raises(ValueError, match="unknown lifecycle"):
        TableLifecycle("warm-ish", capacity_bytes=1024)


# ---------------------------------------------------------------- protocol


@pytest.fixture(scope="module")
def arts(tmp_path_factory):
    return ArtifactCache(tmp_path_factory.mktemp("stream-artifacts"))


@pytest.fixture(scope="module")
def stream_cache(arts):
    return WorkloadCache(artifacts=arts)


@pytest.fixture(scope="module")
def persist_result(stream_cache):
    spec = StreamSpec("pgd", TINY, SlidingWindow(), epochs=3, lifecycle="persist")
    result = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=stream_cache
    ).run()
    return spec, result


def test_stream_spec_validation():
    with pytest.raises(ValueError, match=">= 2 epochs"):
        StreamSpec("pgd", TINY, SlidingWindow(), epochs=1)
    with pytest.raises(ValueError, match="unknown lifecycle"):
        StreamSpec("pgd", TINY, SlidingWindow(), lifecycle="sometimes")
    with pytest.raises(TypeError, match="churn model"):
        StreamSpec("pgd", TINY, churn="sliding")
    with pytest.raises(ValueError, match="unknown dataset"):
        Experiment(
            workloads=[StreamSpec("pgd", "nope", SlidingWindow())],
            prefetchers=["amc"],
        )


def test_epoch_specs_are_content_keyed(arts):
    """Epoch artifacts are keyed on what the trace is *determined by* —
    the per-epoch graph content — so churned epochs get distinct keys
    while bit-identical epochs share one artifact (delta-aware reuse)."""
    spec = StreamSpec("pgd", TINY, SlidingWindow(), epochs=3)
    eps = spec.epoch_specs()
    # a sliding window changes the graph every epoch: three distinct keys
    assert len({arts.path_for(e) for e in eps}) == 3
    # filenames carry the graph-content digest, not an epoch index tag
    assert "_g" in arts.path_for(eps[1]).name
    assert "_e1" not in arts.path_for(eps[1]).name
    # zero churn leaves every epoch's graph bit-identical: ONE shared key
    zc = UniformChurn(init_frac=1.0, del_frac=0.0, add_frac=0.0)
    zeps = StreamSpec("pgd", TINY, zc, epochs=3).epoch_specs()
    assert len({arts.path_for(e) for e in zeps}) == 1
    # a different initial graph moves the key
    other = StreamSpec(
        "pgd",
        TINY,
        UniformChurn(init_frac=0.9, del_frac=0.0, add_frac=0.0),
        epochs=3,
    ).epoch_specs()
    assert arts.path_for(other[0]) != arts.path_for(zeps[0])
    # lifecycle is NOT part of the epoch identity: persist/reset share builds
    a = StreamSpec("pgd", TINY, SlidingWindow(), epochs=3, lifecycle="persist")
    b = StreamSpec("pgd", TINY, SlidingWindow(), epochs=3, lifecycle="reset")
    assert a.epoch_specs() == b.epoch_specs()


def test_stream_through_experiment(persist_result):
    spec, result = persist_result
    rows = result.rows()
    assert len(rows) == 2 * spec.epochs
    amc_rows = [r for r in rows if r["prefetcher"] == "amc"]
    assert [r["epoch"] for r in amc_rows] == [0, 1, 2]
    assert all(r["lifecycle"] == "persist" for r in amc_rows)
    # epoch 0 is cold (nothing to replay); later epochs carry correlations
    assert amc_rows[0]["coverage"] == 0.0
    assert amc_rows[1]["coverage"] > 0.1 and amc_rows[2]["coverage"] > 0.1
    # per-epoch table accounting is attached
    table = amc_rows[1]["info"]["table"]
    assert table["lookup_hits"] > 0 and table["policy"] == "persist"
    # stateless baselines carry no lifecycle
    nl = [r for r in rows if r["prefetcher"] == "nextline2"]
    assert all(r["lifecycle"] is None for r in nl)
    # drift payload round-trips through the documented schema
    from repro.stream.protocol import drift_payload

    cells = [c for c in result.cells if c.prefetcher == "amc"]
    doc = drift_payload(spec, spec.sequence(), cells)
    assert doc["schema"] == "stream-drift" and doc["churn"]["kind"] == "sliding_window"
    assert len(doc["prefetchers"]["amc"]["summary"]["coverage"]) == 3
    assert len(doc["overlap"]["cumulative_overlap"]) == 3


def test_reset_equals_independent_cold_runs(stream_cache, persist_result):
    """Property: with the ``reset`` lifecycle, every epoch's metrics equal
    an independent cold AMC run of that epoch's trace."""
    spec = StreamSpec("pgd", TINY, SlidingWindow(), epochs=3, lifecycle="reset")
    result = Experiment(
        workloads=[spec], prefetchers=["amc"], cache=stream_cache
    ).run()
    gen = get_prefetcher("amc").instantiate()
    for cell in result.cells:
        cold = score_prefetcher(stream_cache.get_or_build(cell.spec), "amc", gen)
        row, cold_row = cell.metrics.row(), cold.row()
        row_info, cold_info = row.pop("info"), cold_row.pop("info")
        assert row == cold_row, f"epoch {cell.epoch}"
        for k in cold_info:  # lifecycle adds keys; shared ones must match
            np.testing.assert_array_equal(row_info[k], cold_info[k])


def test_persist_zero_churn_reproduces_same_graph_rerun(stream_cache):
    """Property: zero churn + persist == the paper's same-graph re-run —
    epoch >= 2 replays a previous identical run, so coverage must be
    positive and no lower than the cold first epoch."""
    static = UniformChurn(init_frac=1.0, del_frac=0.0, add_frac=0.0)
    spec = StreamSpec("pgd", TINY, static, epochs=3, lifecycle="persist")
    result = Experiment(
        workloads=[spec], prefetchers=["amc"], cache=stream_cache
    ).run()
    seq = spec.sequence()
    assert all(s.vertex_overlap == 1.0 for s in seq.stats)
    cov = [c.metrics.coverage for c in sorted(result.cells, key=lambda c: c.epoch)]
    assert cov[1] >= cov[0] and cov[1] > 0.3
    assert cov[2] == pytest.approx(cov[1], rel=0.2)


def test_stream_parallel_matches_serial(stream_cache, persist_result):
    spec, serial = persist_result
    parallel = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=stream_cache
    ).run(workers=2)
    assert rows_equal(serial.rows(), parallel.rows())


def test_figures_load_skips_stream_artifacts(tmp_path):
    """benchmarks.figures.load must skip drift JSONs (and other unknown
    schemas) instead of KeyError-ing; fig_drift consumes them instead."""
    import json
    import sys

    sys.path.insert(0, ".")
    from benchmarks import figures

    sweep_doc = {
        "kernel": "pgd",
        "dataset": "tiny",
        "prefetchers": {"amc": {"speedup": 1.2, "coverage": 0.5, "accuracy": 0.9}},
    }
    drift_doc = {
        "schema": "stream-drift",
        "kernel": "pgd",
        "dataset": "tiny",
        "lifecycle": "persist",
        "churn": {"kind": "sliding_window"},
        "overlap": {"cumulative_overlap": [1.0, 0.9]},
        "prefetchers": {
            "amc": {
                "lifecycle": "persist",
                "summary": {
                    "coverage": [0.0, 0.6],
                    "accuracy": [0.0, 0.9],
                    "tail_mean_coverage": 0.6,
                    "tail_mean_accuracy": 0.9,
                },
            }
        },
    }
    (tmp_path / "pgd_tiny.json").write_text(json.dumps(sweep_doc))
    (tmp_path / "drift_pgd_tiny.json").write_text(json.dumps(drift_doc))
    (tmp_path / "other.json").write_text(json.dumps({"schema": "future-thing"}))
    (tmp_path / "corrupt.json").write_text('{"kernel": "pgd", "trunc')
    (tmp_path / "array.json").write_text("[1, 2, 3]")
    data = figures.load(str(tmp_path))
    assert set(data) == {("pgd", "tiny")}
    streams = figures.load_streams(str(tmp_path))
    assert set(streams) == {("pgd", "tiny", "sliding_window", "persist")}
    headers, rows, derived = figures.fig_drift(streams)
    assert rows and rows[0][1] == "amc"
    assert derived["tail_mean_coverage/pgd/tiny/sliding_window/amc[persist]"] == 0.6


def test_streams_mix_with_plain_workloads(stream_cache):
    from repro.core import WorkloadSpec

    spec = StreamSpec("pgd", TINY, SlidingWindow(), epochs=3)
    plain = WorkloadSpec("pgd", TINY)
    result = Experiment(
        workloads=[plain, spec], prefetchers=["nextline2"], cache=stream_cache
    ).run()
    rows = result.rows()
    assert len(rows) == 1 + spec.epochs
    assert "epoch" not in rows[0]  # plain cells keep the legacy schema
    assert [r["epoch"] for r in rows[1:]] == [0, 1, 2]
