"""Telemetry layer tests: span tracing, metrics, stage-timer shims,
cross-process merge, trace export, and the bench-diff/perf-report tools.

The stage-shim contract (ISSUE 9): ``stage()``/``collect_stages()``/
``record()`` re-exported through ``repro.core.exec.timers`` must behave
bit-identically to the pre-span implementation — including the no-op
fast path and nested-collector shadowing — while doubling as spans when
a tracer is active.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.core.exec.timers import collect_stages, record, stage
from repro.core.obs import spans as obs
from repro.core.obs.metrics import (
    MetricsRegistry,
    bucket_of,
    histogram_quantile,
    merge_snapshots,
)

sys.path.insert(0, ".")


@pytest.fixture(autouse=True)
def _clean_obs_state():
    obs._reset_for_tests()
    yield
    obs._reset_for_tests()


# ------------------------------------------------------------ stage shims


def test_record_accumulates_and_is_noop_when_inactive():
    record("orphan", 2.0)  # no collector: must not raise or record anywhere
    with collect_stages() as times:
        record("overlap", 1.5)
        record("overlap", 0.5)
        record("count")  # default value 1.0
    assert times == {"overlap": 2.0, "count": 1.0}
    record("late", 9.0)  # collector closed again
    assert "late" not in times and "orphan" not in times


def test_nested_collectors_shadow_and_restore():
    with collect_stages() as outer:
        with stage("a"):
            pass
        with collect_stages() as inner:
            with stage("b"):
                pass
            record("r", 3.0)
        # Inner collector closed: the outer one is active again.
        with stage("c"):
            pass
    assert set(outer) == {"a", "c"}
    assert set(inner) == {"b", "r"} and inner["r"] == 3.0


def test_nested_collector_restores_outer_on_exception():
    with collect_stages() as outer:
        with pytest.raises(RuntimeError):
            with collect_stages():
                raise RuntimeError("boom")
        with stage("after"):
            pass
    assert "after" in outer


def test_stage_noop_fast_path_records_nothing():
    assert not obs.tracing()
    with stage("free"):
        pass  # no collector, no tracer, no registry: nothing observable
    assert obs.current_metrics() is None


def test_stage_spans_share_the_exact_collector_durations():
    """The one perf_counter delta feeds both the stage dict and the span,
    so the span-derived totals equal the collector dict bit-for-bit."""
    with collect_stages() as times:
        with obs.trace() as t:
            for _ in range(3):
                with stage("phase"):
                    pass
            with stage("other"):
                pass
    totals = t.result.stage_totals()
    assert totals["phase"] == times["phase"]
    assert totals["other"] == times["other"]
    assert len(t.result.by_name("phase")) == 3


def test_span_parentage_and_attrs():
    with obs.trace() as t:
        with obs.span("outer", kernel="pgd") as sp:
            assert sp is not None and sp.attrs["kernel"] == "pgd"
            with obs.span("inner", epoch=2):
                pass
            sp.attrs["cache"] = "hit"  # late attribute attach
    outer = t.result.by_name("outer")[0]
    inner = t.result.by_name("inner")[0]
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.attrs == {"kernel": "pgd", "cache": "hit"}
    assert outer.trace_id == inner.trace_id == t.trace_id


def test_span_is_noop_without_tracer():
    with obs.span("nothing", x=1) as sp:
        assert sp is None


# ------------------------------------------------------------------ metrics


def test_metrics_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    assert not reg
    reg.inc("hits")
    reg.inc("hits", 2.0)
    reg.set_gauge("pool", 4)
    reg.observe("lat", 0.5)
    reg.observe("lat", 2.0)
    assert reg and reg.counter("hits") == 3.0
    assert reg.ratio("hits", "misses") == 1.0
    assert reg.ratio("absent", "also_absent") is None
    h = reg.snapshot()["histograms"]["lat"]
    assert h["count"] == 2 and h["sum"] == 2.5
    assert h["min"] == 0.5 and h["max"] == 2.0
    assert histogram_quantile(h, 1.0) == 2.0
    assert bucket_of(0.0) == 0 and bucket_of(1e-6) == 0
    assert bucket_of(2e-6) < bucket_of(1.0) < bucket_of(100.0)


def test_merge_snapshots_sums_counters_and_merges_histograms():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("n", 1)
    b.inc("n", 2)
    a.set_gauge("g", 1)
    b.set_gauge("g", 2)
    a.observe("h", 1.0)
    b.observe("h", 4.0)
    merged = merge_snapshots([a.snapshot(), b.snapshot()])
    assert merged["counters"]["n"] == 3.0
    assert merged["gauges"]["g"] == 2.0  # last writer in pid order
    h = merged["histograms"]["h"]
    assert h["count"] == 2 and h["sum"] == 5.0 and h["max"] == 4.0


def test_metrics_helpers_route_to_active_registry():
    with obs.metrics_registry() as reg:
        obs.inc("c", 2)
        obs.observe("h", 0.1)
        obs.set_gauge("g", 7)
        with stage("timed"):
            pass
    assert reg.counter("c") == 2.0
    assert reg.gauges["g"] == 7.0
    assert reg.histograms["stage.timed"]["count"] == 1
    obs.inc("c")  # registry closed: no-op
    assert reg.counter("c") == 2.0


# -------------------------------------------------------- trace dir merge


def _write_worker_file(dir, pid, spans, metrics_lines=()):
    path = dir / f"spans-worker-{pid}.jsonl"
    with open(path, "w") as f:
        for s in spans:
            f.write(json.dumps(s) + "\n")
        for line in metrics_lines:
            f.write(json.dumps(line) + "\n")
    return path


def _fake_span(pid, seq, ts, name="w", trace_id="t1"):
    return {
        "name": name,
        "trace_id": trace_id,
        "span_id": f"{pid:x}-{seq:x}",
        "parent_id": None,
        "ts": ts,
        "dur": 0.001,
        "pid": pid,
        "proc": "worker",
        "attrs": {},
    }


def test_run_trace_merge_is_deterministic_and_ordered(tmp_path):
    _write_worker_file(tmp_path, 300, [_fake_span(300, 1, 50)])
    _write_worker_file(tmp_path, 4, [_fake_span(4, 1, 200), _fake_span(4, 2, 10)])
    a = obs.RunTrace.load(tmp_path)
    b = obs.RunTrace.load(tmp_path)
    assert a.as_dict() == b.as_dict()  # merge is a pure function of files
    assert [(s.ts, s.pid) for s in a.spans] == [(10, 4), (50, 300), (200, 4)]
    assert a.processes() == [(4, "worker"), (300, "worker")]


def test_run_trace_merge_keeps_last_metrics_per_pid_and_sums_across(tmp_path):
    m1 = {"counters": {"n": 1.0}, "gauges": {}, "histograms": {}}
    m2 = {"counters": {"n": 5.0}, "gauges": {}, "histograms": {}}
    _write_worker_file(
        tmp_path,
        4,
        [_fake_span(4, 1, 10)],
        [
            {"kind": "metrics", "pid": 4, "proc": "worker", "seq": 1, "metrics": m1},
            {"kind": "metrics", "pid": 4, "proc": "worker", "seq": 2, "metrics": m2},
        ],
    )
    _write_worker_file(
        tmp_path,
        300,
        [_fake_span(300, 1, 20)],
        [
            {
                "kind": "metrics",
                "pid": 300,
                "proc": "worker",
                "seq": 1,
                "metrics": m1,
            }
        ],
    )
    rt = obs.RunTrace.load(tmp_path)
    # Cumulative snapshots: last per pid (5), summed across pids (+1).
    assert rt.metrics["counters"]["n"] == 6.0


def test_run_trace_merge_drops_corrupt_tail_lines(tmp_path):
    path = _write_worker_file(tmp_path, 4, [_fake_span(4, 1, 10)])
    with open(path, "a") as f:
        f.write('{"name": "torn-wri')  # killed mid-write
    rt = obs.RunTrace.load(tmp_path)
    assert len(rt.spans) == 1


def test_run_trace_save_read_roundtrip(tmp_path):
    with obs.trace(dir=tmp_path / "t") as t:
        with obs.span("a", k=1):
            pass
        obs.inc("c", 2)
    rt = t.result
    path = rt.save(tmp_path / "run.json")
    back = obs.RunTrace.read(path)
    assert back.as_dict() == rt.as_dict()
    with pytest.raises(ValueError):
        bogus = tmp_path / "bogus.json"
        bogus.write_text('{"schema": "other"}')
        obs.RunTrace.read(bogus)


def test_tracer_finish_is_idempotent(tmp_path):
    with obs.trace(dir=tmp_path) as t:
        with obs.span("a"):
            pass
    first = t.finish()
    assert first is t.result and t.finish() is first
    # Exactly one copy of the span on disk despite repeated finishes.
    assert len(obs.RunTrace.load(tmp_path).spans) == 1


# ------------------------------------------------- cross-process tracing


def test_worker_env_probe_joins_parent_trace(tmp_path):
    """A spawned process finding REPRO_TRACE_DIR set appends its spans to
    its own JSONL file; the parent's merge sees both processes."""
    with obs.trace(dir=tmp_path) as t:
        with obs.span("parent_work"):
            pass
        child = (
            "from repro.core.obs import spans as obs\n"
            "obs.inc('child.counter', 3)\n"
            "with obs.span('child_work', shard=1):\n"
            "    pass\n"
            "obs.flush_worker_metrics()\n"
        )
        env = dict(os.environ)
        env[obs.SPAN_DIR_ENV] = str(tmp_path)
        env[obs.TRACE_ID_ENV] = t.trace_id
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
        )
        subprocess.run(
            [sys.executable, "-c", child], check=True, env=env, timeout=120
        )
    rt = t.result
    assert {proc for _, proc in rt.processes()} == {"main", "worker"}
    child_span = rt.by_name("child_work")[0]
    assert child_span.trace_id == t.trace_id
    assert child_span.attrs == {"shard": 1}
    assert child_span.pid != os.getpid()
    assert rt.metrics["counters"]["child.counter"] == 3.0


def test_experiment_tracing_serial_matches_workers2(tmp_path):
    """Results are bit-identical with tracing active, serial vs pool, and
    the pool trace covers parent + worker processes."""
    from repro.core import ArtifactCache, Experiment, WorkloadCache
    from repro.core.exec.scheduler import rows_equal

    def fresh():
        return Experiment(
            kernels=["pgd"],
            datasets=["tiny"],
            prefetchers=["amc", "nextline2"],
            cache=WorkloadCache(artifacts=ArtifactCache(tmp_path / "arts")),
        )

    with obs.trace(dir=tmp_path / "serial") as ts:
        serial = fresh().run(workers=1)
    with obs.trace(dir=tmp_path / "pool") as tp:
        pooled = fresh().run(workers=2)
    assert rows_equal(serial.rows(), pooled.rows())

    assert {p for _, p in ts.result.processes()} == {"main"}
    procs = tp.result.processes()
    assert {p for _, p in procs} == {"main", "worker"}
    assert len(procs) >= 2
    # Worker-side scoring spans joined the parent's trace id.
    cell = tp.result.by_name("score_cell")[0]
    assert cell.trace_id == tp.trace_id
    # Both runs saw the same grid: same scored cells, same span names.
    names = {"experiment_run", "score_cell", "build_workload"}
    assert names <= {s.name for s in ts.result.spans}
    # The pooled run reuses the serial run's artifact cache, so workers
    # load rather than rebuild: materialize/run_task spans, no build.
    assert {"experiment_run", "score_cell", "materialize", "run_task"} <= {
        s.name for s in tp.result.spans
    }
    assert len(ts.result.by_name("score_cell")) == len(
        tp.result.by_name("score_cell")
    )
    # Merge determinism: re-loading the span dir reproduces the RunTrace.
    assert obs.RunTrace.load(tmp_path / "pool").as_dict() == {
        **tp.result.as_dict(),
        "manifest": None,
    }
    # Telemetry attach: manifest provenance + trace linkage.
    assert pooled.telemetry["trace_id"] == tp.trace_id
    assert pooled.telemetry["manifest"]["trace_schema"] == obs.TRACE_SCHEMA
    assert pooled.telemetry["workload_cache"]["hits"] >= 0


# ----------------------------------------------------------- trace export


def test_chrome_trace_export(tmp_path):
    from tools.trace_export import chrome_trace, main

    with obs.trace(dir=tmp_path / "t") as t:
        with obs.span("outer", kernel="pgd"):
            with stage("score"):
                pass
    doc = chrome_trace(t.result)
    assert doc["schema"] == "chrome-trace"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    slices = [e for e in events if e["ph"] == "X"]
    assert len(meta) == 1 and meta[0]["name"] == "process_name"
    assert {e["name"] for e in slices} == {"outer", "score"}
    for e in slices:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == os.getpid()
    inner = next(e for e in slices if e["name"] == "score")
    outer = next(e for e in slices if e["name"] == "outer")
    assert inner["args"]["parent"] == outer["id"]
    json.dumps(doc)  # must be directly serializable

    saved = t.result.save(tmp_path / "run.json")
    out = tmp_path / "chrome.json"
    assert main([str(saved), "-o", str(out)]) == 0
    assert json.loads(out.read_text())["trace_id"] == t.trace_id
    # Directory input works too, and an empty trace is a loud error.
    assert main([str(tmp_path / "t"), "-o", str(out)]) == 0
    empty = tmp_path / "empty"
    empty.mkdir()
    assert main([str(empty), "-o", str(out)]) == 1


# ------------------------------------------------------- bench-diff tools


def test_bench_sort_key_orders_numeric_suffixes():
    from benchmarks.perf_report import bench_sort_key

    paths = [
        "BENCH_2026-08-01.10.json",
        "BENCH_2026-08-01.2.json",
        "BENCH_2026-08-01.json",
        "BENCH_2026-07-30.json",
    ]
    ordered = sorted(paths, key=bench_sort_key)
    assert ordered == [
        "BENCH_2026-07-30.json",
        "BENCH_2026-08-01.json",
        "BENCH_2026-08-01.2.json",
        "BENCH_2026-08-01.10.json",
    ]


def _bench_doc(smoke, stages, grid=None):
    return {
        "schema": 8,
        "smoke": smoke,
        "grid": grid or {"workloads": ["pgd/tiny#s0"], "prefetchers": ["amc"]},
        "stages_s": stages,
    }


def test_bench_diff_flags_regressions_and_honors_floor(tmp_path):
    from tools.bench_diff import comparable, diff_stages

    old = _bench_doc(False, {"score": 1.0, "noise": 0.001, "gone": 1.0})
    new = _bench_doc(False, {"score": 2.0, "noise": 0.004, "added": 1.0})
    assert comparable(old, new)
    assert not comparable(old, _bench_doc(True, {}))
    d = diff_stages(old, new, threshold=1.5, min_seconds=0.05)
    regressed = {r["stage"] for r in d["regressions"]}
    # score breached ratio+floor; noise breached ratio only (under floor).
    assert regressed == {"score"}
    by_stage = {r["stage"]: r for r in d["rows"]}
    assert by_stage["gone"]["new_s"] is None
    assert by_stage["added"]["old_s"] is None


def test_bench_diff_cli_picks_comparable_pair_and_gates(tmp_path):
    from tools.bench_diff import main

    # Newest doc is a smoke run; the full run in between must be skipped
    # when picking its baseline.
    (tmp_path / "BENCH_2026-01-01.json").write_text(
        json.dumps(_bench_doc(True, {"score": 1.0}))
    )
    (tmp_path / "BENCH_2026-01-02.json").write_text(
        json.dumps(_bench_doc(False, {"score": 50.0}))
    )
    (tmp_path / "BENCH_2026-01-03.json").write_text(
        json.dumps(_bench_doc(True, {"score": 1.01}))
    )
    out = tmp_path / "diff.json"
    assert main(["--root", str(tmp_path), "--out", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["old"] == "BENCH_2026-01-01.json"
    assert doc["new"] == "BENCH_2026-01-03.json"

    # A genuine regression in the newest pair exits non-zero.
    (tmp_path / "BENCH_2026-01-04.json").write_text(
        json.dumps(_bench_doc(True, {"score": 9.0}))
    )
    assert main(["--root", str(tmp_path), "--threshold", "1.5"]) == 1
    # No comparable baseline at all: pass with a note.
    solo = tmp_path / "solo"
    solo.mkdir()
    (solo / "BENCH_2026-01-01.json").write_text(
        json.dumps(_bench_doc(True, {"score": 1.0}))
    )
    assert main(["--root", str(solo)]) == 0
