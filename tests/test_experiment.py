"""The declarative Experiment API + prefetcher registry.

Covers: registration/lookup/duplicate-name errors, grid construction,
workload-cache reuse across prefetchers and experiments, and path
equivalence — direct ``score_prefetcher`` scoring must produce the same
PrefetchMetrics as ``Experiment`` for the same workload cell.
"""
import numpy as np
import pytest

from repro.core import (
    Experiment,
    WorkloadCache,
    WorkloadSpec,
    get_prefetcher,
    list_prefetchers,
    register_prefetcher,
)
from repro.core.experiment import score_prefetcher
from repro.core.registry import (
    DuplicatePrefetcherError,
    UnknownPrefetcherError,
    resolve_prefetchers,
)

PAPER_PREFETCHERS = ["amc", "vldp", "bingo", "isb", "misb", "rnr", "domino", "prodigy"]


@pytest.fixture(scope="module")
def cache():
    return WorkloadCache()


# ---------------------------------------------------------------- registry


def test_all_paper_prefetchers_resolvable_by_name():
    names = set(list_prefetchers())
    assert set(PAPER_PREFETCHERS) <= names
    for n in PAPER_PREFETCHERS:
        spec = get_prefetcher(n)
        assert spec.name == n
        assert spec.trains_on  # declarative metadata present
        assert callable(spec.instantiate())


def test_registry_duplicate_name_rejected():
    with pytest.raises(DuplicatePrefetcherError, match="already registered"):

        @register_prefetcher("vldp", trains_on="l2_access")
        def other(workload):
            raise NotImplementedError


def test_registry_unknown_name_lists_available():
    with pytest.raises(UnknownPrefetcherError, match="vldp"):
        get_prefetcher("does-not-exist")


def test_non_configurable_prefetcher_rejects_overrides():
    with pytest.raises(TypeError, match="not configurable"):
        get_prefetcher("vldp").instantiate(degree=4)


def test_amc_factory_applies_config_overrides():
    gen = get_prefetcher("amc").instantiate(lookahead_accesses=30, match_pairs=True)
    cfg = gen.__self__.config
    assert cfg.lookahead_accesses == 30 and cfg.match_pairs


def test_resolve_prefetchers_mixed_references():
    def custom(workload):
        raise NotImplementedError

    pairs = resolve_prefetchers(["rnr", get_prefetcher("vldp"), ("mine", custom)])
    assert [n for n, _ in pairs] == ["rnr", "vldp", "mine"]
    with pytest.raises(ValueError, match="duplicate"):
        resolve_prefetchers(["rnr", "rnr"])


def test_deprecated_shims_are_gone():
    """PR 1's deprecation policy, executed: the SUITE dict and
    run_prefetcher_suite no longer exist — the registry is the only path."""
    import repro.core
    import repro.core.driver
    import repro.core.prefetchers

    assert not hasattr(repro.core, "run_prefetcher_suite")
    assert not hasattr(repro.core.driver, "run_prefetcher_suite")
    with pytest.raises(AttributeError):
        repro.core.prefetchers.SUITE
    # The registry still serves the full Table I baseline suite.
    from repro.core.prefetchers import BASELINE_NAMES

    assert list(BASELINE_NAMES) == [
        "vldp", "bingo", "isb", "misb", "rnr", "domino", "prodigy",
    ]
    for n in BASELINE_NAMES:
        assert callable(get_prefetcher(n).instantiate())


# ------------------------------------------------------------ WorkloadSpec


def test_workload_spec_validates_declaratively():
    # elem-size divisibility is checked at declaration time
    with pytest.raises(ValueError, match="integer multiple"):
        WorkloadSpec("pgd", "comdblp", target_elem_size=6, frontier_elem_size=4)
    # name membership is checked before the app would run from names
    # (an ad-hoc name + caller-supplied runs= stays possible)
    with pytest.raises(ValueError, match="unknown kernel"):
        WorkloadSpec("nope", "comdblp").build()
    with pytest.raises(ValueError, match="unknown dataset"):
        WorkloadSpec("pgd", "nope").build()
    # the frozen spec itself is the cache/identity key
    spec = WorkloadSpec("pgd", "comdblp")
    assert hash(spec) == hash(WorkloadSpec("pgd", "comdblp"))


def test_experiment_fails_fast_on_unknown_names():
    with pytest.raises(ValueError, match="unknown dataset"):
        Experiment(kernels=["pgd"], datasets=["comdlbp"], prefetchers=["rnr"])
    with pytest.raises(ValueError, match="unknown kernel"):
        Experiment(kernels=["nope"], datasets=["comdblp"], prefetchers=["rnr"])


# ------------------------------------------------------------- Experiment


def test_experiment_grid_construction():
    exp = Experiment(
        kernels=["pgd", "cc"], datasets=["comdblp"], prefetchers=["vldp", "rnr"]
    )
    assert len(exp.workload_specs) == 2
    assert exp.prefetcher_names == ["vldp", "rnr"]
    grid = exp.grid
    assert len(grid) == 4
    assert {(s.kernel, n) for s, n in grid} == {
        ("pgd", "vldp"), ("pgd", "rnr"), ("cc", "vldp"), ("cc", "rnr"),
    }
    with pytest.raises(ValueError, match="non-empty"):
        Experiment(kernels=["pgd"], datasets=[], prefetchers=["rnr"])
    with pytest.raises(ValueError, match="either workloads"):
        Experiment(
            kernels=["pgd"], datasets=["comdblp"],
            workloads=[WorkloadSpec("pgd", "comdblp")],
        )
    # seeds=/hierarchy= would be silently dropped with workloads= — reject
    with pytest.raises(ValueError, match="declare them on each WorkloadSpec"):
        Experiment(
            workloads=[WorkloadSpec("pgd", "comdblp")],
            prefetchers=["rnr"], seeds=(0, 1),
        )


def test_experiment_accepts_bare_prefetcher_name():
    exp = Experiment(kernels=["pgd"], datasets=["comdblp"], prefetchers="rnr")
    assert exp.prefetcher_names == ["rnr"]


def test_workload_cache_reused_across_prefetchers_and_experiments(cache):
    exp1 = Experiment(
        kernels=["pgd"], datasets=["comdblp"],
        prefetchers=["rnr", "nextline2"], cache=cache,
    )
    res1 = exp1.run()
    assert cache.builds == 1 and len(res1.cells) == 2  # one build, two scores
    exp2 = Experiment(
        kernels=["pgd"], datasets=["comdblp"], prefetchers=["ideal"], cache=cache
    )
    res2 = exp2.run()
    assert cache.builds == 1 and cache.hits == 1  # second experiment reuses
    # identity, not just equality: the same trace object is handed out
    assert res2.workload("pgd", "comdblp") is res1.workload("pgd", "comdblp")


def test_specs_differing_beyond_coordinates_stay_distinct(cache):
    """Two specs with the same (kernel, dataset, seed) but different
    programming-model parameters must not collide in the result."""
    s8 = WorkloadSpec("pgd", "comdblp")
    s16 = WorkloadSpec("pgd", "comdblp", target_elem_size=16)
    res = Experiment(workloads=[s8, s16], prefetchers=["rnr"], cache=cache).run()
    assert len(res.workloads) == 2
    assert res.workloads[s8].session.regs.target_elem_size == 8
    assert res.workloads[s16].session.regs.target_elem_size == 16
    with pytest.raises(KeyError, match="matched 2"):
        res.workload("pgd", "comdblp")
    # spec= disambiguates cell filters
    assert res.metrics(spec=s16, prefetcher="rnr") is not None


def test_experiment_result_is_tidy(cache):
    res = Experiment(
        kernels=["pgd"], datasets=["comdblp"], prefetchers=["rnr"], cache=cache
    ).run()
    rows = res.rows()
    assert len(rows) == 1
    row = rows[0]
    for key in ("kernel", "dataset", "prefetcher", "seed", "speedup", "coverage"):
        assert key in row
    assert row["prefetcher"] == "rnr"
    assert res.metrics(prefetcher="rnr").speedup == row["speedup"]
    with pytest.raises(KeyError, match="matched 0"):
        res.metrics(prefetcher="vldp")


def test_experiment_matches_direct_scoring():
    """Acceptance: the declarative grid reproduces direct
    build_workload + score_prefetcher metrics exactly."""
    from repro.core.amc import AMCConfig, AMCPrefetcher

    result = Experiment(
        kernels=["bfs"], datasets=["comdblp"], prefetchers=["amc", "vldp"]
    ).run()
    w = result.workload("bfs", "comdblp")
    direct = {
        "amc": score_prefetcher(w, "amc", AMCPrefetcher(AMCConfig()).generate),
        "vldp": score_prefetcher(w, "vldp", get_prefetcher("vldp").instantiate()),
    }
    for name in ("amc", "vldp"):
        new = result.metrics(prefetcher=name).row()
        old = direct[name].row()
        new_info, old_info = new.pop("info"), old.pop("info")
        assert new == old, name
        assert set(new_info) == set(old_info), name
        for k in new_info:
            np.testing.assert_array_equal(new_info[k], old_info[k], err_msg=f"{name}.{k}")
