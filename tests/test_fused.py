"""Fused multi-level hierarchy engine vs the per-level reference cascade.

The fused engine's contract is *bit identity*: one carried L1→L2→LLC scan
must emit exactly the hit levels of running the serial reference scan per
level over successively compacted miss substreams, and its carried
:class:`~repro.memsim.engine.CacheState` lists must compose with any
per-level engine across shard seams.  Covered here: randomized streams x
geometries (property test, including ways=1, single-set, repeated-block
streams and carry resume at a mid-stream seam), degenerate inputs, the
Pallas kernel variant in interpret mode, vmapped-batch == per-stream-loop
identity (raw passes, prefetch scoring, and the Experiment cell layer),
and an end-to-end check that a grid's rows are byte-identical under the
fused and reference engines.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: seeded stub strategies
    from _hypothesis_fallback import given, settings, st

from repro.memsim import use_engine
from repro.memsim.engine import canonicalize_state, init_state
from repro.memsim.fused import (
    fused_cache_pass,
    fused_cache_pass_batch,
    fused_group_count,
    levels_to_hits,
    state_from_groups,
    state_to_groups,
)
from repro.memsim.scan_cache import cache_pass as cache_pass_reference

THREE_LEVEL = ((16, 8), (64, 8), (256, 16))  # the SCALED demand geometry
TWO_LEVEL = ((64, 8), (256, 16))  # the scoring (L2→LLC) geometry


def reference_levels(blocks, levels, states=None, return_states=False):
    """Hit levels via the serial reference scan, one level at a time."""
    lvl = np.full(len(blocks), len(levels), dtype=np.int8)
    pos = np.arange(len(blocks), dtype=np.int64)
    sub = np.asarray(blocks)
    out_states = []
    for i, (sets, ways) in enumerate(levels):
        st_i = None if states is None else states[i]
        res = cache_pass_reference(sub, sets, ways, st_i, return_states)
        hit = res[0] if return_states else res
        if return_states:
            out_states.append(res[1])
        lvl[pos[hit]] = i
        pos, sub = pos[~hit], sub[~hit]
    return (lvl, out_states) if return_states else lvl


@given(
    n=st.integers(1, 400),
    span=st.integers(1, 2000),
    geom=st.sampled_from(
        [
            THREE_LEVEL,
            TWO_LEVEL,
            ((1, 4), (4, 1)),  # single-set L1, direct-mapped L2
            ((4, 1), (8, 2), (16, 1)),  # ways=1 at the outer and inner level
            ((8, 2), (8, 4)),  # equal set counts (R == 1 everywhere)
        ]
    ),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_fused_bit_identical_to_reference(n, span, geom, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, span, n).astype(np.int64)
    if seed % 3 == 0:
        # repeated-block runs: same line touched many times back-to-back
        blocks = np.repeat(blocks, rng.integers(1, 4, n))[: max(n, 1)]
    ref, ref_sts = reference_levels(blocks, geom, return_states=True)
    # force_scan pins the carried-scan path: these streams are small
    # enough that the cost-based plan chooser would route them to the
    # (already reference-gated) per-level cascade and test nothing new.
    got, got_sts = fused_cache_pass(
        blocks, geom, return_states=True, force_scan=True
    )
    np.testing.assert_array_equal(got, ref)
    for a, b in zip(got_sts, ref_sts):
        np.testing.assert_array_equal(a.tags, b.tags)
        np.testing.assert_array_equal(a.age, b.age)
    # the default plan (chooser picks scan or cascade) must agree too
    np.testing.assert_array_equal(fused_cache_pass(blocks, geom), ref)
    # shard-seam carry resume: fused first half, fused second half from the
    # carried states == one uninterrupted pass; and the carried states are
    # canonical, so the *reference* engine can resume them identically.
    h = len(blocks) // 2
    l1, sts = fused_cache_pass(
        blocks[:h], geom, return_states=True, force_scan=True
    )
    l2 = fused_cache_pass(blocks[h:], geom, states=sts, force_scan=True)
    np.testing.assert_array_equal(np.concatenate([l1, l2]), ref)
    l2_ref = reference_levels(blocks[h:], geom, states=sts)
    np.testing.assert_array_equal(l2, l2_ref)


def test_fused_edge_cases():
    rng = np.random.default_rng(0)
    cases = [
        (np.zeros(0, np.int64), THREE_LEVEL),  # empty stream
        (np.zeros(1, np.int64), ((1, 1), (1, 1))),  # degenerate hierarchy
        (np.full(50, 7, np.int64), ((4, 1), (16, 1))),  # repeated, direct-mapped
        (rng.integers(0, 9, 300).astype(np.int64), ((1, 4), (1, 8))),  # one set
        (np.arange(64, dtype=np.int64), TWO_LEVEL),  # all cold misses
    ]
    for blocks, geom in cases:
        ref = reference_levels(blocks, geom)
        got = fused_cache_pass(blocks, geom)
        np.testing.assert_array_equal(got, ref, err_msg=f"{geom}")


def test_fused_skewed_stream_falls_back_and_stays_identical():
    """A stream concentrated in one group would pad to a matrix far larger
    than the stream; the fused pass must route it through the per-level
    cascade (bit-identical by the engine contract) instead of paying that
    allocation."""
    rng = np.random.default_rng(2)
    geom = ((4096, 8), (8192, 8))
    blocks = (rng.integers(0, 500, 2_000) * 4096).astype(np.int64)  # one set
    ref = reference_levels(blocks, geom)
    got = fused_cache_pass(blocks, geom)
    np.testing.assert_array_equal(got, ref)


def test_state_groups_roundtrip_and_group_count():
    rng = np.random.default_rng(3)
    assert fused_group_count(THREE_LEVEL) == 16
    for sets, ways in THREE_LEVEL:
        arr = rng.integers(0, 1000, (sets, ways))
        lanes = state_to_groups(arr, 16)
        assert lanes.shape == (16, sets // 16 * ways)
        np.testing.assert_array_equal(
            state_from_groups(lanes, sets, ways), arr
        )


def test_levels_to_hits_matches_cascade_masks():
    rng = np.random.default_rng(4)
    blocks = rng.integers(0, 800, 2_000).astype(np.int64)
    lvl = fused_cache_pass(blocks, THREE_LEVEL)
    masks = levels_to_hits(lvl, 3)
    sub = blocks
    for i, ((sets, ways), mask) in enumerate(zip(THREE_LEVEL, masks)):
        np.testing.assert_array_equal(
            mask, cache_pass_reference(sub, sets, ways), err_msg=f"level {i}"
        )
        sub = sub[~mask]


def test_fused_pallas_interpret_matches_host_scan():
    """The Pallas kernel variant (interpret mode off-TPU) must agree with
    the host lax.scan on hit levels AND carried states, including resume."""
    rng = np.random.default_rng(5)
    for geom in (THREE_LEVEL, TWO_LEVEL):
        blocks = rng.integers(0, 3000, 4_000).astype(np.int64)
        ref, ref_sts = fused_cache_pass(
            blocks, geom, return_states=True, use_pallas=False,
            force_scan=True,
        )
        got, got_sts = fused_cache_pass(
            blocks, geom, return_states=True, use_pallas=True
        )
        np.testing.assert_array_equal(got, ref)
        for a, b in zip(got_sts, ref_sts):
            np.testing.assert_array_equal(a.tags, b.tags)
            np.testing.assert_array_equal(a.age, b.age)
        # seam: resume the Pallas variant from a host-scan carry
        h = len(blocks) // 2
        l1, sts = fused_cache_pass(
            blocks[:h], geom, return_states=True, use_pallas=False,
            force_scan=True,
        )
        l2 = fused_cache_pass(blocks[h:], geom, states=sts, use_pallas=True)
        np.testing.assert_array_equal(np.concatenate([l1, l2]), ref)


def test_batched_pass_bit_identical_to_loop():
    """One vmapped launch over same-geometry streams == looping the single
    pass, for hit levels and final states, with varied lengths and carries."""
    rng = np.random.default_rng(6)
    streams = [
        rng.integers(0, 1500, n).astype(np.int64) for n in (37, 400, 1200, 1)
    ]
    carries = [
        [init_state(s, w) for s, w in TWO_LEVEL],
        [
            canonicalize_state(
                rng.integers(0, 99, (s, w)), rng.integers(1, 50, (s, w))
            )
            for s, w in TWO_LEVEL
        ],
        [init_state(s, w) for s, w in TWO_LEVEL],
        [init_state(s, w) for s, w in TWO_LEVEL],
    ]
    got, got_sts = fused_cache_pass_batch(
        streams, TWO_LEVEL, states=carries, return_states=True,
        force_scan=True,
    )
    for i, s in enumerate(streams):
        ref, ref_sts = fused_cache_pass(
            s, TWO_LEVEL, states=carries[i], return_states=True,
            force_scan=True,
        )
        np.testing.assert_array_equal(got[i], ref, err_msg=f"stream {i}")
        for a, b in zip(got_sts[i], ref_sts):
            np.testing.assert_array_equal(a.tags, b.tags)
            np.testing.assert_array_equal(a.age, b.age)


def test_batched_pass_empty_and_skewed_fall_back():
    rng = np.random.default_rng(7)
    streams = [
        rng.integers(0, 500, 100).astype(np.int64),
        np.zeros(0, np.int64),  # empty member forces the loop path
    ]
    got = fused_cache_pass_batch(streams, TWO_LEVEL)
    for i, s in enumerate(streams):
        np.testing.assert_array_equal(got[i], fused_cache_pass(s, TWO_LEVEL))
    assert fused_cache_pass_batch([], TWO_LEVEL) == []


def test_simulate_demand_batch_matches_loop():
    """Seed-replica demand batching == looping simulate_demand, on
    run-heavy streams (the fused vmapped scan engages: collapse shrinks
    every member's bucket) — per-level hit masks compared field by field
    against the set_parallel loop."""
    from repro.memsim import simulate_demand, simulate_demand_batch
    from repro.memsim.config import SCALED

    rng = np.random.default_rng(8)
    items = []
    for n in (20_000, 24_000, 18_000):
        base = rng.integers(0, 4_000, n // 4).astype(np.int64)
        blocks = np.repeat(base, 4)[:n]  # run-heavy: collapse wins
        items.append((blocks, np.zeros(n, np.int64)))
    with use_engine("set_parallel"):
        ref = [simulate_demand(b, it, SCALED) for b, it in items]
    with use_engine("fused"):
        got = simulate_demand_batch(items, SCALED)
    for i, (r, g) in enumerate(zip(ref, got)):
        for f in ("l1_hit", "l2_hit", "llc_hit", "l2_pos"):
            np.testing.assert_array_equal(
                getattr(g, f), getattr(r, f), err_msg=f"stream {i}: {f}"
            )


@pytest.fixture(scope="module")
def workload():
    from repro.core import WorkloadSpec

    with use_engine("fused"):
        return WorkloadSpec("pgd", "comdblp").build()


def test_simulate_with_prefetch_batch_matches_loop(workload):
    """The batched scoring pass must reproduce the per-stream loop's
    PrefetchOutcome fields bit-for-bit."""
    import dataclasses

    from repro.memsim import simulate_with_prefetch, simulate_with_prefetch_batch

    rng = np.random.default_rng(8)
    prof = workload.profile
    streams = []
    for k in (1, 4):  # two simple delta prefetchers as the family
        pf_pos = prof.l2_pos[:: 7 * k].astype(np.int64)
        pf_blocks = prof.blocks[pf_pos] + k
        issuer = np.ones(len(pf_blocks), np.int8)
        streams.append((pf_blocks, pf_pos, issuer))
    with use_engine("fused"):
        batched = simulate_with_prefetch_batch(prof, streams)
        looped = [
            simulate_with_prefetch(prof, b, p, pf_issuer=i)
            for b, p, i in streams
        ]
    for got, ref in zip(batched, looped):
        for f in dataclasses.fields(ref):
            a, b = getattr(got, f.name), getattr(ref, f.name)
            assert np.array_equal(a, b), f.name


def test_score_prefetchers_batched_matches_loop(workload):
    from repro.core.exec.scheduler import rows_equal
    from repro.core.experiment import score_prefetcher, score_prefetchers_batched
    from repro.core.registry import resolve_prefetchers

    pairs = resolve_prefetchers(["rnr", "nextline2"])
    with use_engine("fused"):
        batched = [
            m.row() for m in score_prefetchers_batched(workload, pairs)
        ]
        looped = [score_prefetcher(workload, n, g).row() for n, g in pairs]
    assert rows_equal(looped, batched)


def test_experiment_rows_byte_identical_fused_vs_reference():
    """End-to-end: a small grid's result rows match bit-for-bit whether the
    demand profiles and (batched) prefetch scoring run on the fused engine
    or the serial reference."""
    from repro.core import Experiment, WorkloadSpec
    from repro.core.exec.scheduler import rows_equal

    specs = [WorkloadSpec("pgd", "comdblp")]
    prefetchers = ["rnr", "nextline2"]
    with use_engine("fused"):
        rows_fused = Experiment(workloads=specs, prefetchers=prefetchers).run().rows()
    with use_engine("reference"):
        rows_ref = Experiment(workloads=specs, prefetchers=prefetchers).run().rows()
    assert rows_equal(rows_fused, rows_ref)
