"""Per-architecture smoke tests (deliverable f): every assigned arch, a
reduced same-family config, one forward/train step + one decode step on CPU
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    padded_vocab,
)


def _batch_for(cfg, b, s):
    batch = {"labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["tokens"] = jnp.ones((b, s), jnp.int32)
        batch["frames"] = jnp.ones((b, s, cfg.d_model), jnp.float32) * 0.1
    elif cfg.family == "vlm":
        batch["embeds"] = jnp.ones((b, s, cfg.d_model), jnp.float32) * 0.1
        batch["positions3"] = jnp.tile(jnp.arange(s)[None, None], (b, 3, 1))
    else:
        batch["tokens"] = jnp.ones((b, s), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch_for(cfg, b, s)

    def step(p, bt):
        loss, metrics = loss_fn(cfg, p, bt)
        grads = jax.grad(lambda q: loss_fn(cfg, q, bt)[0])(p)
        return loss, grads

    loss, grads = jax.jit(step)(params, batch)
    assert jnp.isfinite(loss), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    cache = init_cache(cfg, b, 16)
    step = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    tok = jnp.ones((b, 1), jnp.int32)
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (b, 1, padded_vocab(cfg.vocab_size)), arch
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert int(cache["len"][0]) == 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    """The full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
    }[arch]
    got = (
        cfg.num_layers,
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected, (arch, got, expected)


def test_param_counts_plausible():
    """Sanity: parameter counts land near the advertised sizes."""
    approx = {
        "llama3_405b": (3.5e11, 4.7e11),
        "mixtral_8x22b": (1.2e11, 1.6e11),
        "grok_1_314b": (2.6e11, 3.6e11),
        "smollm_360m": (2.5e8, 4.5e8),
        "mamba2_780m": (5.0e8, 1.0e9),
        "qwen3_4b": (3.0e9, 5.5e9),
        "glm4_9b": (7.5e9, 1.15e10),
        "qwen2_vl_7b": (6.0e9, 9.5e9),
        "zamba2_1p2b": (0.8e9, 1.8e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3g}")
    # MoE active < total
    moe = get_config("mixtral_8x22b")
    assert moe.active_param_count() < 0.45 * moe.param_count()


def test_long_context_applicability_matrix():
    runs = {a: cell_supported(get_config(a), SHAPES["long_500k"])[0] for a in ARCH_IDS}
    assert runs["mamba2_780m"] and runs["zamba2_1p2b"] and runs["mixtral_8x22b"]
    for a in ["grok_1_314b", "whisper_tiny", "qwen3_4b", "llama3_405b",
              "glm4_9b", "smollm_360m", "qwen2_vl_7b"]:
        assert not runs[a], a
