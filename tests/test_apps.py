"""App correctness: PGD/CC/BFS/BellmanFord against independent references."""
import numpy as np
import pytest

from repro.apps import (
    bellman_ford,
    bfs,
    connected_components,
    pagerank_delta,
    trace_app_run,
)
from repro.apps.trace import ARRAYS, F_ID, N_ID, P_ID, T_ID, V_ID, TraceConfig
from repro.graphs import from_edges, make_dataset
from repro.graphs.csr import symmetrize


@pytest.fixture(scope="module")
def small():
    return make_dataset("comdblp")


def test_pgd_converges_and_shrinks(small):
    run = pagerank_delta(small)
    assert run.num_iters >= 3
    sizes = [len(f) for f in run.frontiers]
    assert sizes[-1] < sizes[0]  # early convergence
    pr = run.values
    assert np.isfinite(pr).all()
    deg_pos = small.degrees > 0
    # rank mass concentrated on present vertices and positive
    assert (pr[deg_pos] >= 0).all()


def _cc_reference(g):
    """Union-find ground truth."""
    parent = np.arange(g.num_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    src = g.edge_sources()
    for s, d in zip(src, g.neighbors):
        rs, rd = find(s), find(d)
        if rs != rd:
            parent[max(rs, rd)] = min(rs, rd)
    return np.array([find(v) for v in range(g.num_vertices)])


def test_cc_matches_union_find():
    rng = np.random.default_rng(1)
    g = from_edges(rng.integers(0, 300, 900), rng.integers(0, 300, 900), 300)
    run = connected_components(g)
    labels = run.values
    und = symmetrize(g)
    ref = _cc_reference(und)
    present = und.degrees > 0
    # same partition: min label within each ref component, restricted to
    # present vertices
    for comp in np.unique(ref[present]):
        members = np.flatnonzero((ref == comp) & present)
        assert len(np.unique(labels[members])) == 1


def _bfs_reference(g, root):
    dist = np.full(g.num_vertices, -1)
    dist[root] = 0
    frontier = [root]
    d = 0
    while frontier:
        nxt = []
        for v in frontier:
            for u in g.neighbors[g.offsets[v] : g.offsets[v + 1]]:
                if dist[u] < 0:
                    dist[u] = d + 1
                    nxt.append(int(u))
        frontier = nxt
        d += 1
    return dist


def test_bfs_levels_match_reference():
    rng = np.random.default_rng(2)
    g = from_edges(rng.integers(0, 200, 800), rng.integers(0, 200, 800), 200)
    root = int(np.argmax(g.degrees))
    run = bfs(g, root=root)
    ref = _bfs_reference(g, root)
    # frontiers are exactly the BFS levels
    for level, f in enumerate(run.frontiers):
        assert set(f) == set(np.flatnonzero(ref == level)), level


def _dijkstra(g, root):
    import heapq

    dist = np.full(g.num_vertices, np.inf)
    dist[root] = 0
    h = [(0.0, root)]
    while h:
        d, v = heapq.heappop(h)
        if d > dist[v]:
            continue
        for e in range(g.offsets[v], g.offsets[v + 1]):
            u = g.neighbors[e]
            nd = d + g.weights[e]
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(h, (nd, int(u)))
    return dist


def test_bellman_ford_matches_dijkstra():
    rng = np.random.default_rng(3)
    m = 600
    g = from_edges(
        rng.integers(0, 150, m),
        rng.integers(0, 150, m),
        150,
        weights=rng.integers(1, 10, m).astype(np.float32),
    )
    root = int(np.argmax(g.degrees))
    run = bellman_ford(g, root=root)
    ref = _dijkstra(g, root)
    got = np.asarray(run.values)
    reachable = np.isfinite(ref)
    np.testing.assert_allclose(got[reachable], ref[reachable], rtol=1e-5)


def test_trace_structure(small):
    run = pagerank_delta(small, max_iters=3)
    traces = trace_app_run(run)
    t0 = traces[0]
    active = run.frontiers[0]
    deg = small.degrees[active]
    assert len(t0) == 3 * len(active) + 2 * deg.sum()
    # header pattern F,T,V then interleaved N,P
    assert t0.array_id[0] == F_ID and t0.array_id[1] == T_ID and t0.array_id[2] == V_ID
    # per-array counts
    for aid, count in [
        (F_ID, len(active)),
        (T_ID, len(active)),
        (V_ID, len(active)),
        (N_ID, deg.sum()),
        (P_ID, deg.sum()),
    ]:
        assert (t0.array_id == aid).sum() == count
    # address ranges disjoint per array
    cfg = TraceConfig(small.num_vertices, small.num_edges)
    for aid in ARRAYS:
        base, size = cfg.region(aid)
        sel = t0.array_id == aid
        assert (t0.addr[sel] >= base).all()
        assert (t0.addr[sel] < base + size).all()
