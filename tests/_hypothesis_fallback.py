"""Deterministic mini-`hypothesis` used when the real library is absent.

The tier-1 suite must run on a bare environment (numpy + jax + pytest
only).  This module implements just the strategy surface the tests use —
``integers``, ``floats``, ``sampled_from``, ``lists`` — drawing a fixed,
seeded sequence of examples so the property tests still exercise their
brute-force references instead of being skipped wholesale.  No shrinking,
no example database; install ``hypothesis`` for the real thing.
"""
import functools
import inspect
import random

# Keep the bare-environment runs fast: the real library's max_examples is
# honored up to this cap (the properties are exact-equality checks against
# brute-force references, so a seeded subset retains most of the power).
_MAX_EXAMPLES_CAP = 15


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        opts = list(elements)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)


st = _Strategies()


class settings:
    """Records max_examples for ``given`` to pick up; deadline is ignored."""

    def __init__(self, max_examples=_MAX_EXAMPLES_CAP, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_max_examples = self.max_examples
        return fn


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above or below @given; check both objects.
            max_examples = getattr(
                wrapper,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", _MAX_EXAMPLES_CAP),
            )
            rng = random.Random(0xA3C)
            for _ in range(min(max_examples, _MAX_EXAMPLES_CAP)):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        wrapper.is_hypothesis_fallback = True
        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (real hypothesis does the same).
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate
