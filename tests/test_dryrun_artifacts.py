"""Integrity of the multi-pod dry-run artifacts (deliverable e).

These tests validate the *recorded* dry-run results (results/dryrun) so the
80-cell matrix stays healthy without recompiling in CI; if artifacts are
missing the suite instructs how to regenerate (skip, not fail — the
compile run is a separate, longer job). A single live lower+compile on a
reduced mesh-compatible config runs unconditionally.
"""
import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def _cells():
    out = {}
    for f in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


@pytest.mark.skipif(
    not glob.glob(os.path.join(DRYRUN_DIR, "*.json")),
    reason="run: python -m repro.launch.dryrun --all",
)
def test_all_80_cells_present_and_clean():
    cells = _cells()
    assert len(cells) == 80, f"expected 80 cells, found {len(cells)}"
    errors = [(k, v.get("error")) for k, v in cells.items() if v["status"] == "error"]
    assert not errors, errors
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            for mesh in ("16x16", "2x16x16"):
                rec = cells[(arch, sname, mesh)]
                ok, _ = cell_supported(cfg, shape)
                assert rec["status"] == ("ok" if ok else "skipped"), (
                    arch, sname, mesh, rec["status"],
                )


@pytest.mark.skipif(
    not glob.glob(os.path.join(DRYRUN_DIR, "*.json")),
    reason="run: python -m repro.launch.dryrun --all",
)
def test_ok_cells_have_cost_and_collectives():
    for key, rec in _cells().items():
        if rec["status"] != "ok":
            continue
        assert rec["flops"] > 0, key
        assert "collectives" in rec and "total_bytes" in rec["collectives"], key
        assert rec["devices"] in (256, 512), key
        # multi-pod cells must actually use 512 devices
        if rec["mesh"] == "2x16x16":
            assert rec["devices"] == 512, key


def test_live_lower_compile_reduced_cell():
    """One real lower+compile on the local device (reduced config)."""
    import jax

    from repro.launch.steps import make_train_step
    from repro.launch.specs import batch_spec, params_spec, opt_state_spec
    from repro.configs.base import ShapeConfig

    cfg = get_config("smollm_360m").reduced()
    shape = ShapeConfig("tiny", 32, 2, "train")
    step = make_train_step(cfg)
    pspec = params_spec(cfg)
    ospec = opt_state_spec(cfg, pspec)
    bspec = batch_spec(cfg, shape)
    lowered = jax.jit(step).lower(pspec, ospec, bspec)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes

    hlo = """
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = (f32[64]{0}, f32[32]{0}) all-gather(%y, %z), dimensions={0}
  %nothing = f32[8]{0} add(%a, %b)
  %cp = u8[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 128 * 256 * 2
    assert out["all-gather"]["bytes"] == 64 * 4 + 32 * 4
    assert out["collective-permute"]["bytes"] == 4
    assert out["total_bytes"] == 128 * 256 * 2 + 64 * 4 + 32 * 4 + 4
