"""Cost-aware scheduler + overlap pipeline + delta-aware epoch reuse.

Three contracts from the async-pipeline scheduler work:

- The cost model's serial-vs-pool decision is deterministic for fixed
  inputs, degrades to serial on one core or when spawn overhead exceeds
  the predicted parallel gain, and caps the pool by memory.
- Pipelined execution (score tasks dispatched as builds land) is
  byte-identical to serial phased execution across every spec kind —
  plain grid, stream, serve, and sharded — in one mixed run.
- Delta-aware reuse returns bit-identical traces to re-emission: a
  zero-churn epoch is a cache hit (one build, shared content key), a
  churned epoch is a miss, and reuse is surfaced as
  ``ExperimentResult.trace_reuse`` identically in serial and pooled runs.
"""
import numpy as np
import pytest

from repro.core import ArtifactCache, Experiment, WorkloadCache
from repro.core.driver import WorkloadSpec
from repro.core.exec import scheduler
from repro.core.exec.scheduler import TaskCost, decide, estimate_cost, rows_equal
from repro.core.exec.sharded import ShardedSpec
from repro.core.experiment import score_prefetcher
from repro.core.registry import resolve_prefetchers
from repro.serve import ServeSpec, TenantSpec
from repro.stream import SlidingWindow, StreamSpec, UniformChurn

TINY = "tiny"
ZERO_CHURN = UniformChurn(init_frac=1.0, del_frac=0.0, add_frac=0.0)


def _cost(total_s, *, measured=True, resident=1e6):
    return TaskCost(
        spec=None,
        build_s=total_s / 2,
        score_s=total_s / 2,
        resident_bytes=resident,
        measured=measured,
    )


# ------------------------------------------------------------- cost model


def test_decide_is_deterministic_for_fixed_inputs():
    costs = [_cost(30.0), _cost(10.0), _cost(5.0)]
    a = decide(costs, cores=4, mem_bytes=1 << 30)
    b = decide(costs, cores=4, mem_bytes=1 << 30)
    assert a == b  # frozen dataclass equality: every field identical


def test_decide_serial_on_single_core():
    d = decide([_cost(100.0), _cost(100.0)], cores=1)
    assert d.mode == "serial" and d.workers == 1
    assert "single core" in d.reason


def test_decide_serial_when_pool_overhead_exceeds_gain():
    # Two sub-second tasks: any pool pays seconds of spawn for nothing.
    d = decide([_cost(0.3), _cost(0.3)], cores=8)
    assert d.mode == "serial" and d.workers == 1
    assert d.est_pool_s is not None and d.est_pool_s >= d.est_serial_s


def test_decide_pool_when_makespan_beats_serial():
    costs = [_cost(40.0), _cost(40.0), _cost(40.0), _cost(40.0)]
    d = decide(costs, cores=4, mem_bytes=1 << 40)
    assert d.mode == "pipeline" and d.workers == 4
    assert d.est_pool_s < d.est_serial_s


def test_decide_memory_caps_pool_width():
    # Four 1 GiB-resident tasks but only ~2 GiB available: width <= 2.
    costs = [_cost(40.0, resident=float(1 << 30)) for _ in range(4)]
    d = decide(costs, cores=8, mem_bytes=(1 << 31) + (1 << 20))
    assert d.workers <= 2
    tight = decide(costs, cores=8, mem_bytes=1 << 30)
    assert tight.mode == "serial" and "memory" in tight.reason


def test_estimate_cost_prefers_artifact_metadata(tmp_path):
    arts = ArtifactCache(tmp_path)
    spec = WorkloadSpec(kernel="pgd", dataset=TINY)
    cold = estimate_cost(spec, 2, arts)
    assert not cold.measured and cold.build_s > 0 and cold.score_s > 0
    # A materialized artifact switches the estimate to measured size and
    # replaces the build cost with the (much cheaper) load cost.
    arts.root.mkdir(parents=True, exist_ok=True)
    arts.path_for(spec).write_bytes(b"x" * 120_000)
    warm = estimate_cost(spec, 2, arts)
    assert warm.measured and warm.build_s < cold.build_s


def test_estimate_cost_prefers_measured_sidecar(tmp_path):
    """Recorded per-task seconds beat every constant-based estimate:
    ``build_s`` prices a rebuild when only the sidecar survived, and
    ``score_s_per_prefetcher`` scales exactly with the prefetcher count."""
    arts = ArtifactCache(tmp_path)
    spec = WorkloadSpec(kernel="pgd", dataset=TINY)
    assert arts.load_cost(spec) is None  # absent == None, not {}

    # record_cost merges per field; latest measurement wins.
    arts.record_cost(spec, build_s=12.5)
    arts.record_cost(spec, score_s_per_prefetcher=0.75)
    arts.record_cost(spec, build_s=10.0)
    assert arts.load_cost(spec) == {
        "build_s": 10.0,
        "score_s_per_prefetcher": 0.75,
    }

    # No artifact on disk: the recorded build_s replaces the cold
    # constant-based estimate and marks the cost as measured.
    cost = estimate_cost(spec, 2, arts)
    assert cost.measured
    assert cost.build_s == 10.0
    assert cost.score_s == pytest.approx(0.75 * 2)
    assert estimate_cost(spec, 3, arts).score_s == pytest.approx(0.75 * 3)

    # A materialized artifact demotes build to a load estimate (cheaper
    # than the recorded rebuild), but scoring still uses the sidecar.
    arts.path_for(spec).write_bytes(b"x" * 120_000)
    warm = estimate_cost(spec, 2, arts)
    assert warm.build_s < 10.0
    assert warm.score_s == pytest.approx(0.75 * 2)

    # A corrupt sidecar reads as absent, falling back to constants.
    arts.cost_path(spec).write_text("not json")
    assert arts.load_cost(spec) is None
    assert estimate_cost(spec, 2, arts).score_s != pytest.approx(0.75 * 2)


def test_plan_execution_deterministic_with_injected_host(tmp_path):
    arts = ArtifactCache(tmp_path)
    specs = [
        WorkloadSpec(kernel="pgd", dataset="road-ca"),
        WorkloadSpec(kernel="pgd", dataset="google"),
    ]
    a = scheduler.plan_execution(specs, 2, arts, cores=4, mem_bytes=1 << 40)
    b = scheduler.plan_execution(specs, 2, arts, cores=4, mem_bytes=1 << 40)
    assert a == b and a.mode == "pipeline"
    assert scheduler.plan_execution(specs, 2, arts, cores=1).mode == "serial"


def test_run_on_single_core_resolves_serial(monkeypatch, tmp_path):
    """The bench-host case: cpus == 1 -> ``run(workers=None)`` executes
    serial in-process (no spawn pool) and records the decision."""
    monkeypatch.setattr("os.cpu_count", lambda: 1)
    cache = WorkloadCache(artifacts=ArtifactCache(tmp_path))
    result = Experiment(
        workloads=[WorkloadSpec(kernel="pgd", dataset=TINY)],
        prefetchers=["nextline2"],
        cache=cache,
    ).run()
    assert result.sched is not None
    assert result.sched["mode"] == "serial" and result.sched["workers"] == 1
    assert result.sched["cores"] == 1
    # Serial runs keep the eager dict workloads mapping — proof no pool
    # path ran.
    assert isinstance(result.workloads, dict)
    # An explicitly forced worker count records no decision.
    forced = Experiment(
        workloads=[WorkloadSpec(kernel="pgd", dataset=TINY)],
        prefetchers=["nextline2"],
        cache=cache,
    ).run(workers=1)
    assert forced.sched is None


# ------------------------------------------------- delta-aware trace reuse


@pytest.fixture(scope="module")
def reuse_arts(tmp_path_factory):
    return ArtifactCache(tmp_path_factory.mktemp("reuse-artifacts"))


def test_zero_churn_epochs_reuse_one_build(reuse_arts):
    """Unchanged graph => cache hit; the reused trace is bit-identical to
    a fresh re-emission, and scoring it gives identical metrics."""
    spec = StreamSpec(kernel="pgd", dataset=TINY, churn=ZERO_CHURN, epochs=3)
    cache = WorkloadCache(artifacts=reuse_arts)
    result = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=cache
    ).run(workers=1)
    assert cache.builds == 1  # epochs 1..2 hit epoch 0's content key
    assert result.trace_reuse == 2
    # Reuse == re-emission, bit for bit.
    es = spec.epoch_specs()
    reused = cache.get_or_build(es[2])
    fresh = es[2].build()
    for field in (
        "block", "array_id", "epoch_id", "iter_id", "elem",
        "nl_blocks", "nl_pos",
    ):
        np.testing.assert_array_equal(
            getattr(reused, field), getattr(fresh, field)
        )
    ((name, gen),) = resolve_prefetchers(["amc"])
    m_reused = score_prefetcher(reused, name, gen)
    m_fresh = score_prefetcher(fresh, name, gen)
    assert rows_equal([m_reused.row()], [m_fresh.row()])
    # A warm rerun reuses every epoch.
    warm_cache = WorkloadCache(artifacts=reuse_arts)
    warm = Experiment(
        workloads=[spec], prefetchers=["amc", "nextline2"], cache=warm_cache
    ).run(workers=1)
    assert warm_cache.builds == 0 and warm.trace_reuse == 3
    assert rows_equal(result.rows(), warm.rows())


def test_churned_epochs_are_cache_misses(tmp_path):
    spec = StreamSpec(kernel="pgd", dataset=TINY, churn=SlidingWindow(), epochs=3)
    cache = WorkloadCache(artifacts=ArtifactCache(tmp_path))
    result = Experiment(
        workloads=[spec], prefetchers=["nextline2"], cache=cache
    ).run(workers=1)
    assert cache.builds == 3  # every epoch's graph changed: no reuse
    assert result.trace_reuse == 0


def test_in_memory_content_alias_dedupes_across_streams():
    """Two streams over the same (unchanged) graph content share one
    in-memory build even without an artifact store — the within-run
    dedupe satellite: persist-vs-reset comparisons and epoch-count
    variations cost one emission."""
    a = StreamSpec(kernel="pgd", dataset=TINY, churn=ZERO_CHURN, epochs=2,
                   lifecycle="persist")
    b = StreamSpec(kernel="pgd", dataset=TINY, churn=ZERO_CHURN, epochs=3,
                   lifecycle="reset")
    cache = WorkloadCache()  # no artifacts: pure in-memory aliasing
    result = Experiment(
        workloads=[a, b], prefetchers=["nextline2"], cache=cache
    ).run(workers=1)
    # 5 epoch specs (2 + 3, all distinct as specs), one real emission.
    assert cache.builds == 1
    assert cache.reuses == 4  # the other four epochs are content aliases
    assert result.trace_reuse == 4
    # The aliased traces score like the original but stay bound to their
    # own spec (retargeted copies, not one shared object).
    ea, eb = a.epoch_specs()[1], b.epoch_specs()[2]
    assert ea != eb
    ta, tb = cache.get_or_build(ea), cache.get_or_build(eb)
    np.testing.assert_array_equal(ta.block, tb.block)
    assert ta.spec == ea and tb.spec == eb and ta.spec != tb.spec


# ------------------------------------- pipelined == serial, all spec kinds


def test_pipelined_mixed_grid_matches_serial(tmp_path):
    """The headline parity property: grid + stream + serve + sharded specs
    in ONE run, serial vs pipelined pool vs phased pool — byte-identical
    rows everywhere, and reuse counts match serial vs pooled."""
    specs = [
        WorkloadSpec(kernel="pgd", dataset=TINY),
        ShardedSpec(base=WorkloadSpec(kernel="bfs", dataset=TINY),
                    shard_accesses=4096),
        StreamSpec(kernel="pgd", dataset=TINY, churn=ZERO_CHURN, epochs=2),
        ServeSpec(tenants=(TenantSpec("pgd", TINY), TenantSpec("cc", TINY))),
    ]
    pf = ["amc", "nextline2"]
    serial = Experiment(
        workloads=specs,
        prefetchers=pf,
        cache=WorkloadCache(artifacts=ArtifactCache(tmp_path / "serial")),
    ).run(workers=1)

    arts = ArtifactCache(tmp_path / "wl")
    piped = Experiment(
        workloads=specs, prefetchers=pf, cache=WorkloadCache(artifacts=arts)
    ).run(workers=2)
    assert rows_equal(serial.rows(), piped.rows())
    assert piped.trace_reuse == serial.trace_reuse == 1  # zero-churn epoch

    phased = Experiment(
        workloads=specs, prefetchers=pf, cache=WorkloadCache(artifacts=arts)
    ).run(workers=2, pipeline=False)
    assert rows_equal(serial.rows(), phased.rows())
    # Warm pooled rerun: every epoch comes from the content-keyed store.
    warm = Experiment(
        workloads=specs, prefetchers=pf, cache=WorkloadCache(artifacts=arts)
    ).run(workers=2)
    assert rows_equal(serial.rows(), warm.rows())
    assert warm.trace_reuse == 2


def test_materialize_pipeline_dedupes_in_flight_builds(tmp_path):
    """Identical-content epoch specs collapse to ONE pool build task."""
    spec = StreamSpec(kernel="pgd", dataset=TINY, churn=ZERO_CHURN, epochs=3)
    arts = ArtifactCache(tmp_path)
    pipe = scheduler.MaterializePipeline(
        spec.epoch_specs(), workers=2, artifacts=arts
    )
    try:
        assert pipe.n_specs == 3
        assert pipe.n_built == 1 and pipe.n_reused == 2
        for es in spec.epoch_specs():
            pipe.wait(es)
            assert arts.has(es)
    finally:
        pipe.close()
    # Fully warm: no pool at all, everything reused.
    warm = scheduler.MaterializePipeline(
        spec.epoch_specs(), workers=2, artifacts=arts
    )
    warm.close()
    assert warm.n_built == 0 and warm.n_reused == 3
    assert warm._stack is None  # no spawn pool was opened
