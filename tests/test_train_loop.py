"""End-to-end training-loop integration: the real launcher, few steps."""
import numpy as np
import pytest


@pytest.mark.slow
def test_train_launcher_loss_decreases_and_resumes(tmp_path):
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "ckpt")
    args = [
        "--arch", "smollm_360m", "--reduced",
        "--batch", "4", "--seq", "64",
        "--ckpt-dir", ckpt, "--ckpt-every", "10",
        "--log-every", "50",
    ]
    losses = train_main(args + ["--steps", "20"])
    assert losses[-1] < losses[0]
    # resume from step 20 and continue to 25
    losses2 = train_main(args + ["--steps", "25"])
    assert len(losses2) == 5  # resumed, not restarted
    assert np.isfinite(losses2).all()


@pytest.mark.slow
def test_serve_launcher_generates(tmp_path):
    from repro.launch.serve import main as serve_main

    gen = serve_main(
        ["--arch", "qwen3_4b", "--reduced", "--batch", "2",
         "--prompt-len", "4", "--gen", "4"]
    )
    assert gen.shape == (2, 4)
    assert np.isfinite(gen).all()
