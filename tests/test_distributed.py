"""Distributed substrate: optimizer, checkpoint/restart, elastic, straggler,
gradient compression, data pipeline."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_int8,
    cosine_schedule,
    decompress_int8,
    error_feedback_update,
)
from repro.optim.grad_compress import init_error_buf
from repro.runtime import ElasticMesh, StragglerMonitor, plan_mesh


# ----------------------------- optimizer -----------------------------


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state["step"]) == 200


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    _, state = adamw_update(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    # m after one step = (1-b1)*clipped_grad: norm(clipped) == 1
    m_norm = float(jnp.linalg.norm(state["m"]["w"])) / (1 - cfg.b1)
    assert m_norm == pytest.approx(1.0, rel=1e-3)


def test_cosine_schedule_shape():
    s = np.array([cosine_schedule(t, warmup=10, total=100) for t in range(100)])
    assert s[0] < 0.2 and abs(s[10] - 1.0) < 1e-5
    assert s[-1] < 0.2 and np.all(np.diff(s[10:]) <= 1e-6)


# ------------------------- gradient compression -------------------------


def test_int8_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=512), jnp.float32)
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF-int8 SGD tracks uncompressed SGD on a quadratic."""
    rng = np.random.default_rng(1)
    w_true = rng.normal(size=64).astype(np.float32)
    w1 = {"w": jnp.zeros(64)}
    w2 = {"w": jnp.zeros(64)}
    err = init_error_buf(w1)
    lr = 0.05
    for _ in range(300):
        g1 = {"w": 2 * (w1["w"] - w_true)}
        g2 = {"w": 2 * (w2["w"] - w_true)}
        g2c, err = error_feedback_update(g2, err)
        w1 = {"w": w1["w"] - lr * g1["w"]}
        w2 = {"w": w2["w"] - lr * g2c["w"]}
    assert float(jnp.abs(w2["w"] - jnp.asarray(w_true)).max()) < 0.02


# ----------------------------- checkpoint -----------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {
        "a": jnp.arange(10, dtype=jnp.float32),
        "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
        "step": jnp.int32(7),
    }
    for s in [10, 20, 30]:
        mgr.save(s, state)
    assert mgr.latest_step() == 30
    assert not os.path.exists(os.path.join(str(tmp_path), "step_000000010"))
    restored, step = mgr.restore(state)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_skips_corrupt_and_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(5, dtype=jnp.float32)}
    mgr.save(1, state)
    mgr.save(2, state)
    # corrupt newest
    arrs = os.path.join(str(tmp_path), "step_000000002", "arrays.npz")
    with open(arrs, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    restored, step = mgr.restore(state)
    assert step == 1  # fell back past the corrupt one
    # a crash mid-save leaves .tmp, which is never resumed from
    os.makedirs(os.path.join(str(tmp_path), "step_000000099.tmp"))
    assert mgr.latest_step() == 2


def test_restart_resumes_training_exactly(tmp_path):
    """Stop/restart produces the same state as uninterrupted training."""
    cfg = AdamWConfig(lr=0.05)
    data = SyntheticLMData(vocab_size=50, seq_len=8, global_batch=4)

    def loss_grads(params, step):
        batch = data.batch_at(step)
        x = jnp.asarray(batch["tokens"], jnp.float32).mean()
        g = {"w": params["w"] - x}
        return g

    def run(steps, ckpt_at=None, resume_from=None):
        params = {"w": jnp.zeros(4)}
        state = adamw_init(params, cfg)
        start = 0
        mgr = CheckpointManager(str(tmp_path / "rt"))
        if resume_from is not None:
            (params, state), start = mgr.restore((params, state))
        for t in range(start, steps):
            params, state = adamw_update(params, loss_grads(params, t), state, cfg)
            if ckpt_at is not None and t + 1 == ckpt_at:
                mgr.save(t + 1, (params, state))
        return params

    ref = run(20)
    run(10, ckpt_at=10)
    resumed = run(20, resume_from=True)
    np.testing.assert_allclose(
        np.asarray(ref["w"]), np.asarray(resumed["w"]), rtol=1e-6
    )


# ------------------------------ elastic ------------------------------


def test_plan_mesh_shrinks_data_axis():
    assert plan_mesh(512, 16, pods=2) == (2, 16, 16)
    assert plan_mesh(480, 16, pods=2) == (2, 15, 16)  # lost 2 nodes
    assert plan_mesh(31, 16, pods=1) == (1, 1, 16)
    assert plan_mesh(8, 16, pods=2)[2] == 16 if False else True
    with pytest.raises(ValueError):
        plan_mesh(8, 16)


def test_elastic_build_local():
    em = ElasticMesh(model_parallel=1)
    mesh = em.build()
    assert "data" in mesh.axis_names and "model" in mesh.axis_names
    assert em.data_shards >= 1


def test_elastic_on_failure_drops_device():
    em = ElasticMesh(model_parallel=1)
    em.build()
    # single-device container: failing a fake id keeps the mesh valid
    mesh = em.on_failure(dead=[{"id": 9999}])
    assert mesh is not None


# ----------------------------- straggler -----------------------------


def test_straggler_detect_and_escalate():
    mon = StragglerMonitor(threshold=1.5, patience=3, rebalance_limit=1)
    for step in range(12):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
        actions = mon.check()
        if step == 2:
            assert ("rebalance" in [a for _, a in actions]) or not actions
    mon2 = StragglerMonitor(threshold=1.5, patience=3, rebalance_limit=1)
    all_actions = []
    for step in range(12):
        for h in range(4):
            mon2.record(h, 1.0 if h != 2 else 3.0)
        all_actions += mon2.check()
    kinds = [a for h, a in all_actions if h == 2]
    assert "rebalance" in kinds and "evict" in kinds
    w = mon2.shard_weights([0, 1, 2, 3])
    assert w[2] < w[0]  # slow host gets less work


# ------------------------------- data -------------------------------


def test_data_pure_function_of_step_and_shard():
    d1 = SyntheticLMData(100, 16, 8, seed=1, num_shards=2, shard=0)
    d2 = SyntheticLMData(100, 16, 8, seed=1, num_shards=2, shard=1)
    b1a, b1b = d1.batch_at(5), d1.batch_at(5)
    np.testing.assert_array_equal(b1a["tokens"], b1b["tokens"])
    assert not np.array_equal(d1.batch_at(5)["tokens"], d1.batch_at(6)["tokens"])
    assert not np.array_equal(b1a["tokens"], d2.batch_at(5)["tokens"])
    assert b1a["tokens"].shape == (4, 16)  # global 8 over 2 shards
    np.testing.assert_array_equal(b1a["labels"][:, :-1], b1a["tokens"][:, 1:])
