"""Baseline prefetcher behaviors + workload driver invariants."""
import numpy as np
import pytest

from repro.core import build_workload, get_prefetcher
from repro.core.experiment import score_prefetcher
from repro.core.prefetchers import BASELINE_NAMES
from repro.core.prefetchers.simple import ideal_l2
from repro.core.prefetchers.spatial import _majority_table, _window_dedupe
from repro.core.prefetchers.temporal import _issue_with_hwm


@pytest.fixture(scope="module")
def workload():
    return build_workload("pgd", "comdblp")


def test_driver_invariants(workload):
    w = workload
    assert w.num_accesses > 10_000
    assert w.eval_from_pos == 0  # PGD evaluates the whole run
    assert len(w.iter_epochs) == len(set(e for e, _ in w.iter_epochs))
    mpos, mblocks, miters = w.baseline_miss_stream()
    assert np.all(np.diff(mpos) >= 0)
    assert len(mpos) < len(w.profile.l2_pos)
    views = w.amc_iteration_views()
    assert len(views) == len(w.iter_epochs)
    t_lo = w.cfg_trace.target_range[0] >> 6
    t_hi = (w.cfg_trace.target_range[0] + w.cfg_trace.target_range[1]) >> 6
    for view, _ in views:
        # target-range misses excluded from recording input
        assert not np.any((view.miss_blocks >= t_lo) & (view.miss_blocks <= t_hi))
        assert np.all(np.diff(view.target_pos) > 0)


def test_bfs_workload_evaluates_second_run():
    w = build_workload("bfs", "comdblp")
    assert w.eval_from_pos > 0
    epochs = [e for e, _ in w.iter_epochs]
    assert set(epochs) == {0, 1}
    # within-epoch indices restart at run 2
    within = [k for _, k in w.iter_epochs]
    assert within.count(0) == 2


def test_ideal_prefetcher_dominates(workload):
    m = score_prefetcher(workload, "ideal", ideal_l2)
    assert m.coverage > 0.9 and m.accuracy > 0.9 and m.speedup > 1.2


def test_all_baselines_produce_valid_streams(workload):
    for name in BASELINE_NAMES:
        gen = get_prefetcher(name).instantiate()
        stream = gen(workload)
        assert len(stream.blocks) == len(stream.pos), name
        if len(stream.pos):
            assert stream.pos.min() >= 0, name
            assert stream.blocks.min() >= 0, name


def test_hwm_dedupe():
    lo, counts = _issue_with_hwm(np.array([0, 1, 2, 10]), degree=4, stream_len=20)
    # trigger 0 issues 1..4; trigger 1 issues 5 only; trigger 2 issues 6;
    # trigger 10 issues 11..14
    np.testing.assert_array_equal(counts, [4, 1, 1, 4])
    np.testing.assert_array_equal(lo, [1, 5, 6, 11])


def test_window_dedupe():
    blocks = np.array([5, 5, 5, 9])
    pos = np.array([0, 10, 5000, 20])
    keep = _window_dedupe(blocks, pos, window=100)
    np.testing.assert_array_equal(keep, [True, False, True, True])


def test_majority_table():
    keys = np.array([1, 1, 1, 2, 2, 3])
    nxt = np.array([7, 7, 8, 9, 9, 5])
    k, v = _majority_table(keys, nxt)
    np.testing.assert_array_equal(k, [1, 2, 3])
    assert v[0] == 7 and v[1] == 9 and v[2] == 5


def test_rnr_records_once_amc_rerecords():
    """The core AMC-vs-RnR distinction on an evolving workload."""
    from repro.core.amc import AMCConfig, AMCPrefetcher
    from repro.core.prefetchers.rnr import rnr

    w = build_workload("pgd", "comdblp")
    amc = score_prefetcher(w, "amc", AMCPrefetcher(AMCConfig()).generate)
    rnr_m = score_prefetcher(w, "rnr", rnr)
    assert amc.coverage > 2 * rnr_m.coverage
