"""Perf-trajectory scorecard: BENCH stage rollup + optional roofline delta.

    PYTHONPATH=src python -m benchmarks.perf_report

Reads every dated ``BENCH_*.json`` at the repo root and rolls the stage
timers up into one trajectory table — one column per run, one row per
stage key.  The rollup takes the UNION of stage keys found in the
documents, discovered generically: every ``stages_s`` dict anywhere in a
document (the top-level pipeline breakdown including nested
cache-pass/score dicts, and each subsystem section — stream, serve per
tenant count, sharded) is flattened under its path prefix.  A stage
added by a newer schema therefore shows up without this script needing
to learn the section, and older documents that predate a stage show an
explicit ``n/a`` instead of being silently dropped or rendered as an
ambiguous dash.

If ``results/roofline_baseline.json`` exists (snapshot taken before the
§5 perf iterations), the report also re-derives the current roofline and
appends the baseline-vs-final delta table; without the baseline the
roofline section is skipped with a note rather than crashing.

Writes ``results/perf_report.md`` and prints it.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def flatten_stages(doc: dict) -> dict:
    """One BENCH document -> flat {stage_key: seconds}.

    Discovers every ``stages_s`` dict anywhere in the document and
    flattens its numeric subtree under the path it was found at, so a
    subsystem section added by a newer schema (stream in v3, serve in
    v5, sharded in v6, ...) contributes its stage keys without this
    function enumerating the sections.
    """
    flat: dict = {}

    def emit(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                emit(f"{prefix}.{k}" if prefix else k, v)
        elif isinstance(node, (int, float)):
            flat[prefix] = float(node)

    def prefix_of(path):
        # serve.by_tenants.<K> keeps its historical serve[K=<K>] label so
        # trajectory rows line up across schema versions.
        if len(path) >= 3 and path[-2] == "by_tenants":
            return ".".join(path[:-2]) + f"[K={path[-1]}]"
        return ".".join(path)

    def find(path, node):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            if k == "stages_s":
                emit(prefix_of(path), v)
            else:
                find(path + (k,), v)

    find((), doc)
    return flat


def bench_sort_key(path: str) -> tuple:
    """Chronological sort key for ``BENCH_<date>[.N].json`` paths.

    Lexicographic sorting breaks at the 10th run of a date —
    ``BENCH_x.10.json`` sorts before ``BENCH_x.2.json`` — so the numeric
    suffix is compared as an int.  The bare ``BENCH_<date>.json`` is run
    1 of its date.  Names that don't parse sort last, lexicographically.
    """
    name = os.path.basename(path)[len("BENCH_") : -len(".json")]
    date, _, suffix = name.partition(".")
    try:
        return (0, date, int(suffix) if suffix else 1, "")
    except ValueError:
        return (1, date, 0, suffix)


def bench_trajectory(root: str = ".") -> tuple[list, list, list, list]:
    """(run labels, union of stage keys, per-run flat dicts, raw docs)."""
    labels, flats, docs = [], [], []
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")), key=bench_sort_key)
    for path in paths:
        try:
            doc = json.load(open(path))
        except (OSError, json.JSONDecodeError) as e:
            print(f"[perf_report] skipping unreadable {path}: {e}", file=sys.stderr)
            continue
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        labels.append(name + (" (smoke)" if doc.get("smoke") else ""))
        flats.append(flatten_stages(doc))
        docs.append(doc)
    keys: list = []
    for flat in flats:  # union, first-seen order
        for k in flat:
            if k not in keys:
                keys.append(k)
    return labels, keys, flats, docs


def rollup_markdown(labels, keys, flats) -> str:
    lines = [
        "| stage | " + " | ".join(labels) + " |",
        "|---|" + "---|" * len(labels),
    ]
    for k in keys:
        # "n/a" marks a run whose schema predates this stage key — the
        # stage was not measured, as opposed to measuring zero seconds.
        cells = [
            f"{flat[k]:.3f}" if k in flat else "n/a" for flat in flats
        ]
        lines.append(f"| {k} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def scheduler_markdown(labels, docs) -> str:
    """Schema v7 rollup: per run, the cost model's resolved decision and
    the delta-aware reuse-hit ratio of the zero-churn stream cell (reused
    epochs / total epochs; warm runs reuse every epoch).  Runs predating
    v7 show ``n/a`` — the sections were not measured."""
    lines = [
        "| run | sched mode | workers | reuse cold | reuse warm | "
        "warm hit ratio |",
        "|---|---|---|---|---|---|",
    ]
    for label, doc in zip(labels, docs):
        decision = ((doc.get("scheduler") or {}).get("auto") or {}).get(
            "decision"
        ) or {}
        reuse = (doc.get("stream") or {}).get("reuse") or {}
        hits = reuse.get("trace_reuse") or {}
        epochs = reuse.get("epochs")
        ratio = (
            f"{hits['warm'] / epochs:.2f}"
            if isinstance(hits.get("warm"), int) and epochs
            else "n/a"
        )
        lines.append(
            f"| {label} | {decision.get('mode', 'n/a')} | "
            f"{decision.get('workers', 'n/a')} | {hits.get('cold', 'n/a')} | "
            f"{hits.get('warm', 'n/a')} | {ratio} |"
        )
    return "\n".join(lines)


def roofline_section() -> str:
    from repro.launch import roofline

    rows = roofline.table()
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)

    baseline_path = "results/roofline_baseline.json"
    if not os.path.exists(baseline_path):
        return (
            "# Roofline\n\n"
            f"(no {baseline_path} snapshot — delta table skipped; current "
            "model written to results/roofline.json)\n\n"
            + roofline.markdown(rows)
        )

    base = {(r["arch"], r["shape"]): r for r in json.load(open(baseline_path))}
    cur = {(r["arch"], r["shape"]): r for r in rows}
    lines = [
        "# Roofline — final (post §5 perf iterations), 16x16 single-pod\n",
        roofline.markdown(rows),
        "\n\n# Delta vs baseline (dominant-term seconds)\n",
        "| cell | baseline dominant | final dominant | reduction |",
        "|---|---|---|---|",
    ]
    for key in sorted(cur):
        if key not in base:
            continue
        b, c = base[key], cur[key]
        bt = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ct = max(c["compute_s"], c["memory_s"], c["collective_s"])
        red = bt / max(ct, 1e-12)
        lines.append(
            f"| {key[0]}/{key[1]} | {b['dominant']} {bt:.3e} | "
            f"{c['dominant']} {ct:.3e} | {red:.2f}x |"
        )
    return "\n".join(lines)


def main():
    sys.path.insert(0, "src")

    sections = []
    labels, keys, flats, docs = bench_trajectory()
    if labels:
        sections.append(
            "# BENCH stage trajectory (seconds per run)\n\n"
            + rollup_markdown(labels, keys, flats)
        )
        sections.append(
            "# Scheduler decisions and delta-aware reuse\n\n"
            + scheduler_markdown(labels, docs)
        )
    else:
        sections.append("# BENCH stage trajectory\n\n(no BENCH_*.json found)")
    sections.append(roofline_section())

    out = "\n\n".join(sections) + "\n"
    os.makedirs("results", exist_ok=True)
    with open("results/perf_report.md", "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
