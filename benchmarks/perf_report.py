"""Perf-iteration scorecard: baseline vs final roofline, per cell.

    PYTHONPATH=src python -m benchmarks.perf_report

Reads results/roofline_baseline.json (snapshot taken before the §5 perf
iterations) and the current dry-run/probe artifacts, writes
results/roofline_final.md with both tables + the delta table.
"""
from __future__ import annotations

import json
import sys


def main():
    sys.path.insert(0, "src")
    from repro.launch import roofline

    rows = roofline.table()
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)

    base = {
        (r["arch"], r["shape"]): r
        for r in json.load(open("results/roofline_baseline.json"))
    }
    cur = {(r["arch"], r["shape"]): r for r in rows}

    lines = [
        "# Roofline — final (post §5 perf iterations), 16x16 single-pod\n",
        roofline.markdown(rows),
        "\n\n# Delta vs baseline (dominant-term seconds)\n",
        "| cell | baseline dominant | final dominant | reduction |",
        "|---|---|---|---|",
    ]
    for key in sorted(cur):
        if key not in base:
            continue
        b, c = base[key], cur[key]
        bt = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ct = max(c["compute_s"], c["memory_s"], c["collective_s"])
        red = bt / max(ct, 1e-12)
        lines.append(
            f"| {key[0]}/{key[1]} | {b['dominant']} {bt:.3e} | "
            f"{c['dominant']} {ct:.3e} | {red:.2f}x |"
        )
    out = "\n".join(lines)
    with open("results/roofline_final.md", "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
