"""Perf-trajectory benchmark harness for the experiment execution engine.

Times the pipeline stages (trace generation with the ``trace_emit``
sub-stage, demand simulation with per-level ``cache_pass[l1|l2|llc]``
breakdown, per-prefetcher scoring), the end-to-end evaluation grid —
serial with a cold workload-artifact cache, then at each ``--workers``
count against the warm cache — and a small 3-epoch evolving-graph stream
cell with the stream-protocol stage breakdown (``update_apply``,
``trace_epoch``, ``table_carry``) and its own serial-vs-parallel parity
gate, and emits a schema-stable ``BENCH_<date>.json`` at the repo root
(never clobbering an existing file: reruns on the same date get a ``.2``,
``.3``, ... infix so the trajectory keeps its before/after points).

Schema v4 adds the trace-emitter section: a full-workload rebuild under
the per-iteration *reference* emitter gated bit-identical against the
batched whole-run emitter, an emission micro-bench over representative
runs (including the long-horizon ``tinyroad`` traversal where the batched
pass wins hardest), and a ``bfs_do`` (direction-optimizing BFS) cell in
the full grid so pull-mode traces ride the whole pipeline.

Schema v5 adds the serving-subsystem section: K in {1, 4} concurrent
tenants (mixed kernels x seeds on ``tiny``) interleaved over one shared
LLC with both AMC table modes, reporting a queries/sec throughput cell
(K tenants / warm wall-clock at the fixed hierarchy), the serving stage
breakdown (``serve_interleave`` / ``serve_llc`` / ``serve_score``), and a
serial-vs-workers parity gate wired into the exit code like the
grid/stream gates.

Schema v6 adds the sharded paper-scale section: the ``ShardedSpec``
streaming-scoring path is parity-gated bit-identical against the unsharded
``score_prefetcher`` rows on a real cell, and (full mode) a peak-RSS gauge
scores the ~8.5M-edge ``road-8m`` cell and the ``comdblp`` cell in fresh
child interpreters at the same shard size, asserting the two peaks agree
within 10% — i.e. streaming memory is flat in trace length (32.5M vs 118k
accesses).  Both children run against the shared persistent XLA
compilation cache (warmed by one discarded run) so the gauge measures
streaming state, not one-time compile transients.

Schema v7 adds the scheduler section: the cost-aware ``workers=None``
default is run against the warm cache (its :class:`SchedDecision` record
is committed with the JSON, and a not-slower-than-``workers=1`` gate
keeps the auto path honest), and a cold A/B pits the cost-aware
pipelined schedule against the legacy phased ``workers=2`` schedule on
fresh artifact dirs — both parity-gated against serial.  The stream
section gains a zero-churn reuse cell exercising delta-aware epoch trace
reuse (content-keyed epochs: unchanged graphs are cache hits, counted by
``trace_reuse``) with a bit-identical reuse-vs-re-emission gate, plus the
``pipeline_overlap`` stage from the overlapped epoch handoff.

Schema v8 adds the telemetry section (``docs/OBSERVABILITY.md``): the
scheduler's auto warm run executes under a cross-process span tracer, and
the committed document carries the run manifest (git sha, resolved
engine/emitter, schema versions, SchedDecision), the merged metrics
registry snapshot (cache hit/build counters, per-stage latency
histograms), and the merged span-trace summary covering parent and
worker processes.  ``tools/bench_diff.py`` gates CI on consecutive
documents; ``tools/trace_export.py`` renders traces for Perfetto.

Schema v9 adds the fused hierarchy-engine section: the default ``fused``
engine runs L1→L2→LLC demand simulation as ONE carried set-parallel scan
(per-access hit levels, no inter-level host round trips; a cost-based
plan chooser keeps short or run-light streams on the bit-identical
cascade) and batches the per-prefetcher scoring passes of one workload
into one vmapped launch per level, so the stage breakdown's
``cache_pass`` dict carries one ``fused`` key per fused-engine demand
walk (the always-zero ``score_cache_pass[l1]`` key is gone; only stages
that actually ran are emitted — ``tools/bench_diff.py`` aliases the
fused key to the sum of its per-level predecessors across the
transition).  The section runs a compile-warmed demand+score A/B of the
fused path against the per-level ``set_parallel`` cascade on the stage
cell — the committed ``speedup`` is the ratio of engine-attributable
seconds (the ``demand_sim`` stage plus the scoring ``cache_pass[*]``
stages; stream generation and the shared host-side outcome analysis are
engine-independent) — reports the wall times and fused launch counters
alongside, and gates the exit code on fused-vs-reference bit identity
(hit masks + scored rows, batched and looped).

The dated JSONs accumulate as the repo's machine-readable perf trajectory;
CI runs ``--smoke`` (1 kernel x 1 dataset x 3 prefetchers) on every push,
uploads the JSON as a build artifact, and fails this script (exit 1) when
the grid errors, parallel results diverge from serial, the set-parallel
cache engine diverges from the serial ``lax.scan`` reference, the batched
trace emitter diverges from the per-iteration reference, the sharded
streaming scorer diverges from the unsharded path, or (full mode) the
sharded peak-RSS gauge is not flat.

Usage:
    PYTHONPATH=src python -m benchmarks.bench [--smoke]
        [--kernels pgd,cc] [--datasets comdblp] [--prefetchers amc,vldp,rnr]
        [--workers 1,2,4] [--out-dir .] [--cache-dir DIR]
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import sys
import tempfile
import time
from datetime import date
from functools import partial
from pathlib import Path

sys.path.insert(0, "src")

SCHEMA_VERSION = 9

# Three prefetchers spanning the suite's families: the paper's contribution
# (amc), a spatial baseline (vldp), and a replay baseline (rnr).  The
# per-prefetcher stage section and the CI smoke grid time all three; the
# full grid scores the two cheap ones so its cell cost stays dominated by
# trace construction, like a real sweep's.
PREFETCHERS = ["amc", "vldp", "rnr"]
GRID_PREFETCHERS = ["amc", "rnr"]
SMOKE_CELLS = [("pgd", "comdblp", 0)]
# The streaming-subsystem cell (schema v3): a 3-epoch sliding-window
# stream, timed for its own stages (update_apply / trace_epoch /
# table_carry) and parity-gated serial vs workers=2.
STREAM_EPOCHS = 3
STREAM_PREFETCHERS = ["amc", "nextline2"]
# The serving-subsystem cells (schema v5): K concurrent query tenants on
# the tiny dataset — mixed kernels and seeds so shared-table aliasing has
# cross-tenant material — timed cold and warm (queries/sec = K / warm
# seconds at the fixed SCALED hierarchy) and parity-gated serial vs
# workers=2.
SERVE_TENANT_COUNTS = [1, 4]
SERVE_TENANTS = [
    ("pgd", "tiny", 0),
    ("cc", "tiny", 0),
    ("pgd", "tiny", 1),
    ("cc", "tiny", 1),
]
SERVE_PREFETCHERS = ["amc", "nextline2"]
# (kernel, dataset, seed) cells on comdblp, both app protocols.  The
# seed-varied bfs/bellmanford cells are distinct evolving-graph trials
# (each seed draws a different §VI run1->run2 evolution), and their
# two-run builds dominate their cell cost — the proportions of a real
# sweep, where trace construction is the bulk of a cold grid.
FULL_CELLS = [
    ("pgd", "comdblp", 0),
    ("cc", "comdblp", 0),
    ("bfs", "comdblp", 0),
    ("bfs", "comdblp", 1),
    ("bfs", "comdblp", 2),
    ("bellmanford", "comdblp", 0),
    ("bellmanford", "comdblp", 1),
    ("bellmanford", "comdblp", 2),
    # Schema v4: direction-optimizing BFS — dense (pull) middle levels
    # emit the in-edge/source-gather pattern through the full pipeline.
    ("bfs_do", "comdblp", 0),
]
# Emission micro-bench runs (schema v4): kernel runs re-emitted under both
# emitters.  bfs/tinyroad is the long-horizon case (hundreds of small
# frontiers — per-iteration overhead dominates the reference emitter);
# pgd_pull/comdblp replays the dense body every iteration.
EMITTER_MICRO = [("bfs", "tinyroad"), ("pgd_pull", "comdblp")]
# Sharded paper-scale section (schema v6).  The parity sub-gate scores a
# real cell through the ShardedSpec streaming path at a shard size small
# enough to force many seams and compares rows bit-for-bit against the
# unsharded path.  The RSS gauge scores the two cells below — 275x apart
# in trace length — in fresh child interpreters at the same shard size and
# requires their ru_maxrss peaks to agree within SHARD_RSS_TOL.
SHARD_PREFETCHERS = ["amc", "nextline2"]
SHARD_PARITY_ACCESSES = 1 << 14
SHARD_GAUGE_ACCESSES = 1 << 16
SHARD_RSS_CELLS = [("bfs", "comdblp", 0), ("bfs", "road-8m", 0)]
SHARD_RSS_TOL = 0.10
# Scheduler section (schema v7).  The auto (workers=None) warm run must
# not lose to the pinned workers=1 reference beyond measurement noise,
# and the cost-aware cold schedule must not lose to the legacy phased
# workers=2 schedule it replaced (the BENCH_2026-08-07 inversion).
SCHED_AUTO_TOL = 1.10
SCHED_COLD_TOL = 1.05


def _sharded_child(argv) -> int:
    """Hidden ``--_score-sharded`` re-exec target for the peak-RSS gauge.

    Scores one pre-materialized sharded cell with the cheap ``nextline2``
    prefetcher in this (fresh) interpreter and reports its own peak RSS
    as JSON on stdout.  The JAX persistent-compilation-cache env vars are
    inherited from the parent bench process, so a warmed cache makes the
    child's peak free of compile-time transients.

    The peak is read from ``/proc/self/status`` ``VmHWM``, which execve
    resets to this process's own image — ``getrusage``'s ``ru_maxrss``
    would instead inherit the high-water mark of the (large) parent bench
    process across fork/exec and report the parent's peak, not ours.
    """
    kernel, dataset, seed, shard_accesses, cache_dir = argv

    from repro.core import WorkloadSpec
    from repro.core.exec.artifacts import ArtifactCache
    from repro.core.exec.sharded import ShardedSpec, score_sharded
    from repro.core.registry import resolve_prefetchers

    spec = ShardedSpec(
        base=WorkloadSpec(kernel, dataset, seed=int(seed)),
        shard_accesses=int(shard_accesses),
    )
    cache = ArtifactCache(cache_dir)
    manifest = cache.load_manifest(spec)
    assert manifest is not None, "gauge cell must be pre-materialized"
    t0 = time.perf_counter()
    scored = score_sharded(spec, resolve_prefetchers(["nextline2"]), cache)
    dt = time.perf_counter() - t0

    def _peak_kb() -> int:
        try:
            with open("/proc/self/status") as f:
                for line in f:
                    if line.startswith("VmHWM:"):
                        return int(line.split()[1])
        except OSError:
            pass
        import resource  # non-Linux fallback (fork-inheritance caveat)

        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    json.dump(
        {
            "maxrss_kb": _peak_kb(),
            "score_s": round(dt, 2),
            "accesses": int(manifest["num_accesses"]),
            "shards": len(manifest["shard_sizes"]),
            "speedup": {n: round(m.speedup, 4) for n, m in scored},
        },
        sys.stdout,
    )
    print()
    return 0


def _gauge_child_run(kernel, dataset, seed, shard_accesses, cache_dir):
    """Run the hidden gauge mode in a fresh interpreter; parse its JSON."""
    import subprocess

    root = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [
            sys.executable,
            str(Path(__file__).resolve()),
            "--_score-sharded",
            kernel,
            dataset,
            str(seed),
            str(shard_accesses),
            cache_dir,
        ],
        cwd=root,
        capture_output=True,
        text=True,
        check=True,
    )
    return json.loads(proc.stdout)


def _grid_seconds(specs, pairs, cache_dir, workers, pipeline=True):
    """Wall-clock one full grid evaluation; returns (seconds, result)."""
    from repro.core import Experiment, WorkloadCache
    from repro.core.exec.artifacts import ArtifactCache

    cache = WorkloadCache(artifacts=ArtifactCache(cache_dir))
    exp = Experiment(workloads=specs, prefetchers=pairs, cache=cache)
    t0 = time.perf_counter()
    # Baselines and parity gates pin workers explicitly (workers=1 is the
    # serial reference path); only the scheduler section passes
    # workers=None to measure the cost model's own choice.
    result = exp.run(workers=workers, pipeline=pipeline)
    return time.perf_counter() - t0, result


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "--_score-sharded":
        return _sharded_child(argv[1:])
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny CI grid (1 kernel x 1 dataset x 3 prefetchers)",
    )
    ap.add_argument("--kernels", default=None, help="comma list (default: per mode)")
    ap.add_argument("--datasets", default=None, help="comma list (default: per mode)")
    ap.add_argument(
        "--prefetchers", default=None, help="comma list (default: per mode)"
    )
    ap.add_argument("--workers", default="1,2,4", help="comma list of pool sizes")
    ap.add_argument("--out-dir", default=".", help="where BENCH_<date>.json lands")
    ap.add_argument(
        "--cache-dir",
        default=None,
        help="workload artifact cache root (default: fresh temp dir, removed "
        "after the run, so the serial baseline is guaranteed cold)",
    )
    args = ap.parse_args(argv)

    # One persistent JAX compilation cache shared by this process and every
    # spawned worker (the scheduler exports a pre-set dir to its children):
    # the untimed stage phase below warms it, so no timed measurement pays
    # for XLA compiles.  Must be set before the first jax import.
    cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="repro-bench-cache-")
    own_cache_dir = args.cache_dir is None
    os.environ.setdefault(
        "JAX_COMPILATION_CACHE_DIR", os.path.join(cache_dir, "jax-cache")
    )
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")

    from repro.core import WorkloadSpec
    from repro.core.exec.scheduler import rows_equal
    from repro.core.exec.timers import collect_stages, stage, time_s
    from repro.core.experiment import score_prefetcher
    from repro.core.registry import resolve_prefetchers
    from repro.memsim import current_engine, simulate_demand, use_engine

    if args.kernels or args.datasets:
        default = SMOKE_CELLS if args.smoke else FULL_CELLS
        if args.kernels:
            kernels = args.kernels.split(",")
        else:
            kernels = sorted({k for k, _, _ in default})
        if args.datasets:
            datasets = args.datasets.split(",")
        else:
            datasets = sorted({d for _, d, _ in default})
        cells = [(k, d, 0) for k in kernels for d in datasets]
    else:
        cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    if args.prefetchers:
        names = args.prefetchers.split(",")
    else:
        names = PREFETCHERS if args.smoke else GRID_PREFETCHERS
    workers_list = [int(w) for w in args.workers.split(",")]

    specs = [WorkloadSpec(k, d, seed=s) for k, d, s in cells]
    pairs = resolve_prefetchers(names)
    stage_names = args.prefetchers.split(",") if args.prefetchers else PREFETCHERS

    # --- pipeline stage breakdown (one cold build; also warms JAX/XLA —
    # compiles land in the shared persistent cache, so neither the serial
    # baseline nor any worker pays for them inside a timed region).
    print(f"[bench] stages: building {specs[0].kernel}/{specs[0].dataset} cold")
    with collect_stages() as stages:
        trace = specs[0].build()
    score_s = {}
    score_stages: dict = {}
    for name, gen in resolve_prefetchers(stage_names):
        with collect_stages(into=score_stages):
            score_s[name] = time_s(partial(score_prefetcher, trace, name, gen))
        print(f"[bench] score {name}: {score_s[name]:.2f}s")

    def _level_times(d):
        # Only stages that actually ran: the fused engine (default) emits
        # one cache_pass[fused] stage per hierarchy walk, the per-level
        # engines emit l1/l2/llc — schema v9 drops the always-zero keys
        # (notably score_cache_pass[l1]; scoring never touches L1).
        return {
            lvl: d[f"cache_pass[{lvl}]"]
            for lvl in ("l1", "l2", "llc", "fused")
            if f"cache_pass[{lvl}]" in d
        }

    # --- trace-emitter gate + micro-bench (schema v4): the batched
    # whole-run emitter must be bit-identical to the per-iteration
    # reference on a full workload build, and the emission micro cases
    # time both emitters over the same app runs.
    import numpy as np

    from repro.apps import get_kernel
    from repro.apps.trace import TraceConfig, trace_run, use_emitter
    from repro.graphs import make_dataset

    ref_stages: dict = {}
    with collect_stages(into=ref_stages), use_emitter("reference"):
        ref_trace = specs[0].build()
    emitter_ok = all(
        np.array_equal(getattr(trace, f), getattr(ref_trace, f))
        for f in ("block", "array_id", "elem", "iter_id", "epoch_id")
    )
    print(
        f"[bench] trace emitter batched vs reference: "
        f"{'ok' if emitter_ok else 'DIVERGED'} "
        f"(trace_emit {stages.get('trace_emit', 0.0):.3f}s vs "
        f"{ref_stages.get('trace_emit', 0.0):.3f}s)"
    )
    if not emitter_ok:
        print(
            "[bench] EMITTER FAILURE: batched whole-run emission diverges "
            "from the per-iteration reference",
            file=sys.stderr,
        )
    del ref_trace

    emitter_micro = []
    for mk, md in EMITTER_MICRO:
        ks = get_kernel(mk)
        g = make_dataset(md, weighted=ks.weighted)
        run = ks.run(g)
        cfg = TraceConfig(g.num_vertices, g.num_edges)
        accesses = len(trace_run(run, cfg))
        sample = {}
        for emitter in ("batched", "reference"):
            with use_emitter(emitter):
                trace_run(run, cfg)  # warm (pull-body caches)
                sample[emitter] = time_s(
                    partial(trace_run, run, cfg), repeats=5
                )
        emitter_micro.append(
            {
                "workload": f"{mk}/{md}",
                "iters": run.num_iters,
                "accesses": accesses,
                "batched_s": sample["batched"],
                "reference_s": sample["reference"],
                "speedup": sample["reference"] / sample["batched"]
                if sample["batched"] > 0
                else float("inf"),
            }
        )
        print(
            f"[bench] emit {mk}/{md} ({run.num_iters} iters): "
            f"batched {sample['batched']:.4f}s vs reference "
            f"{sample['reference']:.4f}s "
            f"(x{emitter_micro[-1]['speedup']:.1f})"
        )

    # --- engine/reference divergence gate: the set-parallel engine's hit
    # masks and one scored cell must be bit-identical to the serial scan.
    engine = current_engine()
    engine_ok = True
    if engine != "reference":
        blocks, iters, cfg = trace.block, trace.iter_id, trace.spec.hierarchy
        prof = trace.profile
        with use_engine("reference"):
            ref_prof = simulate_demand(blocks, iters, cfg)
            pname, pgen = resolve_prefetchers(stage_names[:1])[0]
            ref_row = score_prefetcher(trace, pname, pgen).row()
        eng_row = score_prefetcher(trace, pname, pgen).row()
        engine_ok = bool(
            np.array_equal(prof.l1_hit, ref_prof.l1_hit)
            and np.array_equal(prof.l2_hit, ref_prof.l2_hit)
            and np.array_equal(prof.llc_hit, ref_prof.llc_hit)
        ) and rows_equal([eng_row], [ref_row])
        print(
            f"[bench] engine {engine} vs reference: "
            f"{'ok' if engine_ok else 'DIVERGED'}"
        )
        if not engine_ok:
            print(
                f"[bench] ENGINE FAILURE: {engine} diverges from the "
                "serial lax.scan reference",
                file=sys.stderr,
            )

    # --- fused hierarchy engine (schema v9): compile-warmed demand+score
    # A/B of the fused path (one L1→L2→LLC carried scan per demand walk,
    # one vmapped launch per level for the scored prefetcher family)
    # against the per-level set_parallel cascade, on the stage cell.
    # Both sides run once untimed first so the comparison measures steady
    # state, not per-shape XLA compiles.  The committed speedup is the
    # ratio of engine-attributable seconds — the demand_sim stage plus
    # the scoring cache_pass[*] stages; prefetch-stream generation and
    # the host-side outcome analysis are engine-independent and would
    # only dilute the ratio toward 1.  Bit identity is gated into the
    # exit code: the fused profile masks and scored rows — batched AND
    # looped — must equal the per-level engine's, which the engine gate
    # above ties to the serial reference oracle.
    from repro.core.experiment import score_prefetchers_batched
    from repro.core.obs import spans as obs

    stage_pairs = resolve_prefetchers(stage_names)
    blocks, iters, cfg = trace.block, trace.iter_id, trace.spec.hierarchy
    rows_box: dict = {}

    def _demand():
        with stage("demand_sim"):
            return simulate_demand(blocks, iters, cfg)

    def _score_loop():
        rows_box["loop"] = [
            score_prefetcher(trace, n_, g_).row() for n_, g_ in stage_pairs
        ]

    def _score_batched():
        rows_box["batched"] = [
            m.row() for m in score_prefetchers_batched(trace, stage_pairs)
        ]

    def _engine_seconds(d):
        # demand_sim already contains its nested cache_pass[*] stages
        # (stage timers accumulate flat, so both keys cover the same
        # seconds) — summing both would double-count the demand walk.
        if "demand_sim" in d:
            return d["demand_sim"]
        return sum(v for k, v in d.items() if k.startswith("cache_pass["))

    def _timed_stages(fn):
        d: dict = {}
        t0 = time.perf_counter()
        with collect_stages(into=d):
            fn()
        return time.perf_counter() - t0, d

    with use_engine("set_parallel"):
        _demand(), _score_loop()  # warm per-shape compiles untimed
        pl_demand_w, pl_demand_stages = _timed_stages(_demand)
        pl_score_w, pl_score_stages = _timed_stages(_score_loop)
        pl_rows = rows_box["loop"]
    pl_demand_s = _engine_seconds(pl_demand_stages)
    pl_score_s = _engine_seconds(pl_score_stages)
    with use_engine("fused"):
        _demand(), _score_batched()  # warm per-shape compiles untimed
        # the metrics registry opens after the warm-up, so the committed
        # launch counters cover exactly one timed demand+score pass
        with obs.metrics_registry() as fused_metrics:
            fu_demand_w, fu_demand_stages = _timed_stages(_demand)
            fu_score_w, fu_score_stages = _timed_stages(_score_batched)
        fu_batch_rows = rows_box["batched"]
        _score_loop()
        fu_loop_rows = rows_box["loop"]
        fu_prof = simulate_demand(blocks, iters, cfg)
    fu_demand_s = _engine_seconds(fu_demand_stages)
    fu_score_s = _engine_seconds(fu_score_stages)
    fused_speedup = (pl_demand_s + pl_score_s) / max(
        fu_demand_s + fu_score_s, 1e-9
    )
    if engine == "fused":
        # The engine gate above already compared the fused engine (the
        # session default, used to build `trace`) against the reference.
        fused_vs_ref = engine_ok
    else:
        with use_engine("reference"):
            fr_prof = simulate_demand(blocks, iters, cfg)
            fr_row = score_prefetcher(trace, *stage_pairs[0]).row()
        with use_engine("fused"):
            ff_row = score_prefetcher(trace, *stage_pairs[0]).row()
        fused_vs_ref = bool(
            np.array_equal(fu_prof.l1_hit, fr_prof.l1_hit)
            and np.array_equal(fu_prof.l2_hit, fr_prof.l2_hit)
            and np.array_equal(fu_prof.llc_hit, fr_prof.llc_hit)
        ) and rows_equal([ff_row], [fr_row])
    fused_ok = (
        fused_vs_ref
        and rows_equal(pl_rows, fu_loop_rows)
        and rows_equal(pl_rows, fu_batch_rows)
    )
    print(
        f"[bench] fused demand+score engine-s: "
        f"{fu_demand_s + fu_score_s:.2f}s vs per-level "
        f"{pl_demand_s + pl_score_s:.2f}s (x{fused_speedup:.2f}, wall "
        f"{fu_demand_w + fu_score_w:.2f}s vs "
        f"{pl_demand_w + pl_score_w:.2f}s, "
        f"identity {'ok' if fused_ok else 'DIVERGED'}, "
        f"launches {fused_metrics.counter('fused.launches'):.0f}, "
        f"batched streams "
        f"{fused_metrics.counter('fused.batched_streams'):.0f})"
    )
    if not fused_ok:
        print(
            "[bench] FUSED FAILURE: fused hierarchy engine diverges from "
            "the per-level/reference path",
            file=sys.stderr,
        )
    del trace

    # --- end-to-end grid wall-clock: serial cold, then warm cache per pool.
    parity = True
    try:
        serial_cold_s, serial_result = _grid_seconds(specs, pairs, cache_dir, 1)
        serial_rows = serial_result.rows()
        print(f"[bench] grid serial cold: {serial_cold_s:.1f}s")

        warm = {}
        for w in workers_list:
            seconds, result = _grid_seconds(specs, pairs, cache_dir, w)
            warm[str(w)] = seconds
            same = rows_equal(serial_rows, result.rows())
            parity = parity and same
            print(
                f"[bench] grid workers={w} warm: {seconds:.1f}s "
                f"(x{serial_cold_s / seconds:.1f} vs serial cold, "
                f"parity {'ok' if same else 'FAILED'})"
            )
            if not same:
                print(
                    f"[bench] PARITY FAILURE: workers={w} results diverge "
                    "from serial",
                    file=sys.stderr,
                )

        # --- scheduler (schema v7): the cost-aware workers=None default,
        # measured warm against the pinned workers=1 reference, then a
        # cold A/B of the cost-aware schedule vs the legacy phased
        # workers=2 schedule on fresh artifact dirs.  The committed
        # SchedDecision documents *why* this host went serial or parallel.
        # Schema v8: the auto warm run executes under a cross-process span
        # tracer — workers append spans to per-pid JSONL files under the
        # trace dir, the parent merges them, and the merged summary +
        # metrics snapshot + run manifest are committed below.
        from repro.core.obs import spans as obs

        sched_stages: dict = {}
        trace_dir = tempfile.mkdtemp(prefix="repro-bench-trace-")
        try:
            with obs.trace(dir=trace_dir) as tracer:
                with collect_stages(into=sched_stages):
                    auto_warm_s, auto_result = _grid_seconds(
                        specs, pairs, cache_dir, None
                    )
            auto_run_trace = tracer.result
        finally:
            shutil.rmtree(trace_dir, ignore_errors=True)
        auto_parity = rows_equal(serial_rows, auto_result.rows())
        parity = parity and auto_parity
        warm1 = warm.get("1")
        auto_not_slower = (
            True if warm1 is None else auto_warm_s <= warm1 * SCHED_AUTO_TOL
        )
        auto_sched = auto_result.sched or {}
        print(
            f"[bench] sched auto warm: {auto_warm_s:.1f}s "
            f"(mode {auto_sched.get('mode')}, "
            f"workers {auto_sched.get('workers')}, "
            f"parity {'ok' if auto_parity else 'FAILED'})"
        )
        if not auto_parity:
            print(
                "[bench] PARITY FAILURE: workers=None results diverge "
                "from serial",
                file=sys.stderr,
            )
        if not auto_not_slower:
            print(
                f"[bench] SCHED FAILURE: auto warm {auto_warm_s:.1f}s is "
                f"slower than workers=1 warm {warm1:.1f}s "
                f"(tolerance x{SCHED_AUTO_TOL})",
                file=sys.stderr,
            )

        cold_ab = {}
        for label, ab_workers, ab_pipe in (
            ("auto_pipelined", None, True),
            ("phased_workers2", 2, False),
        ):
            ab_dir = tempfile.mkdtemp(prefix="repro-bench-ab-")
            try:
                ab_s, ab_result = _grid_seconds(
                    specs, pairs, ab_dir, ab_workers, pipeline=ab_pipe
                )
            finally:
                shutil.rmtree(ab_dir, ignore_errors=True)
            ab_same = rows_equal(serial_rows, ab_result.rows())
            parity = parity and ab_same
            cold_ab[label] = {"wallclock_s": ab_s, "parity": ab_same}
            if ab_result.sched is not None:
                cold_ab[label]["decision"] = ab_result.sched
            print(
                f"[bench] sched cold A/B {label}: {ab_s:.1f}s "
                f"(parity {'ok' if ab_same else 'FAILED'})"
            )
            if not ab_same:
                print(
                    f"[bench] PARITY FAILURE: cold {label} results diverge "
                    "from serial",
                    file=sys.stderr,
                )
        cold_not_slower = (
            cold_ab["auto_pipelined"]["wallclock_s"]
            <= cold_ab["phased_workers2"]["wallclock_s"] * SCHED_COLD_TOL
        )
        if not cold_not_slower:
            print(
                "[bench] SCHED FAILURE: cost-aware cold schedule lost to "
                "the legacy phased workers=2 schedule "
                f"(tolerance x{SCHED_COLD_TOL})",
                file=sys.stderr,
            )

        # --- streaming subsystem (schema v3): one small multi-epoch
        # stream cell, with the stream-protocol stage breakdown and a
        # serial-vs-parallel parity gate of its own.
        from repro.stream import SlidingWindow, StreamSpec

        stream_spec = StreamSpec(
            "pgd", "comdblp", SlidingWindow(), epochs=STREAM_EPOCHS
        )
        stream_pairs = resolve_prefetchers(STREAM_PREFETCHERS)
        print(
            f"[bench] stream: {STREAM_EPOCHS}-epoch sliding-window "
            f"{stream_spec.kernel}/{stream_spec.dataset} cold"
        )
        stream_stages: dict = {}
        with collect_stages(into=stream_stages):
            stream_cold_s, stream_result = _grid_seconds(
                [stream_spec], stream_pairs, cache_dir, 1
            )
        stream_rows = stream_result.rows()
        print(f"[bench] stream serial cold: {stream_cold_s:.1f}s")
        stream_par_stages: dict = {}
        with collect_stages(into=stream_par_stages):
            stream_warm_s, stream_par = _grid_seconds(
                [stream_spec], stream_pairs, cache_dir, 2
            )
        stream_parity = rows_equal(stream_rows, stream_par.rows())
        parity = parity and stream_parity
        print(
            f"[bench] stream workers=2 warm: {stream_warm_s:.1f}s "
            f"(parity {'ok' if stream_parity else 'FAILED'}, overlap "
            f"{stream_par_stages.get('pipeline_overlap', 0.0):.2f}s)"
        )
        if not stream_parity:
            print(
                "[bench] PARITY FAILURE: stream workers=2 results diverge "
                "from serial",
                file=sys.stderr,
            )

        # --- delta-aware epoch trace reuse (schema v7): a zero-churn
        # stream's epochs share one content key, so the cold run emits
        # epoch 0 once and serves epochs 1..E-1 from the artifact cache
        # (trace_reuse counts them); a warm rerun reuses every epoch.
        # The reused trace must be bit-identical to a from-scratch
        # re-emission of the same epoch.
        from repro.core import WorkloadCache
        from repro.stream import UniformChurn

        reuse_spec = StreamSpec(
            "pgd",
            "comdblp",
            UniformChurn(init_frac=1.0, del_frac=0.0, add_frac=0.0),
            epochs=STREAM_EPOCHS,
        )
        print(
            f"[bench] stream reuse: zero-churn {STREAM_EPOCHS}-epoch "
            f"{reuse_spec.kernel}/{reuse_spec.dataset} cold"
        )
        reuse_cold_s, reuse_cold = _grid_seconds(
            [reuse_spec], stream_pairs, cache_dir, 1
        )
        reuse_warm_s, reuse_warm = _grid_seconds(
            [reuse_spec], stream_pairs, cache_dir, 1
        )
        reuse_counts_ok = (
            reuse_cold.trace_reuse == STREAM_EPOCHS - 1
            and reuse_warm.trace_reuse == STREAM_EPOCHS
        )
        from repro.core.exec.artifacts import ArtifactCache as _AC

        last_epoch = reuse_spec.epoch_specs()[-1]
        reused_trace = WorkloadCache(artifacts=_AC(cache_dir)).get_or_build(
            last_epoch
        )
        fresh_trace = last_epoch.build()
        reuse_bits_ok = all(
            np.array_equal(getattr(reused_trace, f), getattr(fresh_trace, f))
            for f in (
                "block",
                "array_id",
                "elem",
                "iter_id",
                "epoch_id",
                "nl_blocks",
                "nl_pos",
            )
        )
        del reused_trace, fresh_trace
        reuse_ok = reuse_counts_ok and reuse_bits_ok
        print(
            f"[bench] stream reuse: cold {reuse_cold_s:.1f}s "
            f"(trace_reuse {reuse_cold.trace_reuse}) warm {reuse_warm_s:.1f}s "
            f"(trace_reuse {reuse_warm.trace_reuse}), reuse-vs-re-emission "
            f"{'ok' if reuse_bits_ok else 'DIVERGED'}"
        )
        if not reuse_ok:
            print(
                "[bench] REUSE FAILURE: delta-aware epoch reuse diverges "
                "from re-emission or miscounts cache hits",
                file=sys.stderr,
            )

        # --- serving subsystem (schema v5): K concurrent tenants on one
        # shared LLC, throughput (queries/sec) + a parity gate of its own.
        from repro.serve import ServeSpec, TenantSpec

        serve_pairs = resolve_prefetchers(SERVE_PREFETCHERS)
        serve_by_tenants = {}
        for n_tenants in SERVE_TENANT_COUNTS:
            tenants = tuple(
                TenantSpec(k, d, seed=s)
                for k, d, s in SERVE_TENANTS[:n_tenants]
            )
            serve_spec = ServeSpec(tenants=tenants)
            print(f"[bench] serve: K={n_tenants} tenants on tiny, cold")
            serve_stages: dict = {}
            with collect_stages(into=serve_stages):
                serve_cold_s, serve_result = _grid_seconds(
                    [serve_spec], serve_pairs, cache_dir, 1
                )
            serve_rows = serve_result.rows()
            serve_warm_s, _ = _grid_seconds(
                [serve_spec], serve_pairs, cache_dir, 1
            )
            _, serve_par = _grid_seconds(
                [serve_spec], serve_pairs, cache_dir, 2
            )
            serve_same = rows_equal(serve_rows, serve_par.rows())
            parity = parity and serve_same
            qps = n_tenants / serve_warm_s if serve_warm_s > 0 else 0.0
            print(
                f"[bench] serve K={n_tenants}: cold {serve_cold_s:.1f}s "
                f"warm {serve_warm_s:.1f}s ({qps:.2f} queries/s, "
                f"parity {'ok' if serve_same else 'FAILED'})"
            )
            if not serve_same:
                print(
                    f"[bench] PARITY FAILURE: serve K={n_tenants} workers=2 "
                    "results diverge from serial",
                    file=sys.stderr,
                )
            serve_by_tenants[str(n_tenants)] = {
                "tenants": [
                    f"{k}/{d}#s{s}" for k, d, s in SERVE_TENANTS[:n_tenants]
                ],
                "stages_s": {
                    "serve_interleave": serve_stages.get("serve_interleave", 0.0),
                    "serve_llc": serve_stages.get("serve_llc", 0.0),
                    "serve_score": serve_stages.get("serve_score", 0.0),
                },
                "wallclock_s": {
                    "serial_cold": serve_cold_s,
                    "warm_serial": serve_warm_s,
                },
                "queries_per_s": qps,
                "parallel_matches_serial": serve_same,
            }

        # --- sharded paper-scale subsystem (schema v6): the streaming
        # scorer must be bit-identical to the unsharded path, and (full
        # mode) peak RSS must be flat in trace length.
        from repro.core.exec.artifacts import ArtifactCache
        from repro.core.exec.sharded import (
            ShardedSpec,
            ensure_shards,
            score_sharded,
        )

        acache = ArtifactCache(cache_dir)
        par_kernel, par_dataset = (
            ("bfs", "tiny") if args.smoke else ("bfs", "comdblp")
        )
        par_base = WorkloadSpec(par_kernel, par_dataset, seed=0)
        shard_pairs = resolve_prefetchers(SHARD_PREFETCHERS)
        print(
            f"[bench] sharded parity: {par_kernel}/{par_dataset} at "
            f"shard_accesses={SHARD_PARITY_ACCESSES}"
        )
        shard_stages: dict = {}
        with collect_stages(into=shard_stages):
            t0 = time.perf_counter()
            sh_scored = score_sharded(
                ShardedSpec(
                    base=par_base, shard_accesses=SHARD_PARITY_ACCESSES
                ),
                shard_pairs,
                acache,
            )
            shard_score_s = time.perf_counter() - t0
        par_trace = par_base.build()
        un_rows = [
            score_prefetcher(par_trace, n, g).row() for n, g in shard_pairs
        ]
        del par_trace
        sharded_parity = rows_equal(un_rows, [m.row() for _, m in sh_scored])
        parity = parity and sharded_parity
        print(
            f"[bench] sharded vs unsharded rows: "
            f"{'ok' if sharded_parity else 'DIVERGED'} "
            f"({shard_score_s:.1f}s sharded)"
        )
        if not sharded_parity:
            print(
                "[bench] PARITY FAILURE: sharded streaming scoring diverges "
                "from the unsharded path",
                file=sys.stderr,
            )

        shard_rss = None
        rss_flat = True
        if not args.smoke:
            gauge = {}
            for gk, gd, gs in SHARD_RSS_CELLS:
                gspec = ShardedSpec(
                    base=WorkloadSpec(gk, gd, seed=gs),
                    shard_accesses=SHARD_GAUGE_ACCESSES,
                )
                t0 = time.perf_counter()
                ensure_shards(gspec, acache)
                mat_s = time.perf_counter() - t0
                gauge[gd] = {"kernel": gk, "materialize_s": round(mat_s, 2)}
                print(f"[bench] sharded gauge: {gk}/{gd} built {mat_s:.1f}s")
            # One discarded warm-up run per cell lands every shard-shape's
            # XLA compiles in the shared persistent compilation cache —
            # including each cell's unique remainder-shard bucket — so the
            # measured children pay zero compile-time memory spikes and
            # the gauge compares streaming-state footprints only.
            for gk, gd, gs in SHARD_RSS_CELLS:
                _gauge_child_run(gk, gd, gs, SHARD_GAUGE_ACCESSES, cache_dir)
            for gk, gd, gs in SHARD_RSS_CELLS:
                rep = _gauge_child_run(
                    gk, gd, gs, SHARD_GAUGE_ACCESSES, cache_dir
                )
                gauge[gd].update(rep)
                print(
                    f"[bench] sharded gauge: {gk}/{gd} "
                    f"{rep['accesses']} accesses / {rep['shards']} shards: "
                    f"peak {rep['maxrss_kb']} KiB ({rep['score_s']:.1f}s)"
                )
            ratio = (
                gauge["road-8m"]["maxrss_kb"] / gauge["comdblp"]["maxrss_kb"]
            )
            rss_flat = abs(ratio - 1.0) <= SHARD_RSS_TOL
            shard_rss = {
                "cells": gauge,
                "ratio_vs_comdblp": round(ratio, 4),
                "tolerance": SHARD_RSS_TOL,
                "flat": rss_flat,
            }
            print(
                f"[bench] sharded gauge: peak-RSS ratio {ratio:.3f} "
                f"({'flat' if rss_flat else 'NOT FLAT'} within "
                f"{SHARD_RSS_TOL:.0%})"
            )
            if not rss_flat:
                print(
                    "[bench] RSS FAILURE: sharded scoring peak RSS grows "
                    "with trace length",
                    file=sys.stderr,
                )
    finally:
        if own_cache_dir:
            shutil.rmtree(cache_dir, ignore_errors=True)

    out = {
        "schema": SCHEMA_VERSION,
        "date": date.today().isoformat(),
        "smoke": args.smoke,
        "host": {
            "python": platform.python_version(),
            "platform": platform.platform(),
            "cpus": os.cpu_count(),
        },
        "grid": {
            "workloads": [f"{k}/{d}#s{s}" for k, d, s in cells],
            "prefetchers": names,
            "cells": len(specs) * len(names),
        },
        "cache_engine": engine,
        "stages_s": {
            "trace_gen": stages.get("trace_gen", 0.0),
            "trace_emit": stages.get("trace_emit", 0.0),
            "demand_sim": stages.get("demand_sim", 0.0),
            "cache_pass": _level_times(stages),
            "score": score_s,
            "score_cache_pass": _level_times(score_stages),
        },
        # Schema v4: batched whole-run emission vs the per-iteration
        # reference — full-build stage times, parity, and the micro cases.
        "trace_emitter": {
            "rebuild_reference_s": {
                "trace_gen": ref_stages.get("trace_gen", 0.0),
                "trace_emit": ref_stages.get("trace_emit", 0.0),
            },
            "micro": emitter_micro,
        },
        # Schema v9: the fused hierarchy engine — compile-warmed
        # demand+score A/B against the per-level set_parallel cascade on
        # the stage cell.  ``speedup`` is the engine-attributable ratio
        # (demand_sim stage + scoring cache_pass[*] stages; generation
        # and shared host analysis excluded), ``wall_s`` the raw wall
        # clocks of the same timed runs; launch counters cover exactly
        # the timed fused pass, and the bit-identity verdict is gated
        # into the exit code.
        "fused": {
            "cell": f"{cells[0][0]}/{cells[0][1]}#s{cells[0][2]}",
            "prefetchers": stage_names,
            "per_level_s": {"demand_sim": pl_demand_s, "score": pl_score_s},
            "fused_s": {"demand_sim": fu_demand_s, "score": fu_score_s},
            "wall_s": {
                "per_level": {"demand_sim": pl_demand_w, "score": pl_score_w},
                "fused": {"demand_sim": fu_demand_w, "score": fu_score_w},
            },
            "speedup": fused_speedup,
            "launches": fused_metrics.counter("fused.launches"),
            "batched_streams": fused_metrics.counter("fused.batched_streams"),
            "matches_reference": fused_ok,
        },
        "wallclock_s": {"serial_cold": serial_cold_s, "warm_by_workers": warm},
        "speedup_vs_serial_cold": {
            w: serial_cold_s / s for w, s in warm.items() if s > 0
        },
        # Schema v7: the cost-aware scheduler — the committed decision
        # record for this host, the auto-vs-workers=1 warm gate, and the
        # cold A/B against the legacy phased schedule.
        "scheduler": {
            "auto": {
                "decision": auto_result.sched,
                "warm_wallclock_s": auto_warm_s,
                "warm_workers1_s": warm1,
                "not_slower_than_workers1": auto_not_slower,
                "tolerance": SCHED_AUTO_TOL,
            },
            "cold_ab": {
                **cold_ab,
                "pipelined_not_slower": cold_not_slower,
                "tolerance": SCHED_COLD_TOL,
            },
            "stages_s": dict(sorted(sched_stages.items())),
        },
        # Schema v8: structured run telemetry from the auto warm run —
        # the run manifest (provenance), the merged metrics registry
        # snapshot, and the merged parent+worker span-trace summary.
        "telemetry": {
            "manifest": (auto_result.telemetry or {}).get("manifest"),
            "workload_cache": (auto_result.telemetry or {}).get(
                "workload_cache"
            ),
            "metrics": auto_run_trace.metrics,
            "trace": auto_run_trace.summary(),
        },
        # Schema v3: the streaming-subsystem cell (3-epoch sliding-window
        # stream) with the stream-protocol stage timers.
        "stream": {
            "kernel": stream_spec.kernel,
            "dataset": stream_spec.dataset,
            "epochs": STREAM_EPOCHS,
            "churn": "sliding_window",
            "prefetchers": STREAM_PREFETCHERS,
            "stages_s": {
                "update_apply": stream_stages.get("update_apply", 0.0),
                "trace_epoch": stream_stages.get("trace_epoch", 0.0),
                "table_carry": stream_stages.get("table_carry", 0.0),
                "pipeline_overlap": stream_par_stages.get(
                    "pipeline_overlap", 0.0
                ),
            },
            "wallclock_s": {
                "serial_cold": stream_cold_s,
                "warm_workers2": stream_warm_s,
            },
            "parallel_matches_serial": stream_parity,
            # Schema v7: delta-aware epoch trace reuse (zero-churn cell).
            "reuse": {
                "churn": "zero_churn",
                "epochs": STREAM_EPOCHS,
                "wallclock_s": {
                    "serial_cold": reuse_cold_s,
                    "warm_serial": reuse_warm_s,
                },
                "trace_reuse": {
                    "cold": reuse_cold.trace_reuse,
                    "warm": reuse_warm.trace_reuse,
                },
                "counts_expected": reuse_counts_ok,
                "matches_reemission": reuse_bits_ok,
            },
        },
        # Schema v5: the serving-subsystem cells (K concurrent tenants
        # over one shared LLC, both AMC table modes) with the serving
        # stage timers and the queries/sec throughput figure.
        "serve": {
            "dataset": "tiny",
            "policy": "round_robin",
            "table_modes": ["per_tenant", "shared"],
            "prefetchers": SERVE_PREFETCHERS,
            "by_tenants": serve_by_tenants,
        },
        # Schema v6: the sharded paper-scale subsystem — streaming-scoring
        # parity vs the unsharded path, the streaming stage timers, and
        # (full mode) the peak-RSS flatness gauge.
        "sharded": {
            "prefetchers": SHARD_PREFETCHERS,
            "parity_cell": f"{par_kernel}/{par_dataset}#s0",
            "parity_shard_accesses": SHARD_PARITY_ACCESSES,
            "parity_matches_unsharded": sharded_parity,
            "score_s": shard_score_s,
            "stages_s": dict(sorted(shard_stages.items())),
            "gauge_shard_accesses": SHARD_GAUGE_ACCESSES,
            "rss": shard_rss,
        },
        "parallel_matches_serial": parity,
        "engine_matches_reference": engine_ok,
        "fused_matches_reference": fused_ok,
        "emitter_matches_reference": emitter_ok,
        "sharded_rss_flat": rss_flat,
        "sched_auto_not_slower": auto_not_slower,
        "sched_cold_pipelined_not_slower": cold_not_slower,
        "trace_reuse_matches_reemission": reuse_ok,
    }
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"BENCH_{out['date']}.json"
    n = 2
    while out_path.exists():
        # Keep earlier same-day runs: they are the "before" points of the
        # perf trajectory.
        out_path = out_dir / f"BENCH_{out['date']}.{n}.json"
        n += 1
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"[bench] wrote {out_path}")
    return (
        0
        if (
            parity
            and engine_ok
            and fused_ok
            and emitter_ok
            and rss_flat
            and auto_not_slower
            and cold_not_slower
            and reuse_ok
        )
        else 1
    )


if __name__ == "__main__":
    raise SystemExit(main())
