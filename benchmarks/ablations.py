"""AMC design-space ablations (beyond the paper's single configuration).

Sweeps the four design knobs the paper fixes and measures their effect on
one representative workload — the sensitivity analysis a deployment would
run before committing silicon parameters:

  - max_misses_per_entry (paper: 20, Fig 16)
  - lookahead_accesses   (paper: implicit via frontier buffer depth)
  - storage_fraction     (paper: 20% reserve, §IV-A)
  - match_pairs          (strict (prev,cur) CAM match vs trigger-only)

All variants run in one declarative ``Experiment`` against a single cached
workload build; the build persists in the workload artifact cache, so
re-running after the sweep (or a previous ablation) skips it entirely, and
``--workers N`` shards the variants across a process pool.

    PYTHONPATH=src python -m benchmarks.ablations [--dataset comdblp] [--workers 4]
"""
from __future__ import annotations

import argparse
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="pgd")
    ap.add_argument("--dataset", default="comdblp")
    ap.add_argument("--out", default="results/ablations.json")
    ap.add_argument(
        "--workers", type=int, default=1,
        help="process-parallel scoring of the AMC variants (1 = serial)",
    )
    ap.add_argument(
        "--cache-dir", default=None,
        help="workload artifact cache root (default: $REPRO_WORKLOAD_CACHE "
        "or ~/.cache/repro-amc/workloads)",
    )
    args = ap.parse_args()

    from repro.core import Experiment, WorkloadCache, WorkloadSpec, get_prefetcher
    from repro.core.exec.artifacts import ArtifactCache

    base = dict(
        max_misses_per_entry=20,
        lookahead_accesses=90,
        storage_fraction=0.5,
        match_pairs=False,
    )
    sweeps = {
        "max_misses_per_entry": [5, 10, 20, 40],
        "lookahead_accesses": [10, 30, 90, 300, 1200],
        "storage_fraction": [0.1, 0.25, 0.5, 1.0],
        "match_pairs": [False, True],
    }
    # One declarative experiment: the workload is built once (cached) and
    # every AMC variant is instantiated from the registry with overrides.
    amc = get_prefetcher("amc")
    variants = []
    for knob, values in sweeps.items():
        for v in values:
            name = f"amc[{knob}={v}]"
            variants.append((knob, v, name, amc.instantiate(name=name, **{**base, knob: v})))
    result = Experiment(
        workloads=[WorkloadSpec(args.kernel, args.dataset)],
        prefetchers=[(name, gen) for _, _, name, gen in variants],
        cache=WorkloadCache(artifacts=ArtifactCache(args.cache_dir)),
    ).run(  # incremental progress; detailed rows printed below
        verbose=True, workers=args.workers
    )
    w = result.workload(args.kernel, args.dataset)

    rows = []
    for knob, v, name, _ in variants:
        m = result.metrics(prefetcher=name)
        row = dict(
            knob=knob,
            value=v,
            speedup=round(m.speedup, 3),
            coverage=round(m.coverage, 3),
            accuracy=round(m.accuracy, 3),
            late=m.late,
            evicted_early=m.evicted_early,
            metadata_traffic=round(m.metadata_traffic, 3),
            storage_peak_frac=round(
                m.info.get("storage_peak_bytes", 0) / w.input_bytes, 3
            ),
        )
        rows.append(row)
        print(
            f"{knob}={v!s:>6}: speedup {row['speedup']:.2f} "
            f"cov {row['coverage']:.2f} acc {row['accuracy']:.2f} "
            f"late {row['late']} meta {row['metadata_traffic']:.2f}"
        )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"workload": f"{args.kernel}/{args.dataset}", "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()
