"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints one CSV block per paper table/figure (name,us_per_call,derived) plus
kernel micro-benchmarks. Heavy sweep data comes from cached JSONs
(benchmarks/sweep.py, repro.launch.dryrun) — run those first for the full
report; this entry point stays fast.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import numpy as np

# perf_counter-based timing shared with benchmarks/bench.py — time.time()
# has coarse, non-monotonic ticks that make microsecond numbers meaningless.
from repro.core.exec.timers import time_us as _time_us


def kernel_bench():
    """Kernel micro-benches (interpret on CPU; TPU is the target)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attn.ops import mha
    from repro.kernels.amc_gather.amc_gather import amc_gather
    from repro.kernels.basedelta.basedelta import basedelta_compress_tiles
    from repro.kernels.ssd_scan.ssd_scan import ssd_scan
    from repro.memsim import cache_pass, use_engine

    rows = []
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 256, 4, 64), jnp.float32)
    k = jax.random.normal(key, (2, 256, 2, 64), jnp.float32)
    rows.append(
        ("flash_attn_interp_2x256x4x64",
         _time_us(lambda: np.asarray(mha(q, k, k, interpret=True))), "")
    )
    table = jax.random.normal(key, (1024, 128), jnp.float32)
    idx = jnp.arange(512, dtype=jnp.int32) % 1024
    rows.append(
        ("amc_gather_interp_512x128",
         _time_us(lambda: np.asarray(amc_gather(table, idx, interpret=True))), "")
    )
    tiles = jnp.asarray(
        np.random.default_rng(0).integers(0, 1 << 20, (64, 32)), jnp.int32
    )
    counts = jnp.full((64,), 20, jnp.int32)
    rows.append(
        ("basedelta_interp_64x32",
         _time_us(lambda: [np.asarray(x) for x in basedelta_compress_tiles(tiles, counts, interpret=True)]), "")
    )
    x = jax.random.normal(key, (4, 128, 32), jnp.float32)
    dt = jnp.full((4, 128), 0.5, jnp.float32)
    a = jnp.full((4,), -1.0, jnp.float32)
    b = jax.random.normal(key, (4, 128, 16), jnp.float32)
    rows.append(
        ("ssd_scan_interp_4x128x32",
         _time_us(lambda: np.asarray(ssd_scan(x, dt, a, b, b, chunk=32, interpret=True))), "")
    )
    blocks = np.random.default_rng(0).integers(0, 4096, 1_000_000).astype(np.int64)
    us = _time_us(lambda: cache_pass(blocks, 64, 8), repeats=2)
    rows.append(
        ("cache_pass_1M_accesses", us, f"{1e6 / (us / 1e6) / 1e6:.1f}M acc/s")
    )
    with use_engine("reference"):
        ref_us = _time_us(lambda: cache_pass(blocks, 64, 8), repeats=2)
    rows.append(
        ("cache_pass_ref_1M_accesses", ref_us, f"engine x{ref_us / us:.1f}")
    )
    return rows


def main() -> None:
    from benchmarks import figures

    data = figures.load()
    print("name,us_per_call,derived")

    if not data:
        print("sweep_missing,0,run benchmarks.sweep first")
    else:
        for name, fn in [
            ("fig8_speedup", figures.fig8_speedup),
            ("fig9_coverage", figures.fig9_coverage),
            ("fig10_accuracy", figures.fig10_accuracy),
            ("fig11_timeliness", figures.fig11_timeliness),
            ("fig12_13_traffic", figures.fig12_13_traffic),
            ("fig15_storage", figures.fig15_storage),
            ("fig16_miss_size", figures.fig16_miss_size),
            ("compression_ratio", figures.compression_stats),
        ]:
            t0 = time.perf_counter()
            headers, rows, derived = fn(data)
            us = (time.perf_counter() - t0) * 1e6
            key_items = ";".join(f"{k}={v:.3f}" for k, v in list(derived.items())[:6])
            print(f"{name},{us:.0f},{key_items}")
        figures.table8_storage()
        print("table8_storage,0,static accounting (see EXPERIMENTS.md)")

    # subsystem figures from their own results-dir schemas
    for name, loader, fn in [
        ("fig_drift", figures.load_streams, figures.fig_drift),
        ("fig_contention", figures.load_serves, figures.fig_contention),
        ("fig_stages", figures.load_bench, figures.fig_stages),
    ]:
        docs = loader()
        if not docs:
            print(f"{name},0,no results (run the matching example first)")
            continue
        t0 = time.perf_counter()
        headers, rows, derived = fn(docs)
        us = (time.perf_counter() - t0) * 1e6
        key_items = ";".join(
            f"{k}={v:.3f}" for k, v in list(derived.items())[:6]
        )
        print(f"{name},{us:.0f},{key_items}")

    # roofline summary from dry-run cells
    try:
        from repro.launch import roofline

        rows = roofline.table()
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            best = max(rows, key=lambda r: r["roofline_fraction"])
            print(
                f"roofline,0,cells={len(rows)};best={best['arch']}/{best['shape']}"
                f"={best['roofline_fraction']:.2f};worst={worst['arch']}/"
                f"{worst['shape']}={worst['roofline_fraction']:.2f}"
            )
    except Exception as e:  # noqa: BLE001
        print(f"roofline,0,unavailable ({e})")

    for name, us, derived in kernel_bench():
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
