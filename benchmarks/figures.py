"""Paper-figure benchmark modules: assemble Figs 8-16 + Table VIII from the
sweep JSONs (benchmarks/sweep.py) — one function per paper table/figure.

Each returns (headers, rows) and a dict of derived headline numbers used by
EXPERIMENTS.md's validation table.
"""
from __future__ import annotations

import glob
import json
import os
import warnings
from collections import defaultdict

import numpy as np

PF_ORDER = ["amc", "vldp", "bingo", "isb", "misb", "rnr", "ideal"]

# Results-dir schemas with a dedicated loader: load() skips them silently
# (they are someone else's territory, not an anomaly worth a warning).
KNOWN_SCHEMAS = {
    "stream-drift": "load_streams/fig_drift",
    "serve-contention": "load_serves/fig_contention",
    # Telemetry artifacts (docs/OBSERVABILITY.md): merged span traces and
    # their Chrome trace-event exports (tools/trace_export.py).
    "run-trace": "repro.core.obs.RunTrace/tools/trace_export.py",
    "chrome-trace": "tools/trace_export.py (load in Perfetto)",
}


def load(results_dir: str = "results"):
    """Per-workload sweep JSONs, keyed by (kernel, dataset).

    The results directory also accumulates stream-drift and
    serve-contention artifacts (each with its own loader — see
    ``KNOWN_SCHEMAS``); those are skipped silently.  Anything *else* that
    is skipped — corrupt JSON, unknown schema, non-sweep document — gets a
    warning instead of silence, so a typo'd results file does not quietly
    vanish from every figure.
    """
    out = {}
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        if os.path.basename(f).startswith(("roofline", "perf")):
            continue  # perf-trajectory artifacts, never sweep documents
        try:
            with open(f) as fh:
                r = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            warnings.warn(f"figures.load: skipping unreadable {f}: {e}")
            continue
        if isinstance(r, dict) and r.get("schema") in KNOWN_SCHEMAS:
            continue  # another loader's schema (see KNOWN_SCHEMAS)
        if (
            not isinstance(r, dict)
            or "kernel" not in r
            or not isinstance(r.get("prefetchers"), dict)
        ):
            what = (
                r.get("schema") if isinstance(r, dict) else type(r).__name__
            )
            warnings.warn(
                f"figures.load: skipping {f}: not a per-workload sweep "
                f"document (schema={what!r})"
            )
            continue
        out[(r["kernel"], r["dataset"])] = r
    return out


def load_streams(results_dir: str = "results"):
    """Stream-drift JSONs (repro.stream.protocol.drift_payload documents),
    keyed by (kernel, dataset, churn kind, lifecycle)."""
    out = {}
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        try:
            r = json.load(open(f))
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(r, dict) or r.get("schema") != "stream-drift":
            continue
        key = (
            r["kernel"],
            r["dataset"],
            r.get("churn", {}).get("kind", "?"),
            r.get("lifecycle", "?"),
        )
        out[key] = r
    return out


def fig_drift(streams):
    """Per-epoch accuracy/coverage drift curves per prefetcher (stream
    protocol) — the evolving-graph scenario engine's headline figure."""
    headers = [
        "stream",
        "prefetcher",
        "lifecycle",
        "coverage_by_epoch",
        "accuracy_by_epoch",
        "tail_mean_coverage",
        "tail_mean_accuracy",
        "cumulative_overlap",
    ]
    rows = []
    derived = {}
    for (k, d, churn, lifecycle), r in sorted(streams.items()):
        overlap = [round(v, 3) for v in r["overlap"]["cumulative_overlap"]]
        for pf, doc in sorted(r["prefetchers"].items()):
            s = doc["summary"]
            rows.append(
                [
                    f"{k}/{d}[{churn}]",
                    pf,
                    doc.get("lifecycle") or "-",
                    [round(v, 3) for v in s["coverage"]],
                    [round(v, 3) for v in s["accuracy"]],
                    round(s["tail_mean_coverage"], 3),
                    round(s["tail_mean_accuracy"], 3),
                    overlap,
                ]
            )
            if doc.get("lifecycle"):
                derived[
                    f"tail_mean_coverage/{k}/{d}/{churn}/{pf}[{doc['lifecycle']}]"
                ] = s["tail_mean_coverage"]
    # The headline comparison: does carrying the tables beat cold tables?
    persist = [v for key, v in derived.items() if key.endswith("[persist]")]
    reset = [v for key, v in derived.items() if key.endswith("[reset]")]
    if persist and reset:
        derived["persist_minus_reset_tail_coverage"] = float(
            np.mean(persist) - np.mean(reset)
        )
    return headers, rows, derived


def load_serves(results_dir: str = "results"):
    """Serve-contention JSONs (repro.serve.protocol.contention_payload
    documents), keyed by (tenant summary, policy)."""
    out = {}
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        try:
            with open(f) as fh:
                r = json.load(fh)
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(r, dict) or r.get("schema") != "serve-contention":
            continue
        tenants = "+".join(
            f"{t['kernel']}/{t['dataset']}#s{t['seed']}" for t in r["tenants"]
        )
        out[(tenants, r.get("policy", "?"))] = r
    return out


def fig_contention(serves):
    """Per-tenant accuracy/coverage under shared-LLC interleaving, per
    table mode — the serving subsystem's headline figure: how far the
    shared AMC table falls below per-tenant provisioning (the paper's
    correlation-aliasing failure mode at serving scale)."""
    headers = [
        "scenario",
        "prefetcher",
        "table_mode",
        "coverage_by_tenant",
        "accuracy_by_tenant",
        "mean_coverage",
        "mean_accuracy",
        "aliased_hits",
        "cross_tenant_overwrites",
        "llc_hits_lost",
    ]
    rows = []
    derived = {}
    for (tenants, policy), r in sorted(serves.items()):
        # Tenant mix in the label: same-K scenarios must not collide.
        scenario = f"K={r['num_tenants']}[{policy}]{tenants}"
        for pf, modes in sorted(r["prefetchers"].items()):
            for mode, doc in sorted(modes.items()):
                t_rows = doc["per_tenant_rows"]
                serve_infos = [t.get("serve") or {} for t in t_rows]
                st = [s.get("shared_table", {}) for s in serve_infos]
                rows.append(
                    [
                        scenario,
                        pf,
                        mode,
                        [round(t["coverage"], 3) for t in t_rows],
                        [round(t["accuracy"], 3) for t in t_rows],
                        round(doc["mean_coverage"], 3),
                        round(doc["mean_accuracy"], 3),
                        sum(s.get("aliased_hits", 0) for s in st),
                        st[0].get("cross_tenant_overwrites", 0) if st else 0,
                        sum(
                            s.get("llc_demand_hits_lost", 0)
                            + s.get("llc_pf_hits_lost", 0)
                            for s in serve_infos
                        ),
                    ]
                )
                derived[f"mean_coverage/{scenario}/{pf}[{mode}]"] = doc[
                    "mean_coverage"
                ]
                derived[f"mean_accuracy/{scenario}/{pf}[{mode}]"] = doc[
                    "mean_accuracy"
                ]
        # The headline: per-tenant minus shared, per prefetcher with both.
        for pf, modes in r["prefetchers"].items():
            if "per_tenant" in modes and "shared" in modes:
                derived[f"table_isolation_coverage_gain/{scenario}/{pf}"] = (
                    modes["per_tenant"]["mean_coverage"]
                    - modes["shared"]["mean_coverage"]
                )
                derived[f"table_isolation_accuracy_gain/{scenario}/{pf}"] = (
                    modes["per_tenant"]["mean_accuracy"]
                    - modes["shared"]["mean_accuracy"]
                )
    return headers, rows, derived


def load_bench(root: str = "."):
    """The BENCH_*.json perf trajectory, chronologically ordered.

    Returns ``{"labels", "keys", "flats", "docs"}`` from
    ``benchmarks.perf_report.bench_trajectory`` (empty dict with no BENCH
    documents), ready for :func:`fig_stages`.
    """
    from benchmarks.perf_report import bench_trajectory

    labels, keys, flats, docs = bench_trajectory(root)
    if not labels:
        return {}
    return {"labels": labels, "keys": keys, "flats": flats, "docs": docs}


def fig_stages(bench):
    """Stage breakdown over the BENCH trajectory — where each run's time
    went, per pipeline stage, with the newest run's telemetry-backed
    cache counters as derived headline numbers (schema v8 documents carry
    the merged metrics registry snapshot; older ones contribute ``n/a``).
    """
    labels, keys, flats = bench["labels"], bench["keys"], bench["flats"]
    headers = ["stage"] + labels
    rows = []
    for k in keys:
        rows.append(
            [k] + [
                round(flat[k], 3) if k in flat else "n/a" for flat in flats
            ]
        )
    derived = {}
    newest = flats[-1]
    for k in sorted(newest, key=newest.get, reverse=True)[:5]:
        derived[f"latest/{k}"] = newest[k]
    oldest = flats[0]
    shared = [k for k in keys if k in oldest and k in newest and oldest[k] > 0]
    if shared:
        top = max(shared, key=lambda k: oldest[k])
        derived[f"trend/{top}"] = newest[top] / oldest[top]
    counters = (
        (bench["docs"][-1].get("telemetry") or {}).get("metrics") or {}
    ).get("counters") or {}
    hits = counters.get("artifact_cache.hits", 0.0) + counters.get(
        "artifact.memo_hits", 0.0
    )
    misses = counters.get("artifact_cache.misses", 0.0)
    if hits + misses > 0:
        derived["latest_cache_hit_ratio"] = hits / (hits + misses)
    return headers, rows, derived


def _geomean(xs):
    xs = np.maximum(np.asarray(list(xs), np.float64), 1e-12)
    return float(np.exp(np.log(xs).mean()))


def fig8_speedup(data):
    """Speedup over the composite baseline (Fig 8)."""
    headers = ["workload"] + PF_ORDER
    rows = []
    per_kernel = defaultdict(lambda: defaultdict(list))
    for (k, d), r in sorted(data.items()):
        row = [f"{k}/{d}"]
        for pf in PF_ORDER:
            v = r["prefetchers"].get(pf, {}).get("speedup", float("nan"))
            row.append(round(v, 3))
            per_kernel[k][pf].append(v)
        rows.append(row)
    derived = {}
    for k, pfv in per_kernel.items():
        for pf, vs in pfv.items():
            derived[f"geomean_speedup/{k}/{pf}"] = _geomean(vs)
    for pf in PF_ORDER:
        allv = [r["prefetchers"][pf]["speedup"] for r in data.values() if pf in r["prefetchers"]]
        derived[f"geomean_speedup/all/{pf}"] = _geomean(allv)
    derived["amc_vs_vldp"] = (
        derived["geomean_speedup/all/amc"] / derived["geomean_speedup/all/vldp"]
    )
    return headers, rows, derived


def fig9_coverage(data):
    headers = ["workload"] + PF_ORDER
    rows = [
        [f"{k}/{d}"] + [
            round(r["prefetchers"].get(pf, {}).get("coverage", float("nan")), 3)
            for pf in PF_ORDER
        ]
        for (k, d), r in sorted(data.items())
    ]
    derived = {
        f"avg_coverage/{pf}": float(
            np.mean([r["prefetchers"][pf]["coverage"] for r in data.values() if pf in r["prefetchers"]])
        )
        for pf in PF_ORDER
    }
    return headers, rows, derived


def fig10_accuracy(data):
    headers = ["workload"] + PF_ORDER
    rows = [
        [f"{k}/{d}"] + [
            round(r["prefetchers"].get(pf, {}).get("accuracy", float("nan")), 3)
            for pf in PF_ORDER
        ]
        for (k, d), r in sorted(data.items())
    ]
    derived = {
        f"avg_accuracy/{pf}": float(
            np.mean([r["prefetchers"][pf]["accuracy"] for r in data.values() if pf in r["prefetchers"]])
        )
        for pf in PF_ORDER
    }
    return headers, rows, derived


def fig11_timeliness(data):
    """AMC timeliness: on-time / late / early / overpredicted breakdown."""
    headers = ["workload", "on_time", "late", "early_evicted", "overpredicted"]
    rows = []
    for (k, d), r in sorted(data.items()):
        m = r["prefetchers"]["amc"]
        issued = max(m["issued"] - m["redundant"], 1)
        rows.append(
            [
                f"{k}/{d}",
                round((m["useful"] - m["late"]) / issued, 3),
                round(m["late"] / issued, 3),
                round(m["evicted_early"] / issued, 3),
                round(m["overpredicted"] / issued, 3),
            ]
        )
    late_frac = np.mean([row[2] for row in rows])
    return headers, rows, {"amc_late_fraction_of_issued": float(late_frac)}


def fig12_13_traffic(data):
    """Additional off-chip traffic + metadata share (Figs 12/13)."""
    headers = ["workload"] + [f"{p}_extra" for p in PF_ORDER] + ["amc_meta", "isb_meta", "misb_meta"]
    rows = []
    for (k, d), r in sorted(data.items()):
        row = [f"{k}/{d}"]
        for pf in PF_ORDER:
            row.append(round(r["prefetchers"].get(pf, {}).get("extra_traffic", float("nan")), 3))
        for pf in ["amc", "isb", "misb"]:
            row.append(round(r["prefetchers"].get(pf, {}).get("metadata_traffic", float("nan")), 3))
        rows.append(row)
    derived = {}
    for pf in PF_ORDER:
        derived[f"avg_extra_traffic/{pf}"] = float(
            np.mean([r["prefetchers"][pf]["extra_traffic"] for r in data.values() if pf in r["prefetchers"]])
        )
    for pf in ["amc", "isb", "misb"]:
        derived[f"avg_metadata_traffic/{pf}"] = float(
            np.mean([r["prefetchers"][pf]["metadata_traffic"] for r in data.values() if pf in r["prefetchers"]])
        )
    return headers, rows, derived


def fig15_storage(data):
    """Off-chip metadata storage vs input size (Fig 15)."""
    headers = ["workload", "peak_bytes", "input_bytes", "fraction"]
    rows = []
    for (k, d), r in sorted(data.items()):
        info = r["prefetchers"]["amc"].get("info", {})
        peak = info.get("storage_peak_bytes", 0)
        frac = peak / max(r["input_bytes"], 1)
        rows.append([f"{k}/{d}", peak, r["input_bytes"], round(frac, 3)])
    fr = [row[3] for row in rows]
    return headers, rows, {
        "max_storage_fraction": float(np.max(fr)),
        "avg_storage_fraction": float(np.mean(fr)),
    }


def fig16_miss_size(data):
    """Miss-stream size sensitivity (Fig 16)."""
    headers = ["workload", "pct_entries_le20", "pct_gt20"]
    rows = []
    for (k, d), r in sorted(data.items()):
        ms = r.get("miss_size", {})
        rows.append(
            [f"{k}/{d}", round(ms.get("pct_entries_le20", float("nan")), 4),
             round(ms.get("pct_gt20", float("nan")), 4)]
        )
    return headers, rows, {
        "avg_entries_le20": float(np.nanmean([r[1] for r in rows])),
        "avg_gt20": float(np.nanmean([r[2] for r in rows])),
    }


def compression_stats(data):
    """§V-B compression ratios."""
    headers = ["workload", "ratio", "mode1B", "mode2B", "mode4B", "raw"]
    rows = []
    for (k, d), r in sorted(data.items()):
        info = r["prefetchers"]["amc"].get("info", {})
        mc = info.get("mode_counts", [0, 0, 0, 0])
        tot = max(sum(mc), 1)
        rows.append(
            [f"{k}/{d}", round(info.get("compression_ratio", float("nan")), 2)]
            + [round(c / tot, 3) for c in mc]
        )
    return headers, rows, {
        "avg_compression_ratio": float(np.nanmean([r[1] for r in rows]))
    }


def table8_storage():
    """On-chip storage cost (Table VIII) — static accounting."""
    from repro.core.amc.prefetcher import AMCConfig

    cfg = AMCConfig()
    rows = [
        ["bingo", "119kB", "16K-entry history table"],
        ["vldp", "~1kB", "OPT+DHB+DPTs"],
        ["rnr", "1kB", "window 512 / buffer 256"],
        ["misb", "49kB", "32kB cache + 17kB bloom"],
        [
            "amc",
            f"{cfg.amc_cache_bytes // 1024 + 5}kB",
            f"{cfg.amc_cache_bytes // 1024}kB AMC Cache + 5kB BaseΔ compressor + "
            "100-entry recorder/identifier/frontier buffers",
        ],
    ]
    return ["prefetcher", "on_chip", "notes"], rows, {}


def fmt_table(headers, rows) -> str:
    lines = ["| " + " | ".join(str(h) for h in headers) + " |",
             "|" + "---|" * len(headers)]
    for r in rows:
        lines.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(lines)
