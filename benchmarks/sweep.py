"""Full prefetcher sweep: every (kernel, dataset) x every prefetcher.

Each workload cell is one declarative ``Experiment`` over the registry-named
prefetcher list; the workload trace is built once and shared by all of them.
Produces one JSON per workload under ``results/`` (resumable — existing
files are skipped). All paper figures (Figs 8-16) are assembled from these
JSONs by the per-figure benchmark modules.

Usage:
    PYTHONPATH=src python -m benchmarks.sweep [--kernels pgd,cc] [--datasets amazon]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

# Per-kernel dataset subsets (the paper also evaluates different inputs per
# kernel; e.g. Road-CA x PGD "requires weeks" and is excluded there too).
MATRIX = {
    "pgd": ["amazon", "stanford", "youtube", "comdblp", "google"],
    "cc": ["amazon", "youtube", "notredame", "google"],
    "bfs": ["amazon", "road-ca", "stanford", "notredame"],
    "bellmanford": ["amazon", "google", "stanford", "comdblp"],
}

PREFETCHERS = ["amc", "vldp", "bingo", "isb", "misb", "rnr", "domino", "prodigy", "ideal"]


def miss_size_histogram(workload) -> dict:
    """Fig 16 source: distribution of per-correlation-entry miss counts
    assuming infinite entry size (pre-split group sizes)."""
    sizes = []
    for view, _ in workload.amc_iteration_views():
        if len(view.target_pos) == 0 or len(view.miss_pos) == 0:
            continue
        tag = np.searchsorted(view.target_pos, view.miss_pos, side="right") - 1
        tag = tag[tag >= 0]
        if len(tag) == 0:
            continue
        sizes.append(np.bincount(tag - tag.min()))
    if not sizes:
        return {"sizes": []}
    allsizes = np.concatenate(sizes)
    allsizes = allsizes[allsizes > 0]
    hist = np.bincount(np.minimum(allsizes, 64))
    return {
        "hist": hist.tolist(),
        "pct_gt20": float((allsizes > 20).mean()),
        "pct_entries_le20": float((allsizes <= 20).mean()),
    }


def run_workload(kernel: str, dataset: str, out_dir: str, prefetchers=None):
    from repro.core import Experiment

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{kernel}_{dataset}.json")
    if os.path.exists(path):
        print(f"[skip] {path}")
        return

    t0 = time.time()
    names = list(prefetchers or PREFETCHERS)
    result = Experiment(
        kernels=[kernel], datasets=[dataset], prefetchers=names
    ).run()
    res = result.suite(kernel, dataset)
    w = result.workload(kernel, dataset)
    base = w.profile.baseline_counts(w.eval_from_pos)
    out = {
        "kernel": kernel,
        "dataset": dataset,
        "accesses": int(w.num_accesses),
        "eval_from_pos": int(w.eval_from_pos),
        "input_bytes": int(w.input_bytes),
        "baseline": base,
        "elapsed_s": time.time() - t0,
        "miss_size": miss_size_histogram(w),
        "prefetchers": {n: _to_jsonable(m.row()) for n, m in res.items()},
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(
        f"[done] {kernel}/{dataset} in {out['elapsed_s']:.0f}s  "
        + "  ".join(
            f"{n}:s={res[n].speedup:.2f},c={res[n].coverage:.2f},a={res[n].accuracy:.2f}"
            for n in names
        )
    )


def _to_jsonable(obj):
    if isinstance(obj, dict):
        return {k: _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, tuple):
        return list(obj)
    return obj


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", default=",".join(MATRIX))
    ap.add_argument("--datasets", default="")
    ap.add_argument("--prefetchers", default=",".join(PREFETCHERS))
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    kernels = args.kernels.split(",")
    pfs = args.prefetchers.split(",")
    for k in kernels:
        for d in MATRIX[k]:
            if args.datasets and d not in args.datasets.split(","):
                continue
            run_workload(k, d, args.out, pfs)


if __name__ == "__main__":
    main()
