"""Full prefetcher sweep: every (kernel, dataset) x every prefetcher.

All remaining workloads run as ONE declarative ``Experiment`` on the
execution engine: ``--workers N`` shards workloads across a process pool
(each worker builds or cache-loads its trace once and scores every
prefetcher against it), and built traces persist in the content-addressed
workload artifact cache so repeat sweeps, ablations and CI reruns skip the
rebuild cost entirely.

Output JSONs are deterministic and timing-free: a ``--workers 4`` sweep
produces byte-identical files to a serial one.  One JSON per workload under
``results/`` (resumable — existing files are skipped).  All paper figures
(Figs 8-16) are assembled from these JSONs by the per-figure benchmark
modules; wall-clock measurements live in ``benchmarks/bench.py`` instead.

Usage:
    PYTHONPATH=src python -m benchmarks.sweep [--kernels pgd,cc]
        [--datasets amazon] [--workers 4] [--cache-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

# Per-kernel dataset subsets (the paper also evaluates different inputs per
# kernel; e.g. Road-CA x PGD "requires weeks" and is excluded there too).
MATRIX = {
    "pgd": ["amazon", "stanford", "youtube", "comdblp", "google"],
    "cc": ["amazon", "youtube", "notredame", "google"],
    "bfs": ["amazon", "road-ca", "stanford", "notredame"],
    "bellmanford": ["amazon", "google", "stanford", "comdblp"],
}

PREFETCHERS = ["amc", "vldp", "bingo", "isb", "misb", "rnr", "domino", "prodigy", "ideal"]


def miss_size_histogram(workload) -> dict:
    """Fig 16 source: distribution of per-correlation-entry miss counts
    assuming infinite entry size (pre-split group sizes)."""
    sizes = []
    for view, _ in workload.amc_iteration_views():
        if len(view.target_pos) == 0 or len(view.miss_pos) == 0:
            continue
        tag = np.searchsorted(view.target_pos, view.miss_pos, side="right") - 1
        tag = tag[tag >= 0]
        if len(tag) == 0:
            continue
        sizes.append(np.bincount(tag - tag.min()))
    if not sizes:
        return {"sizes": []}
    allsizes = np.concatenate(sizes)
    allsizes = allsizes[allsizes > 0]
    hist = np.bincount(np.minimum(allsizes, 64))
    return {
        "hist": hist.tolist(),
        "pct_gt20": float((allsizes > 20).mean()),
        "pct_entries_le20": float((allsizes <= 20).mean()),
    }


def workload_payload(w, result, spec, names) -> dict:
    """The per-workload JSON document (deterministic: no timing fields)."""
    base = w.profile.baseline_counts(w.eval_from_pos)
    return {
        "kernel": spec.kernel,
        "dataset": spec.dataset,
        "accesses": int(w.num_accesses),
        "eval_from_pos": int(w.eval_from_pos),
        "input_bytes": int(w.input_bytes),
        "baseline": base,
        "miss_size": miss_size_histogram(w),
        "prefetchers": {
            n: _to_jsonable(result.metrics(spec=spec, prefetcher=n).row())
            for n in names
        },
    }


def _to_jsonable(obj):
    if isinstance(obj, dict):
        return {k: _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, tuple):
        return list(obj)
    return obj


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", default=",".join(MATRIX))
    ap.add_argument("--datasets", default="")
    ap.add_argument("--prefetchers", default=",".join(PREFETCHERS))
    ap.add_argument("--out", default="results")
    ap.add_argument(
        "--workers", type=int, default=1,
        help="process-parallel workload cells (1 = serial reference path)",
    )
    ap.add_argument(
        "--cache-dir", default=None,
        help="workload artifact cache root (default: $REPRO_WORKLOAD_CACHE "
        "or ~/.cache/repro-amc/workloads)",
    )
    args = ap.parse_args()

    from repro.core import Experiment, WorkloadCache, WorkloadSpec
    from repro.core.exec.artifacts import ArtifactCache

    names = args.prefetchers.split(",")
    os.makedirs(args.out, exist_ok=True)
    todo = []
    for k in args.kernels.split(","):
        for d in MATRIX[k]:
            if args.datasets and d not in args.datasets.split(","):
                continue
            path = os.path.join(args.out, f"{k}_{d}.json")
            if os.path.exists(path):
                print(f"[skip] {path}")
                continue
            todo.append((WorkloadSpec(kernel=k, dataset=d), path))
    if not todo:
        return

    cache = WorkloadCache(artifacts=ArtifactCache(args.cache_dir))
    grid_result = None
    if args.workers > 1:
        # One grid run shards all workloads across the pool; traces stay
        # in the artifact store and are re-loaded one at a time below.
        grid_result = Experiment(
            workloads=[spec for spec, _ in todo], prefetchers=names, cache=cache
        ).run(workers=args.workers)

    for spec, path in todo:
        if grid_result is not None:
            result = grid_result
        else:
            # Serial: one experiment per workload, written as it finishes,
            # so an interrupted sweep keeps every completed JSON.  workers=1
            # pins the serial path (a single-workload grid would stay serial
            # under the auto default too; explicit is clearer).
            result = Experiment(
                workloads=[spec], prefetchers=names, cache=cache
            ).run(workers=1)
        w = cache.get_or_build(spec)
        out = workload_payload(w, result, spec, names)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        # Peak memory stays at ~one trace regardless of sweep size.
        cache.evict(spec)
        del w, result
        scores = "  ".join(
            f"{n}:s={out['prefetchers'][n]['speedup']:.2f}"
            f",c={out['prefetchers'][n]['coverage']:.2f}"
            f",a={out['prefetchers'][n]['accuracy']:.2f}"
            for n in names
        )
        print(f"[done] {spec.kernel}/{spec.dataset}  {scores}")


if __name__ == "__main__":
    main()
