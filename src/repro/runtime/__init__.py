"""Distributed runtime: elastic re-meshing + straggler mitigation."""
from repro.runtime.elastic import ElasticMesh, plan_mesh
from repro.runtime.straggler import StragglerMonitor

__all__ = ["ElasticMesh", "plan_mesh", "StragglerMonitor"]
