"""Elastic scaling: rebuild the mesh from the live device set and reshard.

At 1000+-node scale, node loss is routine. The recovery loop is:

  1. a collective failure / health-check marks devices dead;
  2. ``plan_mesh`` picks the largest valid (data, model) grid from the
     surviving device count (model axis preserved — it is baked into the
     weight sharding; the data axis shrinks);
  3. the train state is restored from the latest checkpoint with the new
     mesh's shardings (CheckpointManager.restore accepts any mesh);
  4. the data pipeline re-slices by the new shard count (pure-function
     batches make this exact);
  5. step functions are re-jitted lazily on first call.

On this CPU container the "failure" is injected by tests (device subset);
the planning/resharding logic is identical on real hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def plan_mesh(
    num_devices: int, model_parallel: int, pods: int = 1
) -> tuple:
    """Largest (pod, data, model) grid for the surviving device count.

    The model axis is preserved (weight shardings depend on it); whole
    data-parallel rows are dropped; pods shrink last."""
    assert model_parallel >= 1
    while pods >= 1:
        per_pod = num_devices // pods
        data = per_pod // model_parallel
        if data >= 1:
            return pods, data, model_parallel
        pods -= 1
    raise ValueError(
        f"{num_devices} devices cannot host model_parallel={model_parallel}"
    )


@dataclasses.dataclass
class ElasticMesh:
    model_parallel: int
    pods: int = 1
    mesh: Optional[Mesh] = None

    def build(self, devices: Optional[Sequence] = None) -> Mesh:
        devices = list(devices if devices is not None else jax.devices())
        pods, data, model = plan_mesh(len(devices), self.model_parallel, self.pods)
        used = devices[: pods * data * model]
        arr = np.array(used).reshape(pods, data, model)
        if pods > 1:
            self.mesh = Mesh(arr, ("pod", "data", "model"))
        else:
            self.mesh = Mesh(arr.reshape(data, model), ("data", "model"))
        return self.mesh

    def on_failure(self, dead: Sequence) -> Mesh:
        """Rebuild the mesh without the dead devices (ids, dicts or Devices)."""
        dead_set = {
            d["id"] if isinstance(d, dict) else getattr(d, "id", d) for d in dead
        }
        alive = [d for d in jax.devices() if d.id not in dead_set]
        return self.build(alive)

    @property
    def data_shards(self) -> int:
        assert self.mesh is not None
        shape = dict(self.mesh.shape)
        return shape.get("data", 1) * shape.get("pod", 1)
