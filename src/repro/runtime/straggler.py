"""Straggler detection + mitigation policy.

Synchronous data parallelism runs at the speed of the slowest participant.
The monitor keeps an EWMA of step time per host and flags sustained
stragglers (step time > threshold x fleet median for ``patience`` steps).
Mitigation escalates:

  1. ``rebalance`` — shrink the straggler's data shard (batch rebalancing,
     cheap, no restart);
  2. ``evict``     — drop the host via the elastic path (checkpoint →
                     re-mesh without it → restore), for hardware-degraded
                     nodes.

The decision logic is host-side and hardware-independent; tests drive it
with synthetic timing streams.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 1.5  # x median
    patience: int = 5  # consecutive flagged steps before action
    ewma: float = 0.7
    rebalance_limit: int = 2  # rebalances before escalating to evict

    def __post_init__(self):
        self.times: Dict[int, float] = {}
        self.flags: Dict[int, int] = defaultdict(int)
        self.rebalances: Dict[int, int] = defaultdict(int)

    def record(self, host: int, step_time: float):
        prev = self.times.get(host, step_time)
        self.times[host] = self.ewma * prev + (1 - self.ewma) * step_time

    def check(self) -> List[tuple]:
        """Returns [(host, action)] with action in {rebalance, evict}."""
        if len(self.times) < 2:
            return []
        med = float(np.median(list(self.times.values())))
        actions = []
        for host, t in self.times.items():
            if t > self.threshold * med:
                self.flags[host] += 1
            else:
                self.flags[host] = 0
            if self.flags[host] >= self.patience:
                if self.rebalances[host] < self.rebalance_limit:
                    self.rebalances[host] += 1
                    actions.append((host, "rebalance"))
                else:
                    actions.append((host, "evict"))
                self.flags[host] = 0
        return actions

    def shard_weights(self, hosts: List[int]) -> Dict[int, float]:
        """Inverse-speed batch weights for the rebalance action."""
        if not self.times:
            return {h: 1.0 / len(hosts) for h in hosts}
        speeds = {h: 1.0 / max(self.times.get(h, 1.0), 1e-9) for h in hosts}
        z = sum(speeds.values())
        return {h: s / z for h, s in speeds.items()}
