"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch, EP+TP.

Dispatch is the production gather/scatter form (sort-by-expert, capacity
drop), not the masked-dense form — compiled FLOPs stay proportional to
*active* parameters, which is what the roofline's MODEL_FLOPS/HLO_FLOPs
ratio checks. Under pjit the scatter/gather over the expert axis lowers to
the EP all-to-all pattern.

``use_recorded_dispatch`` is the AMC-technique integration (DESIGN.md
§2.2): routing decisions for step k are *recorded* and replayed as the
dispatch plan for step k+1 (roles swap每 step, like AMC's metadata spaces).
Inter-step routing stability plays the role of the paper's inter-iteration
frontier stability: the replayed plan lets the gather pipeline start before
the router's logits are even computed, removing the router->dispatch
serialization — the analogue of prefetching the miss stream at the frontier
trigger. Tokens whose replayed assignment is stale are caught by the exact
router output and corrected through the combine weights (stale rows get
zero weight), preserving exactness.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class MoEParams(NamedTuple):
    router: jnp.ndarray  # (D, E)
    w_gate: jnp.ndarray  # (E, D, F)
    w_up: jnp.ndarray  # (E, D, F)
    w_down: jnp.ndarray  # (E, F, D)


def route_topk(
    x: jnp.ndarray, router: jnp.ndarray, top_k: int
) -> tuple:
    """Returns (expert_idx (N,k), weights (N,k), aux_loss)."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), router.astype(jnp.float32))
    weights, idx = jax.lax.top_k(logits, top_k)
    weights = jax.nn.softmax(weights, axis=-1)
    # Load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    e = router.shape[1]
    density = jnp.mean(
        jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0
    )
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(density * p_mean)
    return idx, weights.astype(x.dtype), aux


def _dispatch_plan(expert_idx: jnp.ndarray, num_experts: int, capacity: int):
    """Sort token-slots by expert; assign within-expert ranks; drop overflow.

    Returns (slot_expert, slot_rank, keep) over the flattened (N*k,) slots.
    """
    nk = expert_idx.size
    flat_e = expert_idx.reshape(-1)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    # rank within expert = position - first position of that expert
    seg_start = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), (sorted_e[1:] != sorted_e[:-1]).astype(jnp.int32)]
    )
    # index of segment start via cummax of (i where start else 0)
    idxs = jnp.arange(nk)
    start_idx = jax.lax.cummax(jnp.where(seg_start.astype(bool) | (idxs == 0), idxs, 0))
    rank_sorted = idxs - start_idx
    rank = jnp.zeros(nk, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < capacity
    return flat_e, rank, keep


def moe_ffn(
    x: jnp.ndarray,  # (N, D) flattened tokens
    p: MoEParams,
    top_k: int,
    capacity_factor: float = 1.25,
    recorded_plan: Optional[tuple] = None,
) -> tuple:
    """Returns (y (N, D), aux_loss, plan) — ``plan`` can be replayed as
    ``recorded_plan`` next step (AMC recorded-dispatch)."""
    n, d = x.shape
    e = p.router.shape[1]
    capacity = max(int(capacity_factor * n * top_k / e), 1)

    expert_idx, weights, aux = route_topk(x, p.router, top_k)

    if recorded_plan is not None:
        # AMC-style replay: dispatch along last step's plan; stale slots are
        # zero-weighted by the *current* router output below.
        flat_e, rank, keep = recorded_plan
    else:
        flat_e, rank, keep = _dispatch_plan(expert_idx, e, capacity)
    plan = (flat_e, rank, keep)

    token_of_slot = jnp.repeat(jnp.arange(n), top_k)
    # Correctness guard for replayed plans: weight slots by the current
    # router only where the replayed expert matches the current assignment.
    cur_e = expert_idx.reshape(-1)
    w_slot = jnp.where(flat_e == cur_e, weights.reshape(-1), 0.0)
    w_slot = jnp.where(keep, w_slot, 0.0)

    # Perf iteration 5 (EXPERIMENTS §5): without capacity-dim sharding the
    # dispatch scatter replicates the (E, C, D) tensor on every device and
    # the compiler reduces it with full-tensor all-reduces (~1.2e11 B/layer
    # on mixtral train). Sharding C over the batch axes makes the scatter
    # lower to the intended EP-style all-to-all (token-embedding payload).
    # Gated on token volume: for decode-sized batches the capacity dim is
    # tiny and the forced reshard is pure overhead (measured 100x+
    # regression on the MoE decode cells — §5.4 note).
    from repro.models.sharding import shard_hint

    big = n >= 16384
    hint = shard_hint if big else (lambda t, *a: t)

    dispatch = jnp.zeros((e, capacity, d), x.dtype)
    safe_rank = jnp.where(keep, rank, capacity - 1)
    dispatch = dispatch.at[flat_e, safe_rank].add(
        jnp.where(keep[:, None], x[token_of_slot], 0)
    )
    dispatch = hint(dispatch, None, "batch", None)
    g = jnp.einsum("ecd,edf->ecf", dispatch, p.w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", dispatch, p.w_up.astype(x.dtype))
    h = hint(jax.nn.silu(g) * u, None, "batch", "model")
    y_exp = jnp.einsum("ecf,efd->ecd", h, p.w_down.astype(x.dtype))
    y_exp = hint(y_exp, None, "batch", None)

    y_slot = y_exp[flat_e, safe_rank] * w_slot[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[token_of_slot].add(y_slot)
    y = hint(y, "batch", None)
    return y, aux, plan
