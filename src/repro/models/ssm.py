"""Mamba2 / SSD (state-space duality) block, chunked for the MXU.

Implements the SSD algorithm of arXiv:2405.21060: within a chunk the
sequence mixing is a (masked) matmul — MXU-friendly — and chunks are linked
by a small recurrent state (B, H, P, N) scanned across chunk boundaries.
Decode is the O(1)/token recurrence. A scalar-per-head A (Mamba2's
restriction) keeps the decay terms rank-1.

Shapes: d_inner = expand * d_model; H = d_inner / head_dim; P = head_dim;
N = ssm_state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SSMParams(NamedTuple):
    w_in: jnp.ndarray  # (D, 2*d_inner + 2*N + H)  -> x, z, B, C, dt
    a_log: jnp.ndarray  # (H,)
    d_skip: jnp.ndarray  # (H,)
    dt_bias: jnp.ndarray  # (H,)
    norm: jnp.ndarray  # (d_inner,)
    w_out: jnp.ndarray  # (d_inner, D)


def _split_proj(zxbcdt, d_inner, n_state, n_heads):
    x, z, b, c, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + n_state, 2 * d_inner + 2 * n_state],
        axis=-1,
    )
    return x, z, b, c, dt


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P) inputs per head
    dt: jnp.ndarray,  # (B, S, H) softplus'd step sizes
    a: jnp.ndarray,  # (H,) negative decay rates
    b_proj: jnp.ndarray,  # (B, S, N)
    c_proj: jnp.ndarray,  # (B, S, N)
    chunk: int = 256,
    init_state=None,  # (B, H, P, N)
):
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state)."""
    bsz, s, h, p = x.shape
    n = b_proj.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_proj = jnp.pad(b_proj, ((0, 0), (0, pad), (0, 0)))
        c_proj = jnp.pad(c_proj, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_proj.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c_proj.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    da = dtc * a[None, None, None, :]  # (B,C,L,H) negative
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative log-decay
    seg_total = cum[:, :, -1]  # (B,C,H)

    def per_chunk(xc_, dtc_, bc_, cc_, da_, cum_):
        # intra-chunk: y[t] = sum_{s<=t} C[t]·B[s] * exp(cum[t]-cum[s]) dt[s] x[s]
        decay = jnp.exp(
            cum_[:, :, None, :] - cum_[:, None, :, :]
        )  # (B,L,L,H), t>=s valid
        l_idx = jnp.arange(xc_.shape[1])
        mask = (l_idx[:, None] >= l_idx[None, :]).astype(jnp.float32)
        cb = jnp.einsum("btn,bsn->bts", cc_, bc_)  # (B,L,L)
        w = cb[..., None] * decay * mask[None, :, :, None]  # (B,L,L,H)
        xdt = xc_.astype(jnp.float32) * dtc_[..., None]  # (B,L,H,P)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xdt)
        # chunk state contribution: K[s->end]
        state_w = jnp.exp(cum_[:, -1:, :] - cum_) * dtc_  # (B,L,H)
        new_state = jnp.einsum("bsn,bsh,bshp->bhpn", bc_, state_w, xc_.astype(jnp.float32))
        return y_intra, new_state

    y_intra, chunk_states = jax.vmap(
        per_chunk, in_axes=(1, 1, 1, 1, 1, 1), out_axes=(1, 1)
    )(xc, dtc, bc, cc, da, cum)

    # inter-chunk: scan states across chunks
    seg_decay = jnp.exp(seg_total)  # (B,C,H)

    def scan_body(carry, inp):
        state = carry  # (B,H,P,N)
        s_new, dec = inp  # (B,H,P,N), (B,H)
        out_state = state
        state = state * dec[:, :, None, None] + s_new
        return state, out_state

    init = (
        init_state
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(seg_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,C,H,P,N)

    # contribution of the incoming state to each position
    y_state = jnp.einsum(
        "bcln,bclh,bchpn->bclhp", cc.reshape(bsz, nc, chunk, n), jnp.exp(cum), prev_states
    )
    y = (y_intra + y_state).reshape(bsz, nc * chunk, h, p)
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), final_state


def ssm_block(
    params: SSMParams,
    x: jnp.ndarray,  # (B, S, D)
    cfg,
    init_state=None,
):
    """Full Mamba2 block: in-proj -> SSD -> gated RMSNorm -> out-proj."""
    d_inner = cfg.ssm_expand * cfg.d_model
    h = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params.w_in.astype(x.dtype))
    xi, z, b, c, dt = _split_proj(zxbcdt, d_inner, n, h)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params.dt_bias)
    a = -jnp.exp(params.a_log.astype(jnp.float32))
    xh = xi.reshape(*xi.shape[:-1], h, cfg.ssm_head_dim)
    y, state = ssd_chunked(xh, dt, a, b, c, chunk=cfg.ssm_chunk, init_state=init_state)
    y = y + xh.astype(jnp.float32) * params.d_skip[None, None, :, None]
    y = y.reshape(*xi.shape)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6)) * params.norm
    return (
        jnp.einsum("bsk,kd->bsd", y.astype(x.dtype), params.w_out.astype(x.dtype)),
        state,
    )


def ssm_decode_step(
    params: SSMParams,
    x: jnp.ndarray,  # (B, 1, D)
    state: jnp.ndarray,  # (B, H, P, N) float32
    cfg,
):
    """O(1) recurrent decode: h' = h*exp(dt*A) + dt*B x ; y = C·h' + D x."""
    d_inner = cfg.ssm_expand * cfg.d_model
    h = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params.w_in.astype(x.dtype))
    xi, z, b, c, dt = _split_proj(zxbcdt, d_inner, n, h)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params.dt_bias)[:, 0]  # (B,H)
    a = -jnp.exp(params.a_log.astype(jnp.float32))
    xh = xi[:, 0].reshape(-1, h, cfg.ssm_head_dim).astype(jnp.float32)  # (B,H,P)
    bv = b[:, 0].astype(jnp.float32)  # (B,N)
    cv = c[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])  # (B,H)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, bv, dt
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cv) + xh * params.d_skip[None, :, None]
    y = y.reshape(x.shape[0], 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6)) * params.norm
    return (
        jnp.einsum("bsk,kd->bsd", y.astype(x.dtype), params.w_out.astype(x.dtype)),
        state,
    )
