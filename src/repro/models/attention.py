"""Attention: blocked (flash-style) training/prefill path, decode paths.

The training/prefill path is a ``lax.scan`` over KV blocks with an online
softmax — memory stays O(S * block) instead of O(S^2), which is what makes
the 32k-prefill cells compile with sane ``memory_analysis()``. The Pallas
TPU kernel (:mod:`repro.kernels.flash_attn`) implements the same tiling for
the MXU; this module is the jnp fallback and the kernel's oracle.

Decode paths: batched single-token attention against a KV cache, plus a
sequence-sharded variant (``shard_map`` + partial-softmax psum combine) for
long_500k where batch(=1) cannot cover the data axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd) for GQA."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd
    )


def blocked_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    causal: bool = True,
    sliding_window: int = 0,
    q_offset: int = 0,
    block_size: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention, scanned over KV blocks."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = hd**-0.5
    qf = (q * scale).astype(jnp.float32)

    nblocks = -(-skv // block_size)
    pad = nblocks * block_size - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblocks, block_size, h, hd)
    vb = v.reshape(b, nblocks, block_size, h, hd)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, lsum, acc = carry
        kblk, vblk, blk_idx = inp
        k_pos = blk_idx * block_size + jnp.arange(block_size)
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", qf, kblk.astype(jnp.float32)
        )  # (B,H,Sq,blk)
        mask = k_pos[None, :] <= q_pos[:, None] if causal else jnp.ones(
            (sq, block_size), bool
        )
        if sliding_window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
        mask = mask & (k_pos < skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lsum_new = lsum * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, lsum_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, lsum, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            jnp.arange(nblocks),
        ),
    )
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, hd)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,  # (B, S, KV, hd)
    cache_len: jnp.ndarray,  # (B,) valid lengths
    sliding_window: int = 0,
) -> jnp.ndarray:
    """Single-token attention against a (batch-sharded) KV cache."""
    b, s, kv, hd = k_cache.shape
    h = q.shape[2]
    groups = h // kv
    scale = hd**-0.5
    qf = (q[:, 0] * scale).astype(jnp.float32).reshape(b, kv, groups, hd)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32)
    )  # (B,KV,G,S)
    pos = jnp.arange(s)
    mask = pos[None, :] < cache_len[:, None]  # (B,S)
    if sliding_window:
        mask = mask & (pos[None, :] >= cache_len[:, None] - sliding_window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def decode_attention_seqsharded(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
    mesh,
    seq_axis: str = "data",
    k_new: Optional[jnp.ndarray] = None,  # (B, 1, KV, hd) token to insert
    v_new: Optional[jnp.ndarray] = None,
):
    """long_500k decode: the KV cache's sequence dim is sharded over
    ``seq_axis``; each shard computes a partial softmax and the results are
    combined exactly via (max, sum) psum reductions of the log-sum-exp.

    The new token's KV insert happens INSIDE the shard_map (only the owner
    shard writes) — perf iteration 4: a scatter into a seq-sharded cache
    outside the shard region forced XLA into "involuntary full
    rematerialization" (gather + re-shard of the whole 500k cache per step).

    Returns (out, k_cache, v_cache).
    """
    from jax.experimental.shard_map import shard_map

    b, s, kvh, hd = k_cache.shape
    h = q.shape[2]
    groups = h // kvh
    scale = hd**-0.5
    axis_size = mesh.shape[seq_axis]
    shard_len = s // axis_size
    insert = k_new is not None
    if not insert:
        k_new = jnp.zeros((b, 1, kvh, hd), k_cache.dtype)
        v_new = jnp.zeros((b, 1, kvh, hd), v_cache.dtype)

    def local(q_, k_, v_, cl_, kn_, vn_):
        idx = jax.lax.axis_index(seq_axis)
        if insert:
            # owner-shard write of the new token at global position cl_
            local_pos = cl_ - idx * shard_len  # (B,)
            owner = (local_pos >= 0) & (local_pos < shard_len)
            safe = jnp.clip(local_pos, 0, shard_len - 1)
            bidx = jnp.arange(b)
            k_upd = k_.at[bidx, safe].set(
                jnp.where(owner[:, None, None], kn_[:, 0], k_[bidx, safe])
            )
            v_upd = v_.at[bidx, safe].set(
                jnp.where(owner[:, None, None], vn_[:, 0], v_[bidx, safe])
            )
            k_, v_ = k_upd, v_upd
            cl_ = cl_ + 1
        qf = (q_[:, 0] * scale).astype(jnp.float32).reshape(b, kvh, groups, hd)
        scores = jnp.einsum("bkgd,bskd->bkgs", qf, k_.astype(jnp.float32))
        pos = idx * shard_len + jnp.arange(shard_len)
        mask = pos[None, :] < cl_[:, None]
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        m_loc = scores.max(axis=-1)
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        p = jnp.exp(scores - m_glob[..., None])
        l_loc = p.sum(axis=-1)
        l_glob = jax.lax.psum(l_loc, seq_axis)
        o_loc = jnp.einsum("bkgs,bskd->bkgd", p, v_.astype(jnp.float32))
        o_glob = jax.lax.psum(o_loc, seq_axis)
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.reshape(b, 1, h, hd).astype(q.dtype), k_, v_

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(),  # q replicated across the seq axis
            P(None, seq_axis, None, None),
            P(None, seq_axis, None, None),
            P(),
            P(),
            P(),
        ),
        out_specs=(P(), P(None, seq_axis, None, None), P(None, seq_axis, None, None)),
        check_rep=False,
    )(q, k_cache, v_cache, cache_len, k_new, v_new)
