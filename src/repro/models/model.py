"""Model zoo assembly: init / train-forward / prefill / decode for all six
families (dense, moe, ssm, hybrid, encdec, vlm).

Design choices that matter at scale:
  - scan-over-layers with stacked params: HLO size and compile time are
    O(1) in depth (llama3-405b's 126 layers compile as one scanned layer);
  - blocked attention everywhere (memory O(S*block));
  - remat policy per config (dots_saveable default for train);
  - KV caches are functional (donated by the launcher's serve loop);
  - vocab padded to a multiple of 128 so the model axis always divides it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    blocked_attention,
    decode_attention,
    decode_attention_seqsharded,
)
from repro.models.layers import dense_init, mrope, rms_norm, rope, swiglu
from repro.models.sharding import shard_hint
from repro.models.moe import MoEParams, moe_ffn
from repro.models.ssm import SSMParams, ssm_block, ssm_decode_step


def padded_vocab(v: int) -> int:
    return -(-v // 128) * 128


def layer_scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked layers, or an unrolled Python loop when
    ``cfg.scan_layers`` is False (the layer-probe path: XLA cost_analysis
    does not descend into while bodies, so probes must lower inline)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    length = len(jax.tree.leaves(xs)[0])
    outs = []
    for i in range(length):
        carry, out = body(carry, jax.tree.map(lambda a: a[i], xs))
        outs.append(out)
    if outs and outs[0] is not None:
        stacked = jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
    else:
        stacked = None
    return carry, stacked


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------


def _attn_params(key, cfg: ModelConfig, layers: Optional[int], dtype):
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    pre = (layers,) if layers else ()
    ks = jax.random.split(key, 6)

    def init(k, shape, in_axis):
        if layers:
            return dense_init(k, (layers,) + shape, in_axis=in_axis + 1, dtype=dtype)
        return dense_init(k, shape, in_axis=in_axis, dtype=dtype)

    p = {
        "wq": init(ks[0], (d, cfg.num_heads * hd), 0),
        "wk": init(ks[1], (d, cfg.num_kv_heads * hd), 0),
        "wv": init(ks[2], (d, cfg.num_kv_heads * hd), 0),
        "wo": init(ks[3], (cfg.num_heads * hd, d), 0),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones(pre + (hd,), dtype)
        p["kn"] = jnp.ones(pre + (hd,), dtype)
    return p


def _mlp_params(key, cfg: ModelConfig, layers: Optional[int], dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)

    def init(k, shape, in_axis):
        if layers:
            return dense_init(k, (layers,) + shape, in_axis=in_axis + 1, dtype=dtype)
        return dense_init(k, shape, in_axis=in_axis, dtype=dtype)

    return {
        "wg": init(ks[0], (d, f), 0),
        "wu": init(ks[1], (d, f), 0),
        "wd": init(ks[2], (f, d), 0),
    }


def _moe_params(key, cfg: ModelConfig, layers: int, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (layers, d, e), in_axis=1, dtype=dtype),
        "wg": dense_init(ks[1], (layers, e, d, f), in_axis=2, dtype=dtype),
        "wu": dense_init(ks[2], (layers, e, d, f), in_axis=2, dtype=dtype),
        "wd": dense_init(ks[3], (layers, e, f, d), in_axis=2, dtype=dtype),
    }


def _ssm_params(key, cfg: ModelConfig, layers: int, dtype):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    h = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    kdim = 2 * d_inner + 2 * n + h
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (layers, d, kdim), in_axis=1, dtype=dtype),
        "a_log": jnp.zeros((layers, h), dtype) + jnp.log(jnp.float32(1.0)).astype(dtype),
        "d_skip": jnp.ones((layers, h), dtype),
        "dt_bias": jnp.zeros((layers, h), dtype),
        "norm": jnp.ones((layers, d_inner), dtype),
        "w_out": dense_init(ks[1], (layers, d_inner, d), in_axis=1, dtype=dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    vp = padded_vocab(cfg.vocab_size)
    keys = jax.random.split(key, 12)
    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], (vp, cfg.d_model), in_axis=1, dtype=dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (vp, cfg.d_model), in_axis=1, dtype=dtype
        )
    L = cfg.num_layers
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = {
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ln2": jnp.ones((L, cfg.d_model), dtype),
            "attn": _attn_params(keys[2], cfg, L, dtype),
            "mlp": _mlp_params(keys[3], cfg, L, dtype),
        }
    elif fam == "moe":
        params["blocks"] = {
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ln2": jnp.ones((L, cfg.d_model), dtype),
            "attn": _attn_params(keys[2], cfg, L, dtype),
            "moe": _moe_params(keys[3], cfg, L, dtype),
        }
    elif fam == "ssm":
        params["blocks"] = {
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ssm": _ssm_params(keys[2], cfg, L, dtype),
        }
    elif fam == "hybrid":
        params["blocks"] = {
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ssm": _ssm_params(keys[2], cfg, L, dtype),
        }
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": _attn_params(keys[4], cfg, None, dtype),
            "mlp": _mlp_params(keys[5], cfg, None, dtype),
        }
    elif fam == "encdec":
        Le = cfg.encoder_layers
        params["encoder"] = {
            "ln1": jnp.ones((Le, cfg.d_model), dtype),
            "ln2": jnp.ones((Le, cfg.d_model), dtype),
            "attn": _attn_params(keys[6], cfg, Le, dtype),
            "mlp": _mlp_params(keys[7], cfg, Le, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        params["blocks"] = {
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ln2": jnp.ones((L, cfg.d_model), dtype),
            "ln3": jnp.ones((L, cfg.d_model), dtype),
            "attn": _attn_params(keys[2], cfg, L, dtype),
            "xattn": _attn_params(keys[8], cfg, L, dtype),
            "mlp": _mlp_params(keys[3], cfg, L, dtype),
        }
    else:  # pragma: no cover
        raise ValueError(fam)
    return params


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------


def _apply_rope(cfg: ModelConfig, q, k, positions, positions3=None):
    if cfg.mrope and positions3 is not None:
        q = mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k


def _attention_block(
    p, cfg: ModelConfig, x, positions, positions3=None, causal=True, kv_x=None
):
    """Full-sequence attention (train/prefill). kv_x != None => cross-attn."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype)).reshape(
        b, s, cfg.num_heads, hd
    )
    k = jnp.einsum("bsd,dk->bsk", src, p["wk"].astype(x.dtype)).reshape(
        b, src.shape[1], cfg.num_kv_heads, hd
    )
    v = jnp.einsum("bsd,dk->bsk", src, p["wv"].astype(x.dtype)).reshape(
        b, src.shape[1], cfg.num_kv_heads, hd
    )
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"])
        k = rms_norm(k, p["kn"])
    if kv_x is None and cfg.rope_theta and cfg.family != "encdec":
        q, k = _apply_rope(cfg, q, k, positions, positions3)
    o = blocked_attention(
        q, k, v, causal=causal, sliding_window=cfg.sliding_window
    )
    o = o.reshape(b, s, cfg.num_heads * hd)
    return jnp.einsum("bsk,kd->bsd", o, p["wo"].astype(x.dtype)), (k, v)


def _attention_decode(
    p, cfg: ModelConfig, x, k_cache, v_cache, cache_len, mesh=None, seq_sharded=False
):
    """One-token attention against the cache; returns (out, new_k, new_v)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype)).reshape(
        b, 1, cfg.num_heads, hd
    )
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"].astype(x.dtype)).reshape(
        b, 1, cfg.num_kv_heads, hd
    )
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"].astype(x.dtype)).reshape(
        b, 1, cfg.num_kv_heads, hd
    )
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"])
        k = rms_norm(k, p["kn"])
    if cfg.rope_theta and cfg.family != "encdec":
        q, _ = _apply_rope(cfg, q, q, cache_len[:, None])
        k, _ = _apply_rope(cfg, k, k, cache_len[:, None])
    s_max = k_cache.shape[1]
    if seq_sharded and mesh is not None:
        # Insert happens inside the shard region (owner shard writes) —
        # perf iteration 4, see decode_attention_seqsharded.
        o, k_cache, v_cache = decode_attention_seqsharded(
            q, k_cache, v_cache, cache_len, mesh, k_new=k, v_new=v
        )
    else:
        # Functional cache insert at position cache_len (ring for SWA).
        if cfg.sliding_window and cfg.sliding_window < s_max:
            write_pos = cache_len % cfg.sliding_window
        else:
            write_pos = jnp.minimum(cache_len, s_max - 1)
        bidx = jnp.arange(b)
        k_cache = k_cache.at[bidx, write_pos].set(k[:, 0])
        v_cache = v_cache.at[bidx, write_pos].set(v[:, 0])
        eff_len = (
            jnp.minimum(cache_len + 1, cfg.sliding_window)
            if cfg.sliding_window and cfg.sliding_window < s_max
            else cache_len + 1
        )
        o = decode_attention(
            q, k_cache, v_cache, eff_len,
            sliding_window=0,  # ring buffer already bounds the window
        )
    o = o.reshape(b, 1, cfg.num_heads * hd)
    return (
        jnp.einsum("bsk,kd->bsd", o, p["wo"].astype(x.dtype)),
        k_cache,
        v_cache,
    )


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# --------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: Optional[jnp.ndarray],  # (B, S) or None when embeds given
    *,
    embeds: Optional[jnp.ndarray] = None,  # (B, S, D) stub frontends
    positions3: Optional[jnp.ndarray] = None,  # (B, 3, S) M-RoPE
    encoder_frames: Optional[jnp.ndarray] = None,  # (B, Se, D) audio stub
    return_cache: bool = False,
):
    """Returns (logits, aux_loss, cache_or_None)."""
    dt = cfg.activation_dtype
    if embeds is not None:
        x = embeds.astype(dt)
    else:
        x = params["embed"].astype(dt)[tokens]
    x = shard_hint(x, "batch", None, None)
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    aux_total = jnp.zeros((), jnp.float32)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encoder_forward(cfg, params["encoder"], encoder_frames)

    blocks = params["blocks"]
    caches = [] if return_cache else None

    if cfg.family in ("dense", "vlm", "moe"):

        def layer(x, lp):
            x = shard_hint(x, "batch", None, None)
            h = rms_norm(x, lp["ln1"])
            attn_out, kv = _attention_block(
                lp["attn"], cfg, h, positions, positions3
            )
            x = x + attn_out
            h = rms_norm(x, lp["ln2"])
            if cfg.family == "moe":
                mp = MoEParams(
                    lp["moe"]["router"], lp["moe"]["wg"], lp["moe"]["wu"], lp["moe"]["wd"]
                )
                y, aux, _ = moe_ffn(h.reshape(b * s, d), mp, cfg.moe_top_k)
                y = y.reshape(b, s, d)
            else:
                y = swiglu(h, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
                aux = jnp.zeros((), jnp.float32)
            return x + y, (aux, kv)

        layer = _remat(layer, cfg)

        def scan_body(carry, lp):
            x, aux_acc = carry
            x, (aux, kv) = layer(x, lp)
            out = kv if return_cache else None
            return (x, aux_acc + aux), out

        (x, aux_total), kvs = layer_scan(cfg, scan_body, (x, aux_total), blocks)
        if return_cache:
            caches = kvs  # (k: (L,B,S,KV,hd), v: (L,B,S,KV,hd))

    elif cfg.family == "ssm":

        def layer(x, lp):
            h = rms_norm(x, lp["ln1"])
            sp = SSMParams(**{k: lp["ssm"][k] for k in SSMParams._fields})
            y, state = ssm_block(sp, h, cfg)
            return x + y, state

        layer = _remat(layer, cfg)

        def scan_body(x, lp):
            x, state = layer(x, lp)
            return x, state if return_cache else None

        x, states = layer_scan(cfg, scan_body, x, blocks)
        if return_cache:
            caches = states

    elif cfg.family == "hybrid":
        x, aux_total, caches = _hybrid_forward(
            cfg, params, x, positions, return_cache
        )

    elif cfg.family == "encdec":

        def layer(x, lp):
            h = rms_norm(x, lp["ln1"])
            attn_out, kv = _attention_block(lp["attn"], cfg, h, positions)
            x = x + attn_out
            h = rms_norm(x, lp["ln3"])
            xo, xkv = _attention_block(
                lp["xattn"], cfg, h, positions, causal=False, kv_x=enc_out
            )
            x = x + xo
            h = rms_norm(x, lp["ln2"])
            y = swiglu(h, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
            return x + y, (kv, xkv)

        layer = _remat(layer, cfg)

        def scan_body(x, lp):
            x, kvs = layer(x, lp)
            return x, kvs if return_cache else None

        x, kvs = layer_scan(cfg, scan_body, x, blocks)
        if return_cache:
            caches = kvs

    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(dt))
    logits = shard_hint(logits, "batch", None, "model")
    return logits, aux_total, caches


def _encoder_forward(cfg: ModelConfig, enc, frames):
    dt = cfg.activation_dtype
    x = frames.astype(dt)
    b, s, d = x.shape
    # sinusoidal positions (whisper-style)
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half) / max(half - 1, 1) * jnp.log(10000.0))
    ang = jnp.arange(s)[:, None] * freqs[None, :]
    pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dt)
    x = x + pos[None]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def layer(x, lp):
        h = rms_norm(x, lp["ln1"])
        o, _ = _attention_block(lp["attn"], cfg, h, positions, causal=False)
        x = x + o
        h = rms_norm(x, lp["ln2"])
        return x + swiglu(h, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"]), None

    layer = _remat(layer, cfg)
    stacked = {k: v for k, v in enc.items() if k != "final_norm"}
    x, _ = layer_scan(cfg, lambda c, lp: layer(c, lp), x, stacked)
    return rms_norm(x, enc["final_norm"])


def _hybrid_forward(cfg, params, x, positions, return_cache):
    """Zamba2: groups of ``hybrid_attn_every`` mamba layers + shared attn."""
    b, s, d = x.shape
    blocks = params["blocks"]
    shared = params["shared_attn"]
    every = cfg.hybrid_attn_every
    L = cfg.num_layers
    groups = L // every
    rest = L - groups * every
    aux = jnp.zeros((), jnp.float32)

    def mamba_layer(x, lp):
        h = rms_norm(x, lp["ln1"])
        sp = SSMParams(**{k: lp["ssm"][k] for k in SSMParams._fields})
        y, state = ssm_block(sp, h, cfg)
        return x + y, state

    mamba_layer = _remat(mamba_layer, cfg)

    def shared_block(x):
        h = rms_norm(x, shared["ln1"])
        o, kv = _attention_block(shared["attn"], cfg, h, positions)
        x = x + o
        h = rms_norm(x, shared["ln2"])
        y = swiglu(h, shared["mlp"]["wg"], shared["mlp"]["wu"], shared["mlp"]["wd"])
        return x + y, kv

    grouped = jax.tree.map(
        lambda a: a[: groups * every].reshape((groups, every) + a.shape[1:]), blocks
    )
    tail = jax.tree.map(lambda a: a[groups * every :], blocks)

    def group_body(x, gp):
        def inner(x, lp):
            x, st = mamba_layer(x, lp)
            return x, st

        x, states = layer_scan(cfg, inner, x, gp)
        x, kv = shared_block(x)
        return x, (states, kv)

    x, (g_states, g_kv) = layer_scan(cfg, group_body, x, grouped)
    if rest:
        x, t_states = layer_scan(cfg, lambda c, lp: mamba_layer(c, lp), x, tail)
    else:
        t_states = None
    caches = (g_states, g_kv, t_states) if return_cache else None
    return x, aux, caches


# --------------------------------------------------------------------------
# Loss / train step body
# --------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    logits, aux, _ = forward(
        cfg,
        params,
        batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions3=batch.get("positions3"),
        encoder_frames=batch.get("frames"),
    )
    labels = batch["labels"]
    vp = logits.shape[-1]
    # mask padded vocab
    logits = logits.astype(jnp.float32)
    if vp > cfg.vocab_size:
        neg = jnp.full((vp - cfg.vocab_size,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size :].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll).mean()
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# Decode (serve_step)
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Allocate the decode cache pytree for one model."""
    dt = dtype or cfg.activation_dtype
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    eff_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.family in ("dense", "vlm", "moe"):
        return {
            "k": jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        h = d_inner // cfg.ssm_head_dim
        return {
            "state": jnp.zeros((L, batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        h = d_inner // cfg.ssm_head_dim
        every = cfg.hybrid_attn_every
        groups = cfg.num_layers // every
        rest = cfg.num_layers - groups * every
        cache = {
            "g_state": jnp.zeros(
                (groups, every, batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            ),
            "g_k": jnp.zeros((groups, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "g_v": jnp.zeros((groups, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
        if rest:
            cache["t_state"] = jnp.zeros(
                (rest, batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            )
        return cache
    if cfg.family == "encdec":
        return {
            "k": jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "xk": jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "xv": jnp.zeros((L, batch, eff_len, cfg.num_kv_heads, hd), dt),
            "xlen": jnp.zeros((batch,), jnp.int32),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    raise ValueError(cfg.family)


def decode_step(
    cfg: ModelConfig,
    params,
    tokens: jnp.ndarray,  # (B, 1)
    cache: Dict[str, jnp.ndarray],
    mesh=None,
    seq_sharded: bool = False,
):
    """serve_step: one new token against the cache. Returns (logits, cache)."""
    dt = cfg.activation_dtype
    x = params["embed"].astype(dt)[tokens]
    b = x.shape[0]
    blocks = params["blocks"]
    cache_len = cache["len"]

    if cfg.family in ("dense", "vlm", "moe"):

        def body(x, xs):
            lp, kc, vc = xs
            h = rms_norm(x, lp["ln1"])
            o, kc, vc = _attention_decode(
                lp["attn"], cfg, h, kc, vc, cache_len, mesh, seq_sharded
            )
            x = x + o
            h = rms_norm(x, lp["ln2"])
            if cfg.family == "moe":
                mp = MoEParams(
                    lp["moe"]["router"], lp["moe"]["wg"], lp["moe"]["wu"], lp["moe"]["wd"]
                )
                y, _, _ = moe_ffn(h.reshape(b, -1), mp, cfg.moe_top_k)
                y = y.reshape(b, 1, -1)
            else:
                y = swiglu(h, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
            return x + y, (kc, vc)

        x, (new_k, new_v) = layer_scan(cfg, body, x, (blocks, cache["k"], cache["v"]))
        cache = dict(cache, k=new_k, v=new_v, len=cache_len + 1)

    elif cfg.family == "ssm":

        def body(x, xs):
            lp, st = xs
            h = rms_norm(x, lp["ln1"])
            sp = SSMParams(**{k: lp["ssm"][k] for k in SSMParams._fields})
            y, st = ssm_decode_step(sp, h, st, cfg)
            return x + y, st

        x, new_state = layer_scan(cfg, body, x, (blocks, cache["state"]))
        cache = dict(cache, state=new_state, len=cache_len + 1)

    elif cfg.family == "hybrid":
        x, cache = _hybrid_decode(cfg, params, x, cache, mesh, seq_sharded)

    elif cfg.family == "encdec":

        def body(x, xs):
            lp, kc, vc, xk, xv = xs
            h = rms_norm(x, lp["ln1"])
            o, kc, vc = _attention_decode(lp["attn"], cfg, h, kc, vc, cache_len)
            x = x + o
            h = rms_norm(x, lp["ln3"])
            xo = _cross_decode(lp["xattn"], cfg, h, xk, xv, cache["xlen"])
            x = x + xo
            h = rms_norm(x, lp["ln2"])
            y = swiglu(h, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
            return x + y, (kc, vc)

        x, (new_k, new_v) = layer_scan(
            cfg, body, x, (blocks, cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        cache = dict(cache, k=new_k, v=new_v, len=cache_len + 1)

    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(dt))
    logits = shard_hint(logits, "batch", None, "model")
    return logits, cache


def _cross_decode(p, cfg, x, xk, xv, xlen):
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype)).reshape(
        b, 1, cfg.num_heads, hd
    )
    o = decode_attention(q, xk, xv, xlen)
    o = o.reshape(b, 1, cfg.num_heads * hd)
    return jnp.einsum("bsk,kd->bsd", o, p["wo"].astype(x.dtype))


def _hybrid_decode(cfg, params, x, cache, mesh, seq_sharded):
    blocks = params["blocks"]
    shared = params["shared_attn"]
    every = cfg.hybrid_attn_every
    groups = cfg.num_layers // every
    rest = cfg.num_layers - groups * every
    cache_len = cache["len"]

    grouped = jax.tree.map(
        lambda a: a[: groups * every].reshape((groups, every) + a.shape[1:]), blocks
    )
    tail = jax.tree.map(lambda a: a[groups * every :], blocks)

    def mamba_step(x, xs):
        lp, st = xs
        h = rms_norm(x, lp["ln1"])
        sp = SSMParams(**{k: lp["ssm"][k] for k in SSMParams._fields})
        y, st = ssm_decode_step(sp, h, st, cfg)
        return x + y, st

    def group_body(x, xs):
        gp, g_st, kc, vc = xs
        x, new_st = layer_scan(cfg, mamba_step, x, (gp, g_st))
        h = rms_norm(x, shared["ln1"])
        o, kc, vc = _attention_decode(
            shared["attn"], cfg, h, kc, vc, cache_len, mesh, seq_sharded
        )
        x = x + o
        h = rms_norm(x, shared["ln2"])
        y = swiglu(h, shared["mlp"]["wg"], shared["mlp"]["wu"], shared["mlp"]["wd"])
        return x + y, (new_st, kc, vc)

    x, (new_gstate, new_gk, new_gv) = layer_scan(
        cfg, group_body, x, (grouped, cache["g_state"], cache["g_k"], cache["g_v"])
    )
    cache = dict(cache, g_state=new_gstate, g_k=new_gk, g_v=new_gv)
    if rest:
        x, new_t = layer_scan(cfg, mamba_step, x, (tail, cache["t_state"]))
        cache["t_state"] = new_t
    cache["len"] = cache_len + 1
    return x, cache
