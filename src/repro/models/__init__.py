"""Model zoo: composable JAX definitions for the 10 assigned architectures."""
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    padded_vocab,
)
from repro.models.sharding import (
    batch_shardings,
    cache_shardings,
    params_shardings,
)

__all__ = [
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "padded_vocab",
    "batch_shardings",
    "cache_shardings",
    "params_shardings",
]
