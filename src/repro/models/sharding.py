"""Sharding rules: parameter/activation PartitionSpecs for the production mesh.

Mesh axes: ("pod", "data", "model") multi-pod / ("data", "model") single-pod.
  - batch        -> ("pod", "data") (pure DP across pods: only the gradient
                    all-reduce crosses the slow inter-pod links)
  - vocab, d_ff, attention heads, experts' f dim -> "model" (TP)
  - parameters' d_model/d_ff input dims -> "data" (FSDP/ZeRO-3 style)
  - attention replicated on "model" for archs whose head count does not
    divide the model axis (smollm 15H, whisper 6H, qwen2-vl 28H) — noted in
    each config.

Rules are resolved per-leaf by path, with divisibility checked against the
actual mesh so every (arch x mesh) pair lowers cleanly.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def shard_hint(x, *spec):
    """with_sharding_constraint that degrades to a no-op outside a mesh.

    ('batch',) expands to the mesh's batch axes (('pod','data') multi-pod,
    ('data',) single-pod). Perf iteration 1 (EXPERIMENTS §5): without these
    hints XLA replicates logits/activation intermediates (6 TB temp on
    llama3-405b train) and inserts full-tensor all-reduces."""
    try:
        from jax.sharding import PartitionSpec
        from jax._src import mesh as mesh_lib

        mesh = mesh_lib.thread_resources.env.physical_mesh
        names = () if mesh.empty else mesh.axis_names
        if "data" not in names:
            return x
        ba = ("pod", "data") if "pod" in names else ("data",)
        resolved = tuple(
            ba if s_ == "batch" else (s_ if s_ in names else None) for s_ in spec
        )
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*resolved))
    except Exception:
        return x


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


# Perf iteration 3 (EXPERIMENTS §5): FSDP (weight sharding over the data
# axis) pays a per-layer all-gather on every step. For models whose full
# fp32 train state (p+g+m+v = 16 B/param) fits one chip's HBM with room for
# activations, replicating weights across the data axis removes those
# gathers entirely — the only cross-data collective left is the single
# gradient all-reduce.
FSDP_STATE_BYTES_THRESHOLD = 12e9


def _use_fsdp(cfg: ModelConfig) -> bool:
    import jax.numpy as jnp

    per_param = (
        2 * jnp.dtype(cfg.param_dtype).itemsize  # p + g
        + 2 * jnp.dtype(cfg.opt_state_dtype).itemsize  # m + v
    )
    return cfg.param_count() * per_param > FSDP_STATE_BYTES_THRESHOLD


def param_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape) -> P:
    """PartitionSpec for one parameter leaf (path is '/'-joined)."""
    fsdp = "data" if _use_fsdp(cfg) else None
    tp = "model"
    nd = len(shape)

    def ok(dim_size, axis):
        return _div(dim_size, mesh, axis)

    name = path.split("/")[-1]
    stacked = path.startswith("blocks/") or path.startswith("encoder/")
    # how many leading stack dims (hybrid grouping adds none at init)
    lead = 1 if stacked and nd >= 2 else 0

    heads_shardable = cfg.num_heads > 0 and ok(cfg.num_heads, tp)

    if name in ("embed", "lm_head") or path in ("embed", "lm_head"):
        spec = [None] * nd
        if ok(shape[0], tp):
            spec[0] = tp
        if ok(shape[1], fsdp):
            spec[1] = fsdp
        return P(*spec)

    if name in ("wq", "wk", "wv"):
        spec = [None] * nd
        if ok(shape[lead], fsdp):
            spec[lead] = fsdp
        out_ok = heads_shardable if name == "wq" else ok(cfg.num_kv_heads, tp)
        if out_ok and ok(shape[lead + 1], tp):
            spec[lead + 1] = tp
        return P(*spec)
    if name == "wo":
        spec = [None] * nd
        if heads_shardable and ok(shape[lead], tp):
            spec[lead] = tp
        if ok(shape[lead + 1], fsdp):
            spec[lead + 1] = fsdp
        return P(*spec)
    if name in ("wg", "wu"):  # (L?, [E,] D, F)
        spec = [None] * nd
        if ok(shape[-1], tp):
            spec[-1] = tp
        if ok(shape[-2], fsdp):
            spec[-2] = fsdp
        return P(*spec)
    if name == "wd":  # (L?, [E,] F, D)
        spec = [None] * nd
        if ok(shape[-2], tp):
            spec[-2] = tp
        if ok(shape[-1], fsdp):
            spec[-1] = fsdp
        return P(*spec)
    if name == "router":
        spec = [None] * nd
        if ok(shape[-2], fsdp):
            spec[-2] = fsdp
        return P(*spec)
    if name == "w_in":  # (L, D, K)
        spec = [None] * nd
        if ok(shape[-2], fsdp):
            spec[-2] = fsdp
        if ok(shape[-1], tp):
            spec[-1] = tp
        return P(*spec)
    if name == "w_out":  # (L, d_inner, D)
        spec = [None] * nd
        if ok(shape[-2], tp):
            spec[-2] = tp
        if ok(shape[-1], fsdp):
            spec[-1] = fsdp
        return P(*spec)
    if name == "norm" and nd >= 2:  # ssm gated norm (L, d_inner)
        spec = [None] * nd
        if ok(shape[-1], tp):
            spec[-1] = tp
        return P(*spec)
    # norms, scalars, biases: replicated
    return P(*([None] * nd))


def params_shardings(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """Map a params (shape-)pytree to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        spath = "/".join(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        specs.append(
            NamedSharding(mesh, param_spec(cfg, mesh, spath, leaf.shape))
        )
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    """Input batch: leading dim over (pod, data) when divisible."""
    ba = batch_axes(mesh)
    n_b = int(np.prod([mesh.shape[a] for a in ba]))

    def spec(leaf):
        if leaf.shape and leaf.shape[0] % n_b == 0 and leaf.shape[0] > 1:
            return NamedSharding(mesh, P(ba, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree.map(spec, batch_shape)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape, seq_sharded: bool):
    """Decode cache: batch-sharded normally; seq-sharded for long_500k."""
    ba = batch_axes(mesh)
    n_b = int(np.prod([mesh.shape[a] for a in ba]))

    def spec(leaf):
        shp = leaf.shape
        nd = len(shp)
        if nd == 1:  # lengths
            return NamedSharding(mesh, P(None))
        # caches have a leading layer/group dim, batch at dim 1
        if seq_sharded:
            # shard the sequence dim (dim 2 of (L,B,S,KV,hd)) over data
            if nd >= 3 and shp[2] % mesh.shape["data"] == 0 and shp[2] > 1:
                return NamedSharding(
                    mesh, P(*([None, None, "data"] + [None] * (nd - 3)))
                )
            return NamedSharding(mesh, P(*([None] * nd)))
        if nd >= 2 and shp[1] % n_b == 0 and shp[1] > 1:
            return NamedSharding(mesh, P(*([None, ba] + [None] * (nd - 2))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree.map(spec, cache_shape)
