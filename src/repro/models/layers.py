"""Core NN layers: RMSNorm, RoPE / M-RoPE, SwiGLU, initializers.

Pure-function JAX (no framework deps); parameters are plain pytrees.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def rope(
    x: jnp.ndarray,  # (..., S, H, hd)
    positions: jnp.ndarray,  # (..., S)
    theta: float = 1e4,
) -> jnp.ndarray:
    """Standard rotary embedding (half-split convention)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(
    x: jnp.ndarray,  # (..., S, H, hd)
    positions3: jnp.ndarray,  # (..., 3, S): t/h/w position ids
    sections: Tuple[int, int, int],
    theta: float = 1e6,
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): rotary half-dims are split into t/h/w
    sections, each rotated by its own position stream."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(hd, theta)  # (half,)
    # Select which position stream drives each frequency slot.
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # (half,)
    # positions3: (..., 3, S) -> (..., S, half)
    pos = jnp.take_along_axis(
        jnp.moveaxis(positions3, -2, -1),  # (..., S, 3)
        jnp.broadcast_to(sec_id, positions3.shape[:-2] + (positions3.shape[-1], half)),
        axis=-1,
    )
    ang = pos.astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down.astype(x.dtype))


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jnp.ndarray:
    fan_in = shape[in_axis]
    std = (1.0 / fan_in) ** 0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)
