"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state; the dry-run sets XLA_FLAGS before any jax import to emulate
512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def mesh_num_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
