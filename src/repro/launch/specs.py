"""ShapeDtypeStruct input stands-ins for every (arch x shape) cell.

``input_specs`` mirrors the pattern used by shannon/kernels: weak-type-
correct, shardable, zero device allocation. The dry-run lowers
train/prefill/decode step functions against these.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import init_cache, init_params
from repro.optim.adamw import AdamWConfig, adamw_init

SDS = jax.ShapeDtypeStruct


def params_spec(cfg: ModelConfig) -> Any:
    """Shape pytree of the parameters (eval_shape over init)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def opt_state_spec(cfg: ModelConfig, pspec) -> Any:
    ocfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
    return jax.eval_shape(lambda p: adamw_init(p, ocfg), pspec)


def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    spec: Dict[str, Any] = {"labels": SDS((b, s), jnp.int32)}
    if cfg.family == "encdec":
        spec["tokens"] = SDS((b, s), jnp.int32)
        spec["frames"] = SDS((b, s, cfg.d_model), jnp.bfloat16)  # audio stub
    elif cfg.family == "vlm":
        spec["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)  # patch stub
        spec["positions3"] = SDS((b, 3, s), jnp.int32)
    else:
        spec["tokens"] = SDS((b, s), jnp.int32)
    return spec


def cache_spec(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def decode_tokens_spec(shape: ShapeConfig):
    return SDS((shape.global_batch, 1), jnp.int32)
