import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as its own process (``python -m repro.launch.dryrun``) so
the XLA_FLAGS above take effect before jax initializes. Emits one JSON per
cell under results/dryrun/ with memory analysis, cost analysis, and the
collective-bytes breakdown the roofline reads.

Usage:
  python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_num_devices  # noqa: E402
from repro.launch.steps import cell_step_and_specs  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        print(f"[skip] {tag}")
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        _dump(path, rec)
        print(f"[skipped-by-design] {tag}: {why}")
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        step, in_specs, in_shardings = cell_step_and_specs(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(step, in_shardings=in_shardings)
            lowered = jitted.lower(*in_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            coll = collective_bytes(hlo)

        n_dev = mesh_num_devices(mesh)
        rec.update(
            status="ok",
            devices=n_dev,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1)) if cost else -1,
            bytes_accessed=float(cost.get("bytes accessed", -1)) if cost else -1,
            memory=_mem_dict(mem),
            collectives=coll,
            param_count=cfg.param_count(),
            active_param_count=cfg.active_param_count(),
        )
        print(
            f"[ok] {tag}: lower {t_lower:.0f}s compile {t_compile:.0f}s "
            f"flops={rec['flops']:.3g} coll={coll['total_bytes']:.3g}B"
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:2000])
        traceback.print_exc()
        print(f"[ERROR] {tag}: {e}")
    _dump(path, rec)
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _dump(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        f"dry-run needs 512 emulated devices, got {len(jax.devices())}; "
        "run as a fresh process"
    )

    cells = []
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            if not args.multi_pod_only:
                cells.append((a, s, False))
            if not args.single_pod_only:
                cells.append((a, s, True))
    if args.multi_pod and not args.all and args.arch:
        cells = [(args.arch, s, True) for s in shapes]

    for a, s, mp in cells:
        run_cell(a, s, mp, args.out)


if __name__ == "__main__":
    main()
