"""HLO text analysis: collective byte counting for the roofline.

``cost_analysis()`` has FLOPs and memory bytes but not collective traffic;
we parse the (compiled or lowered) HLO text and sum the result-shape bytes
of every collective op, bucketed by op kind.
"""
from __future__ import annotations

import re
from typing import Dict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {count, bytes} from HLO text.

    Bytes are the op's result-shape bytes — the payload that crosses links
    (for all-gather this is the gathered size; for reduce-scatter the
    scattered size; a per-kind link-traffic factor is applied in the
    roofline, not here).
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            token = f" {kind}("
            idx = line.find(token)
            if idx < 0:
                # fused/start variants: all-reduce-start(
                token = f" {kind}-start("
                idx = line.find(token)
                if idx < 0:
                    continue
            lhs = line[:idx]
            if "=" not in lhs:
                continue
            result_seg = lhs.split("=", 1)[1]
            b = _shape_bytes(result_seg)
            out[kind]["count"] += 1
            out[kind]["bytes"] += b
            break
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict)
    )
    return out
