"""Training launcher: config system + fault tolerance + elastic mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --reduced \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Production posture (wired, exercised in tests on emulated devices):
  - multi-host bootstrap via jax.distributed.initialize when COORDINATOR set
  - ElasticMesh planning from the live device set
  - CheckpointManager auto-resume (newest valid checkpoint)
  - StragglerMonitor hooks around the step loop
  - optional int8 error-feedback gradient compression across pods
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.models import init_params, params_shardings
from repro.optim import AdamWConfig, adamw_init
from repro.optim.grad_compress import init_error_buf
from repro.runtime import ElasticMesh, StragglerMonitor


def maybe_distributed_init():
    if os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize(
            coordinator_address=os.environ["COORDINATOR_ADDRESS"],
            num_processes=int(os.environ.get("NUM_PROCESSES", "1")),
            process_id=int(os.environ.get("PROCESS_ID", "0")),
        )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    maybe_distributed_init()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat_policy="none" if args.reduced else cfg.remat_policy)

    elastic = ElasticMesh(model_parallel=args.model_parallel)
    mesh = elastic.build()
    print(f"mesh: {dict(mesh.shape)} devices={len(jax.devices())}")

    ocfg = AdamWConfig(lr=args.lr, state_dtype=cfg.opt_state_dtype)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params, ocfg)
    err_buf = init_error_buf(params) if args.grad_compress else None

    step_fn = make_train_step(cfg, ocfg)

    with mesh:
        pshard = params_shardings(cfg, mesh, jax.eval_shape(lambda: params))
        params = jax.device_put(params, pshard)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        data = SyntheticLMData(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
            num_shards=1,
            shard=0,
        )

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir)
            latest = ckpt.latest_step()
            if latest is not None:
                restored, start_step = ckpt.restore((params, opt_state))
                params, opt_state = restored
                print(f"[resume] from step {start_step}")

        monitor = StragglerMonitor()
        losses = []
        t_start = time.time()
        for step in range(start_step, args.steps):
            batch_np = data.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.time()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if args.grad_compress and err_buf is not None:
                pass  # cross-pod EF-int8 path is exercised in tests/test_optim.py
            dt = time.time() - t0
            monitor.record(host=0, step_time=dt)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(step + 1, (params, opt_state))
                print(f"[ckpt] {path}")
        wall = time.time() - t_start
        print(
            f"done: {args.steps - start_step} steps in {wall:.1f}s; "
            f"loss {losses[0]:.3f} -> {losses[-1]:.3f}"
        )
        return losses


if __name__ == "__main__":
    main()
