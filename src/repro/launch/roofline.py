"""Roofline analysis from the dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = link_bytes_per_chip / ICI_bw             (50 GB/s)

XLA SPMD cost_analysis reports *per-partition* numbers (the program is
single-device SPMD), so no division by chip count is needed. Collective
link-bytes convention: all-reduce counts 2x its payload (ring reduce +
broadcast phases), all-gather / reduce-scatter / all-to-all /
collective-permute count 1x their result bytes — stated here once, used
everywhere.

The "roofline fraction" figure of merit is compute_term / max(all terms):
1.0 means the step is compute-bound at peak (perfectly overlapped); lower
means the dominant non-compute term caps utilization. MODEL_FLOPS
(6·N·D_tokens, active params for MoE) over global HLO FLOPs catches
remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

LINK_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

SHAPE_TOKENS = {  # global tokens processed per step
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}

# MODEL_FLOPS conventions: 6·N·T for training (fwd 2NT + bwd 4NT),
# 2·N·T for inference.
FLOPS_PER_PARAM_TOKEN = {"train": 6.0, "prefill": 2.0, "decode": 2.0}


def load_cells(dryrun_dir: str = "results/dryrun") -> List[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def _cfg_info(arch: str, shape: str) -> dict:
    """Analytic model facts for the memory bound (no device allocation)."""
    import numpy as np

    from repro.configs import SHAPES, get_config
    from repro.launch import specs

    cfg = get_config(arch)
    info = dict(d_model=cfg.d_model, layers=cfg.num_layers)
    if SHAPES[shape].mode == "decode":
        cspec = specs.cache_spec(cfg, SHAPES[shape])
        info["cache_bytes"] = float(
            sum(
                np.prod(leaf.shape) * leaf.dtype.itemsize
                for leaf in __import__("jax").tree.leaves(cspec)
            )
        )
    return info


def _analytic_memory_bytes(rec: dict) -> float:
    """Fused-execution HBM-traffic lower bound per device per step.

    cost_analysis 'bytes accessed' on the CPU backend is unfused-op
    accounting (every intermediate counted), a ~100x overestimate of real
    HBM traffic; this analytic bound is what the roofline's memory term
    uses. Conventions: train touches params 4x in fp32 (p, g, m, v
    read+write amortized) + one activation save + read per layer; inference
    reads bf16 active params once + the KV/state cache."""
    devices = rec.get("devices", 256)
    n = rec.get("param_count") or 0
    n_active = rec.get("active_param_count") or n
    tokens = SHAPE_TOKENS[rec["shape"]]
    info = _cfg_info(rec["arch"], rec["shape"])
    d_model, layers = info["d_model"], info["layers"]
    if rec["mode"] == "train":
        param_traffic = n * 4.0 * 4  # fp32 p/g/m/v r+w amortized
        act = 2.0 * layers * tokens * d_model * 2  # save+read per layer, bf16
        total = param_traffic + act
    elif rec["mode"] == "prefill":
        total = n_active * 2.0 + 2.0 * layers * tokens * d_model * 2
    else:  # decode: params replicate over the data axis (weights are
        # TP-sharded only), so each chip streams its model-axis shard; the
        # cache is batch/seq sharded over all devices.
        model_shards = 16
        return n_active * 2.0 / model_shards + info.get("cache_bytes", 0.0) / devices
    return total / devices


def analyze_cell(rec: dict, probe: Optional[dict] = None) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    flops = probe["flops"] if probe else rec["flops"]
    raw_total = sum(
        v["bytes"]
        for k, v in rec.get("collectives", {}).items()
        if isinstance(v, dict)
    )
    weighted = sum(
        LINK_FACTOR[k] * v["bytes"]
        for k, v in rec.get("collectives", {}).items()
        if isinstance(v, dict) and k in LINK_FACTOR
    )
    if probe:
        # probe gives depth-corrected totals; apply the raw mix's average
        # link factor (falls back to 1.3 when the raw program had none).
        factor = weighted / raw_total if raw_total > 0 else 1.3
        link_bytes = probe["coll_bytes"] * factor
    else:
        link_bytes = weighted
    membytes = _analytic_memory_bytes(rec)
    t_comp = flops / PEAK_FLOPS
    t_mem = membytes / HBM_BW
    t_coll = link_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = t_comp / bound if bound > 0 else 0.0

    tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = rec.get("active_param_count") or rec.get("param_count") or 0
    model_flops = FLOPS_PER_PARAM_TOKEN[rec["mode"]] * n_active * tokens
    global_hlo = flops * rec.get("devices", 1)
    useful = model_flops / global_hlo if global_hlo > 0 else 0.0

    hint = {
        "compute": "compute-bound: raise per-chip utilization (larger "
        "per-device tiles, fused kernels)",
        "memory": "HBM-bound: reduce activation traffic (fusion, lighter "
        "remat policy, wider batching per chip)",
        "collective": "ICI-bound: reshard to cut collective payload or "
        "overlap collectives with compute (async scheduling)",
    }[dominant]
    return dict(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        mode=rec["mode"],
        compute_s=t_comp,
        memory_s=t_mem,
        collective_s=t_coll,
        dominant=dominant,
        roofline_fraction=frac,
        model_flops=model_flops,
        hlo_flops_global=global_hlo,
        useful_flop_ratio=useful,
        hint=hint,
    )


def load_probes(probe_dir: str = "results/layerprobe") -> Dict[tuple, dict]:
    out = {}
    for f in glob.glob(os.path.join(probe_dir, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def table(cells=None, mesh: str = "16x16", probes=None) -> List[dict]:
    cells = cells if cells is not None else load_cells()
    probes = probes if probes is not None else load_probes()
    rows = []
    for rec in cells:
        if rec.get("mesh") != mesh:
            continue
        probe = probes.get((rec.get("arch"), rec.get("shape"), rec.get("mesh")))
        r = analyze_cell(rec, probe)
        if r:
            r["depth_corrected"] = probe is not None
            rows.append(r)
    return rows


def markdown(rows: List[dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline frac | useful FLOP ratio |\n|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['roofline_fraction']:.2f} | {r['useful_flop_ratio']:.2f} |"
        )
    return "\n".join(lines)


def main():
    rows = table()
    print(markdown(rows))
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    # pick hillclimb candidates
    ok = [r for r in rows if r["roofline_fraction"] > 0]
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["collective_s"] / max(r["compute_s"], 1e-12))
    print("\nworst roofline fraction:", worst["arch"], worst["shape"],
          f"{worst['roofline_fraction']:.2f}")
    print("most collective-bound:", coll["arch"], coll["shape"],
          f"coll/comp={coll['collective_s']/max(coll['compute_s'],1e-12):.1f}")


if __name__ == "__main__":
    main()
