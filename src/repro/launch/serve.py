"""Serving launcher: batched prefill + decode with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import init_cache, init_params
from repro.runtime import ElasticMesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mesh = ElasticMesh(model_parallel=args.model_parallel).build()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    serve_step = jax.jit(make_serve_step(cfg))

    with mesh:
        rng = np.random.default_rng(args.seed)
        max_len = args.prompt_len + args.gen + 1
        cache = init_cache(cfg, args.batch, max_len)
        tok = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, 1)), jnp.int32
        )
        # prefill modeled as sequential decode of the prompt (exercises the
        # same cache path; a fused prefill_step exists for the dry-run cells)
        t0 = time.time()
        for i in range(args.prompt_len):
            nxt = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (args.batch, 1)), jnp.int32
            )
            _, cache = serve_step(params, tok, cache)
            tok = nxt
        t_prefill = time.time() - t0

        outs = []
        t0 = time.time()
        for i in range(args.gen):
            tok, cache = serve_step(params, tok, cache)
            outs.append(np.asarray(tok))
        t_gen = time.time() - t0
        gen = np.concatenate(outs, axis=1)
        tps = args.batch * args.gen / max(t_gen, 1e-9)
        print(f"prefill {args.prompt_len} toks: {t_prefill:.2f}s")
        print(f"decode  {args.gen} toks x {args.batch} seqs: {t_gen:.2f}s ({tps:.1f} tok/s)")
        print("sample:", gen[0, :16].tolist())
        assert np.isfinite(gen).all()
        return gen


if __name__ == "__main__":
    main()
