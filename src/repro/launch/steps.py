"""Step builders: train_step / prefill_step / serve_step with shardings.

These are what the dry-run lowers and what train.py/serve.py execute.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import specs
from repro.models import (
    batch_shardings,
    cache_shardings,
    decode_step,
    forward,
    loss_fn,
    params_shardings,
)
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(cfg: ModelConfig, ocfg: Optional[AdamWConfig] = None):
    ocfg = ocfg or AdamWConfig(state_dtype=cfg.opt_state_dtype)

    def train_step(params, opt_state, batch):
        def lf(p):
            return loss_fn(cfg, p, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        lr_scale = cosine_schedule(opt_state["step"])
        new_params, new_state = adamw_update(params, grads, opt_state, ocfg, lr_scale)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _, cache = forward(
            cfg,
            params,
            batch.get("tokens"),
            embeds=batch.get("embeds"),
            positions3=batch.get("positions3"),
            encoder_frames=batch.get("frames"),
            return_cache=True,
        )
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None, seq_sharded: bool = False):
    def serve_step(params, tokens, cache):
        logits, cache = decode_step(
            cfg, params, tokens, cache, mesh=mesh, seq_sharded=seq_sharded
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def cell_step_and_specs(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
) -> Tuple[Any, tuple, tuple]:
    """(step_fn, in_specs, in_shardings) for one (arch x shape x mesh) cell."""
    pspec = specs.params_spec(cfg)
    pshard = params_shardings(cfg, mesh, pspec)
    if shape.mode == "train":
        ospec = specs.opt_state_spec(cfg, pspec)
        oshard = {
            "m": params_shardings(cfg, mesh, ospec["m"]),
            "v": params_shardings(cfg, mesh, ospec["v"]),
            "step": NamedSharding(mesh, P()),
        }
        bspec = specs.batch_spec(cfg, shape)
        bshard = batch_shardings(cfg, mesh, bspec)
        step = make_train_step(cfg)
        return step, (pspec, ospec, bspec), (pshard, oshard, bshard)
    if shape.mode == "prefill":
        bspec = specs.batch_spec(cfg, shape)
        bspec.pop("labels")
        bshard = batch_shardings(cfg, mesh, bspec)
        step = make_prefill_step(cfg)
        return step, (pspec, bspec), (pshard, bshard)
    # decode
    seq_sharded = shape.name == "long_500k" and cfg.family in ("hybrid",)
    cspec = specs.cache_spec(cfg, shape)
    cshard = cache_shardings(cfg, mesh, cspec, seq_sharded=seq_sharded)
    tspec = specs.decode_tokens_spec(shape)
    tshard = batch_shardings(cfg, mesh, {"t": tspec})["t"]
    step = make_serve_step(cfg, mesh=mesh, seq_sharded=seq_sharded)
    return step, (pspec, tspec, cspec), (pshard, tshard, cshard)
