import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Two-point layer probe: correct cost_analysis for scan-over-layers.

XLA cost_analysis counts a while-loop body ONCE (not x trip count), so the
dry-run's raw FLOP/byte/collective numbers undercount everything inside the
layer scan by ~L. Lowering each (arch x shape) at two small depths L1 < L2
and fitting  cost(L) = fixed + L * per_layer  recovers the exact full-depth
cost for any program linear in L — which scan-over-layers programs are
(stacked-param optimizer updates and gradient all-reduces outside the scan
are linear in L too, so the fit captures them).

Hybrid (zamba2) scans over GROUPS of (every + shared-attn): the probe varies
the group count with the tail fixed. Enc-dec varies encoder+decoder depth
together (whisper has Le == Ld).

Writes results/layerprobe/<arch>__<shape>__<mesh>.json with extrapolated
flops / bytes / collective bytes.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import cell_step_and_specs  # noqa: E402


def probe_depths(cfg):
    """(L1, L2, unit_count_full, make_cfg(L)) for the two-point fit."""
    # Probes lower UNROLLED (cost_analysis does not descend into while
    # bodies), so inline per-layer costs are fully counted and linear in L.
    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        tail = cfg.num_layers - (cfg.num_layers // every) * every
        full_groups = cfg.num_layers // every

        def mk(groups):
            return dataclasses.replace(
                cfg, num_layers=groups * every + tail, scan_layers=False
            )

        return 1, 2, full_groups, mk
    if cfg.family == "encdec":

        def mk(layers):
            return dataclasses.replace(
                cfg, num_layers=layers, encoder_layers=layers, scan_layers=False
            )

        return 1, 2, cfg.num_layers, mk

    def mk(layers):
        return dataclasses.replace(cfg, num_layers=layers, scan_layers=False)

    return 2, 4, cfg.num_layers, mk


def measure(cfg, shape, mesh):
    step, in_specs, in_shardings = cell_step_and_specs(cfg, shape, mesh)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_shardings).lower(*in_specs)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = collective_bytes(hlo)
    return dict(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total_bytes"]),
        coll=coll,
    )


def run_probe(arch: str, shape_name: str, out_dir: str, multi_pod=False):
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        print(f"[skip] {tag}")
        return
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return
    try:
        t0 = time.time()
        l1, l2, full_units, mk = probe_depths(cfg)
        mesh = make_production_mesh(multi_pod=multi_pod)
        m1 = measure(mk(l1), shape, mesh)
        m2 = measure(mk(l2), shape, mesh)
        out = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "probe_l1": l1, "probe_l2": l2, "full_units": full_units}
        for key in ("flops", "bytes", "coll_bytes"):
            per_unit = (m2[key] - m1[key]) / (l2 - l1)
            fixed = m1[key] - l1 * per_unit
            out[key] = fixed + full_units * per_unit
            out[key + "_per_layer"] = per_unit
            out[key + "_fixed"] = fixed
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[ok] {tag} ({time.time()-t0:.0f}s): flops={out['flops']:.3g} "
              f"coll={out['coll_bytes']:.3g}B")
    except Exception as e:  # noqa: BLE001
        traceback.print_exc()
        print(f"[ERROR] {tag}: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--out", default="results/layerprobe")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    assert len(jax.devices()) == 512
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            run_probe(a, s, args.out, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
