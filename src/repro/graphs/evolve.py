"""Evolving-graph dynamics following the paper's Section VI protocol.

"these kernels are simulated twice with two different inputs ... For the
first time, 80% of the vertices are randomly selected; for the second time,
10% of vertices from the first input graph are randomly deleted and 10% of
vertices from the original input are added."

Vertex ids are PRESERVED across the two runs (the property/target arrays are
indexed by original vertex id), which is what makes the access-to-miss
correlations recorded on run-1 partially valid on run-2 — the effect AMC
exploits.  ``induced_subgraph`` (now hosted in :mod:`repro.graphs.csr`)
therefore keeps the original id space and masks vertices instead of
compacting ids.

The two-run protocol is the E=2 special case of the multi-epoch streaming
subsystem: :func:`make_evolving_pair` delegates to
``repro.stream.snapshots.snapshot_sequence`` with the §VI
``UniformChurn(init_frac=0.8, del_frac=0.10, add_frac=0.10)`` model, which
performs the exact same rng draws in the exact same order — the produced
masks and CSR arrays are bit-identical to the original two-run
implementation (asserted in ``tests/test_stream.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.csr import CSRGraph, induced_subgraph  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class EvolvingGraphPair:
    base: CSRGraph  # original full graph
    run1: CSRGraph  # 80% induced subgraph
    run2: CSRGraph  # run1 - 10% + 10% fresh
    mask1: np.ndarray
    mask2: np.ndarray

    @property
    def vertex_overlap(self) -> float:
        """Fraction of run-1's active vertices still present in run-2."""
        both = (self.mask1 & self.mask2).sum()
        return float(both / max(self.mask1.sum(), 1))


def make_evolving_pair(g: CSRGraph, seed: int = 0) -> EvolvingGraphPair:
    """§VI two-run protocol — the E=2 epoch sequence under uniform churn."""
    # Imported here: repro.stream builds on repro.graphs, not the reverse.
    from repro.stream.snapshots import snapshot_sequence
    from repro.stream.updates import UniformChurn

    seq = snapshot_sequence(g, UniformChurn(), epochs=2, seed=seed)
    return EvolvingGraphPair(
        base=g,
        run1=dataclasses.replace(seq.graphs[0], name=g.name + "@run1"),
        run2=dataclasses.replace(seq.graphs[1], name=g.name + "@run2"),
        mask1=seq.masks[0],
        mask2=seq.masks[1],
    )
