"""Evolving-graph dynamics following the paper's Section VI protocol.

"these kernels are simulated twice with two different inputs ... For the
first time, 80% of the vertices are randomly selected; for the second time,
10% of vertices from the first input graph are randomly deleted and 10% of
vertices from the original input are added."

Vertex ids are PRESERVED across the two runs (the property/target arrays are
indexed by original vertex id), which is what makes the access-to-miss
correlations recorded on run-1 partially valid on run-2 — the effect AMC
exploits. ``induced_subgraph`` therefore keeps the original id space and
masks vertices instead of compacting ids.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.csr import CSRGraph, from_edges


def induced_subgraph(g: CSRGraph, keep_mask: np.ndarray, name: str) -> CSRGraph:
    """Induced subgraph on ``keep_mask`` vertices, original id space."""
    src = g.edge_sources()
    dst = g.neighbors
    e_keep = keep_mask[src] & keep_mask[dst]
    w = g.weights[e_keep] if g.weights is not None else None
    return from_edges(
        src[e_keep], dst[e_keep], g.num_vertices, weights=w, dedup=False, name=name
    )


@dataclasses.dataclass(frozen=True)
class EvolvingGraphPair:
    base: CSRGraph  # original full graph
    run1: CSRGraph  # 80% induced subgraph
    run2: CSRGraph  # run1 - 10% + 10% fresh
    mask1: np.ndarray
    mask2: np.ndarray

    @property
    def vertex_overlap(self) -> float:
        """Fraction of run-1's active vertices still present in run-2."""
        both = (self.mask1 & self.mask2).sum()
        return float(both / max(self.mask1.sum(), 1))


def make_evolving_pair(g: CSRGraph, seed: int = 0) -> EvolvingGraphPair:
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    # Run 1: random 80% of vertices.
    mask1 = np.zeros(n, dtype=bool)
    mask1[rng.choice(n, size=int(0.8 * n), replace=False)] = True
    run1 = induced_subgraph(g, mask1, g.name + "@run1")

    # Run 2: delete 10% of run-1's vertices, add 10% (of the original count)
    # from the not-yet-selected pool.
    in1 = np.flatnonzero(mask1)
    out1 = np.flatnonzero(~mask1)
    n_del = int(0.10 * len(in1))
    n_add = min(int(0.10 * n), len(out1))
    mask2 = mask1.copy()
    mask2[rng.choice(in1, size=n_del, replace=False)] = False
    mask2[rng.choice(out1, size=n_add, replace=False)] = True
    run2 = induced_subgraph(g, mask2, g.name + "@run2")
    return EvolvingGraphPair(base=g, run1=run1, run2=run2, mask1=mask1, mask2=mask2)
