"""Graph partitioning for the paper's 4-worker SPMD setup (METIS stand-in).

The paper partitions with METIS [27] into 4 parts processed by SPMD workers.
METIS is unavailable offline; we provide (1) a BFS reordering that clusters
connected neighborhoods into contiguous id ranges, followed by (2) balanced
contiguous-range partitioning — the standard lightweight approximation with
the same locality intent (neighbors land in the same part far more often
than random). The tracer simulates worker 0's private L1/L2 per Table VI.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.graphs.csr import CSRGraph, from_edges


def bfs_reorder(g: CSRGraph, seed: int = 0) -> np.ndarray:
    """Return ``order`` s.t. new_id = order[old_id], BFS-clustered."""
    n = g.num_vertices
    rng = np.random.default_rng(seed)
    visited = np.zeros(n, dtype=bool)
    order = np.full(n, -1, dtype=np.int64)
    nxt = 0
    # Iterative BFS from highest-degree roots (covers disconnected parts).
    roots = np.argsort(-g.degrees)
    for root in roots:
        if visited[root]:
            continue
        frontier = np.array([root], dtype=np.int64)
        visited[root] = True
        while len(frontier):
            for v in frontier:
                order[v] = nxt
                nxt += 1
            # gather all unvisited neighbors
            outs: List[np.ndarray] = []
            for v in frontier:
                s, e = g.offsets[v], g.offsets[v + 1]
                outs.append(g.neighbors[s:e])
            if outs:
                cand = np.unique(np.concatenate(outs))
                cand = cand[~visited[cand]]
            else:
                cand = np.empty(0, dtype=np.int64)
            visited[cand] = True
            frontier = cand
        if nxt >= n:
            break
    # Isolated leftovers.
    rest = np.flatnonzero(order < 0)
    order[rest] = np.arange(nxt, nxt + len(rest))
    _ = rng  # determinism hook
    return order


def partition_contiguous(
    g: CSRGraph, num_parts: int = 4, reorder: bool = True, seed: int = 0
) -> Tuple[List[CSRGraph], np.ndarray]:
    """Split into ``num_parts`` edge-balanced contiguous vertex ranges.

    Returns per-part CSR graphs (original id space, edges owned by the part's
    sources) plus the part assignment array.
    """
    n = g.num_vertices
    if reorder:
        order = bfs_reorder(g, seed=seed)
    else:
        order = np.arange(n, dtype=np.int64)
    # Edge-balanced split over the reordered vertex sequence.
    inv = np.argsort(order)
    deg_seq = g.degrees[inv]
    cum = np.cumsum(deg_seq)
    total = cum[-1] if len(cum) else 0
    bounds = np.searchsorted(cum, (np.arange(1, num_parts) * total) // num_parts)
    part_of_pos = np.zeros(n, dtype=np.int32)
    for i, b in enumerate(bounds):
        part_of_pos[b + 1 :] = i + 1  # noqa: E203
    part = np.zeros(n, dtype=np.int32)
    part[inv] = part_of_pos
    src = g.edge_sources()
    parts = []
    for p in range(num_parts):
        keep = part[src] == p
        w = g.weights[keep] if g.weights is not None else None
        parts.append(
            from_edges(
                src[keep], g.neighbors[keep], n, weights=w, dedup=False,
                name=f"{g.name}.p{p}",
            )
        )
    return parts, part


def edge_balance(parts: List[CSRGraph]) -> float:
    """max/mean edge count across parts (1.0 = perfectly balanced)."""
    counts = np.array([p.num_edges for p in parts], dtype=np.float64)
    return float(counts.max() / max(counts.mean(), 1e-9))
