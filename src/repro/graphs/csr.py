"""Compressed-sparse-row graph container used by every app and the tracer.

Layout matches the paper's Fig 3 data-structure model:
  V (offsets)   -- vertex array: CSR row pointers, one slot per vertex (+1)
  N (neighbors) -- edge array: destination vertex ids, CSR order
  P (property)  -- per-vertex property array (rank / distance / component)
  F (frontier)  -- per-vertex bitmap of active vertices

Arrays are plain ``numpy`` on the host (graph construction is host-side data
plumbing) and are exported to ``jnp`` device arrays once via
:meth:`CSRGraph.device` for the JAX apps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

try:  # JAX is required by the apps; csr itself stays importable without it.
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Immutable CSR graph. ``offsets`` has length n+1; ``neighbors`` length m."""

    offsets: np.ndarray  # int64 (n+1,)
    neighbors: np.ndarray  # int32 (m,)
    weights: Optional[np.ndarray] = None  # float32 (m,) for BellmanFord
    name: str = "graph"

    @property
    def num_vertices(self) -> int:
        return int(self.offsets.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.neighbors.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets)

    @property
    def avg_degree(self) -> float:
        n = max(self.num_vertices, 1)
        return self.num_edges / n

    def edge_sources(self) -> np.ndarray:
        """Expand CSR rows to a per-edge source array (int32, length m)."""
        return np.repeat(
            np.arange(self.num_vertices, dtype=np.int32), self.degrees
        )

    def transpose(self) -> "CSRGraph":
        """The CSC view: in-edges of this graph as a CSR graph over the
        same vertex id space (row ``d`` lists the sources of ``d``'s
        in-edges; weights are carried per edge).

        Pull-mode (dense) EDGEMAP traverses this view — destinations scan
        their in-edge rows sequentially and gather source properties.  The
        transpose is built once per graph and cached on the instance, so
        every pull step and the pull-mode tracer share one CSC build.
        """
        t = self.__dict__.get("_transpose")
        if t is None:
            src = self.edge_sources()
            t = from_edges(
                self.neighbors,
                src,
                self.num_vertices,
                weights=self.weights,
                dedup=False,
                name=self.name + "^T",
            )
            object.__setattr__(self, "_transpose", t)
        return t

    def device(self):
        """Return (offsets, neighbors, weights, edge_src) as jnp arrays."""
        assert jnp is not None, "jax not available"
        w = self.weights
        if w is None:
            w = np.ones(self.num_edges, dtype=np.float32)
        return (
            jnp.asarray(self.offsets),
            jnp.asarray(self.neighbors),
            jnp.asarray(w),
            jnp.asarray(self.edge_sources()),
        )

    def validate(self) -> None:
        n, m = self.num_vertices, self.num_edges
        assert self.offsets[0] == 0 and self.offsets[-1] == m
        assert np.all(np.diff(self.offsets) >= 0), "offsets must be monotone"
        if m:
            assert self.neighbors.min() >= 0 and self.neighbors.max() < n
        if self.weights is not None:
            assert self.weights.shape == (m,)


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    num_vertices: int,
    weights: Optional[np.ndarray] = None,
    dedup: bool = True,
    name: str = "graph",
) -> CSRGraph:
    """Build a CSR graph from an edge list (drops self loops, dedups)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if weights is not None:
        weights = np.asarray(weights, dtype=np.float32)[keep]
    if dedup and len(src):
        key = src * num_vertices + dst
        order = np.argsort(key, kind="stable")
        key = key[order]
        uniq = np.ones(len(key), dtype=bool)
        uniq[1:] = key[1:] != key[:-1]
        src, dst = src[order][uniq], dst[order][uniq]
        if weights is not None:
            weights = weights[order][uniq]
    else:
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        if weights is not None:
            weights = weights[order]
    counts = np.bincount(src, minlength=num_vertices).astype(np.int64)
    offsets = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    g = CSRGraph(
        offsets=offsets,
        neighbors=dst.astype(np.int32),
        weights=weights,
        name=name,
    )
    g.validate()
    return g


def build_csr(edges: np.ndarray, num_vertices: int, **kw) -> CSRGraph:
    """Convenience: edges is an (m, 2) array."""
    return from_edges(edges[:, 0], edges[:, 1], num_vertices, **kw)


def induced_subgraph(g: CSRGraph, keep_mask: np.ndarray, name: str) -> CSRGraph:
    """Induced subgraph on ``keep_mask`` vertices, original id space.

    Vertex ids are PRESERVED (vertices are masked, not compacted): the
    property/target arrays stay indexed by original vertex id across graph
    versions, which is what keeps access-to-miss correlations recorded on
    one version partially valid on the next — the effect AMC exploits.
    """
    src = g.edge_sources()
    dst = g.neighbors
    e_keep = keep_mask[src] & keep_mask[dst]
    w = g.weights[e_keep] if g.weights is not None else None
    return from_edges(
        src[e_keep], dst[e_keep], g.num_vertices, weights=w, dedup=False, name=name
    )


def symmetrize(g: CSRGraph) -> CSRGraph:
    """Return the undirected version of ``g`` (both edge directions)."""
    src = g.edge_sources()
    dst = g.neighbors.astype(np.int64)
    w = g.weights
    if w is not None:
        w = np.concatenate([w, w])
    return from_edges(
        np.concatenate([src, dst]),
        np.concatenate([dst, src]),
        g.num_vertices,
        weights=w,
        name=g.name + "+sym",
    )
