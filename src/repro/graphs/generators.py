"""Synthetic graph generators mirroring the paper's Table VII dataset shapes.

SNAP downloads are unavailable offline, so each dataset is represented by a
synthetic graph at ~1/32 the vertex count with matching average degree and
degree-distribution class:

  amazon    product network   RMAT (a=.57)     0.4M v, deg 9  -> 12.5k v
  stanford  web graph         RMAT (a=.65, skewed) 0.28M v, deg 9 -> 9k v
  youtube   social network    powerlaw (gamma=2.1) 1.16M v, deg 3 -> 36k v
  road-ca   road network      2-D lattice + shortcuts, deg 3      -> 61k v
  comdblp   collaboration     powerlaw clustered, deg 1(dir)      -> 13k v
  google    web graph         RMAT (a=.6) 0.88M v, deg 6          -> 27k v
  notredame web graph         RMAT (a=.63) 0.33M v, deg 5         -> 10k v

The properties AMC exploits (frontier sparsity, degree skew, cross-iteration
stability of the vertex-neighbor relation) are scale-free, so reduced-scale
graphs exercise the same mechanisms; EXPERIMENTS.md §1 records the scaling.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.graphs.csr import CSRGraph, from_edges


def rmat_graph(
    num_vertices: int,
    num_edges: int,
    a: float = 0.57,
    b: float = None,
    c: float = None,
    seed: int = 0,
    name: str = "rmat",
) -> CSRGraph:
    """R-MAT / Kronecker generator (power-law in/out degrees, communities).

    ``b``/``c`` default to an even split of the remaining mass so any
    skew parameter ``a`` < 1 is valid."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(num_vertices, 2))))
    n = 1 << scale
    # Oversample: dedup + self-loop removal eats some edges.
    m = int(num_edges * 1.35)
    if b is None:
        b = (1.0 - a) * 0.35
    if c is None:
        c = (1.0 - a) * 0.35
    d = 1.0 - a - b - c
    assert d >= 0, (a, b, c)
    probs = np.array([a, b, c, d])
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        q = rng.choice(4, size=m, p=probs)
        src = (src << 1) | (q >> 1)
        dst = (dst << 1) | (q & 1)
    # Fold into [0, num_vertices) and permute ids to break bit-structure.
    perm = rng.permutation(n)
    src = perm[src] % num_vertices
    dst = perm[dst] % num_vertices
    g = from_edges(src, dst, num_vertices, name=name)
    if g.num_edges > num_edges:
        keep = np.sort(rng.choice(g.num_edges, size=num_edges, replace=False))
        g = from_edges(
            g.edge_sources()[keep], g.neighbors[keep], num_vertices,
            dedup=False, name=name,
        )
    return g


def powerlaw_graph(
    num_vertices: int,
    num_edges: int,
    gamma: float = 2.1,
    seed: int = 0,
    name: str = "powerlaw",
) -> CSRGraph:
    """Configuration-model graph with power-law out-degrees."""
    rng = np.random.default_rng(seed)
    # Zipf-like degree weights, capped.
    ranks = np.arange(1, num_vertices + 1, dtype=np.float64)
    w = ranks ** (-1.0 / (gamma - 1.0))
    rng.shuffle(w)
    w /= w.sum()
    src = rng.choice(num_vertices, size=int(num_edges * 1.25), p=w)
    dst = rng.choice(num_vertices, size=int(num_edges * 1.25), p=w)
    g = from_edges(src, dst, num_vertices, name=name)
    if g.num_edges > num_edges:
        keep = np.sort(rng.choice(g.num_edges, size=num_edges, replace=False))
        g = from_edges(
            g.edge_sources()[keep], g.neighbors[keep], num_vertices,
            dedup=False, name=name,
        )
    return g


def road_graph(
    num_vertices: int,
    shortcut_frac: float = 0.05,
    seed: int = 0,
    name: str = "road",
) -> CSRGraph:
    """2-D lattice + a few shortcuts: low degree, huge diameter (road-CA class)."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(num_vertices))
    n = side * side
    idx = np.arange(n).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    lattice = np.concatenate([right, down])
    # Both directions.
    edges = np.concatenate([lattice, lattice[:, ::-1]])
    n_short = int(n * shortcut_frac)
    s = rng.integers(0, n, size=n_short)
    d = rng.integers(0, n, size=n_short)
    edges = np.concatenate([edges, np.stack([s, d], axis=1)])
    return from_edges(edges[:, 0], edges[:, 1], n, name=name)


# name -> (generator kind, vertices, edges, kwargs). Scaled ~1/8 of Table VII
# (paired with the 1/8-1/16-scaled cache hierarchy in memsim.config.SCALED).
# "tiny" is not a paper input: it is the fast-iteration cell used by the
# stream-protocol tests and the CI streaming smoke (seconds, not minutes).
DATASETS: Dict[str, dict] = {
    "tiny": dict(kind="powerlaw", n=3_000, m=9_000, gamma=2.2, seed=21),
    # "tinyroad" is the long-horizon fast cell: a pure 2-D lattice (no
    # shortcuts) whose huge diameter drives traversal kernels through
    # hundreds of small frontiers — the regime where whole-run batched
    # trace emission beats the per-iteration path hardest (bench-gated).
    "tinyroad": dict(kind="road", n=20_000, shortcut_frac=0.0, seed=18),
    "amazon": dict(kind="rmat", n=50_000, m=424_000, a=0.57, seed=11),
    "stanford": dict(kind="rmat", n=35_000, m=289_000, a=0.65, seed=12),
    "youtube": dict(kind="powerlaw", n=145_000, m=374_000, gamma=2.1, seed=13),
    "road-ca": dict(kind="road", n=246_000, seed=14),
    # "road-8m" is the paper-scale cell: ~2.1M vertices / ~8.4M directed
    # edges, the largest trace the repo emits. Its workload traces exceed
    # memory when materialized whole, so it is only reachable through the
    # ShardedSpec streaming-scoring path (bench-gated for flat peak RSS).
    "road-8m": dict(kind="road", n=2_100_000, seed=19),
    "comdblp": dict(kind="powerlaw", n=54_000, m=45_000, gamma=2.4, seed=15),
    "google": dict(kind="rmat", n=110_000, m=640_000, a=0.60, seed=16),
    "notredame": dict(kind="rmat", n=41_000, m=188_000, a=0.63, seed=17),
}

# Paper Table VII full-scale shapes, for reference and for storage-overhead
# normalization (vertices, edges in millions).
PAPER_SCALE = {
    "amazon": (0.4e6, 3.39e6),
    "stanford": (0.28e6, 2.31e6),
    "youtube": (1.16e6, 2.99e6),
    "road-ca": (1.97e6, 5.53e6),
    "comdblp": (0.43e6, 0.36e6),
    "google": (0.88e6, 5.11e6),
    "notredame": (0.33e6, 1.5e6),
}

_CACHE: Dict[str, CSRGraph] = {}


def make_dataset(name: str, weighted: bool = False, seed_offset: int = 0) -> CSRGraph:
    """Materialize a named synthetic dataset (memoized)."""
    key = f"{name}:{weighted}:{seed_offset}"
    if key in _CACHE:
        return _CACHE[key]
    spec = dict(DATASETS[name])
    kind = spec.pop("kind")
    spec["seed"] = spec.get("seed", 0) + seed_offset
    if kind == "rmat":
        g = rmat_graph(spec["n"], spec["m"], a=spec["a"], seed=spec["seed"], name=name)
    elif kind == "powerlaw":
        g = powerlaw_graph(spec["n"], spec["m"], gamma=spec["gamma"], seed=spec["seed"], name=name)
    elif kind == "road":
        g = road_graph(
            spec["n"],
            shortcut_frac=spec.get("shortcut_frac", 0.05),
            seed=spec["seed"],
            name=name,
        )
    else:  # pragma: no cover
        raise ValueError(kind)
    if weighted:
        rng = np.random.default_rng(spec["seed"] + 999)
        w = rng.integers(1, 16, size=g.num_edges).astype(np.float32)
        g = CSRGraph(g.offsets, g.neighbors, weights=w, name=g.name)
    _CACHE[key] = g
    return g
