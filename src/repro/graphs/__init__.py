"""Graph substrate: CSR graphs, synthetic generators, evolving-graph dynamics.

The paper evaluates evolving (dynamic) graph analytics on SNAP datasets.
Offline we synthesize graphs whose *shape statistics* (vertex count, average
degree, degree skew, diameter class) mirror the paper's Table VII inputs at a
reduced scale, and reproduce the paper's dynamics protocol (Section VI):
run-1 on a random 80%-vertex induced subgraph, run-2 after deleting 10% of
run-1's vertices and adding 10% fresh ones.
"""
from repro.graphs.csr import CSRGraph, build_csr, from_edges
from repro.graphs.generators import (
    rmat_graph,
    powerlaw_graph,
    road_graph,
    make_dataset,
    DATASETS,
)
from repro.graphs.evolve import EvolvingGraphPair, make_evolving_pair, induced_subgraph
from repro.graphs.partition import partition_contiguous, bfs_reorder

__all__ = [
    "CSRGraph",
    "build_csr",
    "from_edges",
    "rmat_graph",
    "powerlaw_graph",
    "road_graph",
    "make_dataset",
    "DATASETS",
    "EvolvingGraphPair",
    "make_evolving_pair",
    "induced_subgraph",
    "partition_contiguous",
    "bfs_reorder",
]
