"""BaseΔ compression of AMC miss streams (paper §V-B, Figs 5/6).

An AMC entry holds up to 20 miss block addresses (46-bit physical block
addresses in the paper). The first miss is the base; the rest are encoded as
1-, 2- or 4-byte signed deltas — the smallest size that fits every delta in
the entry is chosen (all three tested in parallel in hardware). Entries
whose deltas exceed 4 bytes are stored raw.

Encoded entry layout (bits):  8 (mode+count)  +  46 (base)  +  (n-1)*8*δ
Raw entry layout:             8               +  n*46

This module is the *bit-accounting and reference* implementation (numpy,
exact round-trip); :mod:`repro.kernels.basedelta` is the TPU Pallas version
operating on fixed-width tiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

BASE_BITS = 46
HEADER_BITS = 8
MODE_BYTES = {0: 1, 1: 2, 2: 4, 3: None}  # 3 = raw


def select_modes(miss_blocks: np.ndarray, seg_ids: np.ndarray, n_entries: int):
    """Vectorized per-entry mode selection.

    ``miss_blocks``: int64 block addresses, grouped by contiguous ``seg_ids``
    (ascending). Returns (mode, nmiss, bits) arrays of length ``n_entries``.
    """
    if n_entries == 0:
        z = np.zeros(0, dtype=np.int64)
        return z.astype(np.int8), z, z
    nmiss = np.bincount(seg_ids, minlength=n_entries).astype(np.int64)
    starts = np.zeros(n_entries, dtype=np.int64)
    np.cumsum(nmiss[:-1], out=starts[1:])
    # Delta of each miss vs its entry's base (the first miss of the entry).
    base = miss_blocks[np.minimum(starts, max(len(miss_blocks) - 1, 0))]
    deltas = miss_blocks - base[seg_ids]
    absmax = np.zeros(n_entries, dtype=np.int64)
    np.maximum.at(absmax, seg_ids, np.abs(deltas))
    mode = np.full(n_entries, 3, dtype=np.int8)
    mode[absmax <= 2**31 - 1] = 2
    mode[absmax <= 2**15 - 1] = 1
    mode[absmax <= 2**7 - 1] = 0
    delta_bytes = np.array([1, 2, 4, 0])[mode]
    bits = np.where(
        mode < 3,
        HEADER_BITS + BASE_BITS + np.maximum(nmiss - 1, 0) * 8 * delta_bytes,
        HEADER_BITS + nmiss * BASE_BITS,
    )
    bits = np.where(nmiss == 0, 0, bits)
    return mode, nmiss, bits


def basedelta_compress(blocks: np.ndarray) -> tuple:
    """Compress ONE entry. Returns (mode, packed_bytes) — exact round-trip."""
    blocks = np.asarray(blocks, dtype=np.int64)
    n = len(blocks)
    assert n >= 1
    base = blocks[0]
    deltas = blocks - base
    absmax = int(np.abs(deltas).max())
    if absmax <= 2**7 - 1:
        mode, width = 0, 1
    elif absmax <= 2**15 - 1:
        mode, width = 1, 2
    elif absmax <= 2**31 - 1:
        mode, width = 2, 4
    else:
        mode, width = 3, None
    header = np.array([mode << 5 | n], dtype=np.uint8).tobytes()
    if mode == 3:
        return mode, header + blocks.astype("<i8").tobytes()
    body = base.astype("<i8").tobytes()[:6]  # 46-bit base, 6-byte container
    dt = {1: "<i1", 2: "<i2", 4: "<i4"}[width]
    body += deltas[1:].astype(dt).tobytes()
    return mode, header + body


def basedelta_decompress(packed: bytes) -> np.ndarray:
    """Inverse of :func:`basedelta_compress`."""
    header = packed[0]
    mode, n = header >> 5, header & 0x1F
    if mode == 3:
        return np.frombuffer(packed[1:], dtype="<i8")[:n].copy()
    base = int.from_bytes(packed[1:7], "little", signed=False)
    if base >= 1 << 45:  # sign-extend 46-bit
        base -= 1 << 46
    width = MODE_BYTES[mode]
    dt = {1: "<i1", 2: "<i2", 4: "<i4"}[width]
    deltas = np.frombuffer(packed[7 : 7 + (n - 1) * width], dtype=dt)
    out = np.empty(n, dtype=np.int64)
    out[0] = base
    out[1:] = base + deltas.astype(np.int64)
    return out


def compressed_entry_bytes(mode: int, nmiss: int) -> int:
    """Byte size of the reference pack (raw mode uses 8-byte containers;
    the hardware bit-accounting in select_modes uses 46-bit addresses)."""
    if mode == 3:
        return 1 + nmiss * 8
    return (HEADER_BITS + BASE_BITS + max(nmiss - 1, 0) * 8 * MODE_BYTES[mode] + 7) // 8


@dataclasses.dataclass
class CompressionStats:
    """Aggregate ratios, mirroring the paper's §V-B measurements."""

    uncompressed_bits: int = 0
    compressed_bits: int = 0
    entries: int = 0
    mode_counts: tuple = (0, 0, 0, 0)

    def add(self, mode: np.ndarray, nmiss: np.ndarray, bits: np.ndarray):
        self.uncompressed_bits += int((nmiss * BASE_BITS).sum())
        self.compressed_bits += int(bits.sum())
        self.entries += int((nmiss > 0).sum())
        mc = list(self.mode_counts)
        for m in range(4):
            mc[m] += int((mode[nmiss > 0] == m).sum())
        self.mode_counts = tuple(mc)

    @property
    def ratio(self) -> float:
        return self.uncompressed_bits / max(self.compressed_bits, 1)
