"""Access-to-Miss Correlation (AMC) prefetcher — the paper's contribution."""
from repro.core.amc.compression import (
    basedelta_compress,
    basedelta_decompress,
    compressed_entry_bytes,
    CompressionStats,
)
from repro.core.amc.storage import AMCStorage, AMCEntryTable
from repro.core.amc.prefetcher import AMCConfig, AMCPrefetcher
from repro.core.amc.api import AMCSession

__all__ = [
    "basedelta_compress",
    "basedelta_decompress",
    "compressed_entry_bytes",
    "CompressionStats",
    "AMCStorage",
    "AMCEntryTable",
    "AMCConfig",
    "AMCPrefetcher",
    "AMCSession",
]
