"""Off-chip AMC metadata storage model (paper §V-B, Fig 4).

Two metadata spaces exist simultaneously — one being recorded into, one
being prefetched from — each holding a *Miss Addresses* region (compressed
miss streams, FIFO) and an *AMC Index* region (per-entry: two target
addresses, compression mode, miss count, pointer). `swap()` is the
role-reversal performed by ``AMC.update()`` at every iteration boundary.

The OS reserves at most ``capacity_bytes`` (20% of the application input
size, §IV-A) per space; recording that would overflow is dropped (counted,
visible in the Fig 15 storage benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# Per index entry: two target *deltas* (§V-B: "only the delta of the target
# accesses is recorded"), compression mode + miss count, pointer, valid.
INDEX_ENTRY_BYTES = 2 * 3 + 1 + 4 + 1  # = 12


def intra_rank(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated (ragged-expansion helper)."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.zeros(len(counts), dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


@dataclasses.dataclass
class AMCEntryTable:
    """One recorded iteration's correlation entries (struct of ragged arrays)."""

    iteration: int
    trigger_vid: np.ndarray  # (E,) current (second) target vertex id
    prev_vid: np.ndarray  # (E,) previous target vertex id
    mode: np.ndarray  # (E,) int8
    nmiss: np.ndarray  # (E,)
    bits: np.ndarray  # (E,) compressed size in bits
    miss_offsets: np.ndarray  # (E+1,) ragged offsets into miss_blocks
    miss_blocks: np.ndarray  # concatenated miss block ids
    truncated: bool = False  # storage cap hit while recording
    age: int = 0  # epochs since recorded (cross-epoch lifecycle only)

    def subset(self, keep: np.ndarray) -> "AMCEntryTable":
        """A new table holding only the entries selected by ``keep``
        (boolean mask over entries), ragged miss streams re-packed."""
        keep_idx = np.flatnonzero(keep)
        nm = self.nmiss[keep_idx].astype(np.int64)
        gather = np.repeat(self.miss_offsets[keep_idx], nm) + intra_rank(nm)
        offsets = np.zeros(len(keep_idx) + 1, dtype=np.int64)
        np.cumsum(nm, out=offsets[1:])
        return AMCEntryTable(
            iteration=self.iteration,
            trigger_vid=self.trigger_vid[keep_idx],
            prev_vid=self.prev_vid[keep_idx],
            mode=self.mode[keep_idx],
            nmiss=self.nmiss[keep_idx],
            bits=self.bits[keep_idx],
            miss_offsets=offsets,
            miss_blocks=self.miss_blocks[gather],
            truncated=self.truncated,
            age=self.age,
        )

    @property
    def num_entries(self) -> int:
        return len(self.trigger_vid)

    @property
    def miss_bytes(self) -> int:
        return int(self.bits.sum() + 7) // 8

    @property
    def index_bytes(self) -> int:
        return self.num_entries * INDEX_ENTRY_BYTES

    @property
    def total_bytes(self) -> int:
        return self.miss_bytes + self.index_bytes


class AMCStorage:
    """The pair of role-swapping metadata spaces + traffic accounting."""

    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self.recording: Dict[int, AMCEntryTable] = {}
        self.prefetching: Dict[int, AMCEntryTable] = {}
        self.write_bytes = 0  # off-chip metadata writes (recording)
        self.read_bytes = 0  # off-chip metadata reads (prefetch phase)
        self.dropped_entries = 0
        self.peak_bytes = 0
        # Cross-epoch lifecycle accounting (repro.stream.lifecycle):
        self.lookup_hits = 0  # lookups that found a table
        self.lookup_misses = 0  # lookups with no table for the iteration
        self.stale_hits = 0  # hits on tables older than one epoch (age > 0)
        self.invalidated_entries = 0  # dropped by invalidate_triggers()
        self.aged_out_tables = 0  # dropped by swap_retaining() age cap

    def record_bytes_used(self) -> int:
        return sum(t.total_bytes for t in self.recording.values())

    def store(self, table: AMCEntryTable) -> AMCEntryTable:
        """Record a table, enforcing the capacity cap (drops the tail)."""
        used = self.record_bytes_used()
        if used + table.total_bytes > self.capacity_bytes:
            # Keep the prefix of entries that fits.
            budget = max(self.capacity_bytes - used, 0)
            per_entry = (np.asarray(table.bits, dtype=np.int64) + 7) // 8 + INDEX_ENTRY_BYTES
            cum = np.cumsum(per_entry)
            keep = int(np.searchsorted(cum, budget, side="right"))
            self.dropped_entries += table.num_entries - keep
            end = int(table.miss_offsets[keep])
            table = AMCEntryTable(
                iteration=table.iteration,
                trigger_vid=table.trigger_vid[:keep],
                prev_vid=table.prev_vid[:keep],
                mode=table.mode[:keep],
                nmiss=table.nmiss[:keep],
                bits=table.bits[:keep],
                miss_offsets=table.miss_offsets[: keep + 1],
                miss_blocks=table.miss_blocks[:end],
                truncated=True,
            )
        self.recording[table.iteration] = table
        self.write_bytes += table.total_bytes
        self.peak_bytes = max(
            self.peak_bytes, self.record_bytes_used(), self.prefetch_bytes_used()
        )
        return table

    def prefetch_bytes_used(self) -> int:
        return sum(t.total_bytes for t in self.prefetching.values())

    def lookup(self, iteration: int) -> Optional[AMCEntryTable]:
        table = self.prefetching.get(iteration)
        if table is None:
            self.lookup_misses += 1
        else:
            self.lookup_hits += 1
            if table.age > 0:
                self.stale_hits += 1
        return table

    def charge_read(self, nbytes: int):
        self.read_bytes += int(nbytes)

    def swap(self):
        """AMC.update(): the freshly recorded space becomes the prefetch
        space; the old prefetch space is invalidated and recycled."""
        self.prefetching = self.recording
        self.recording = {}

    def swap_retaining(self, max_age: int):
        """Epoch-boundary swap that *retains* old tables as aged fallbacks.

        The ``age`` lifecycle policy: iterations re-recorded this epoch get
        their fresh table; iterations the new epoch did not reach keep the
        previous table with its age incremented, up to ``max_age`` epochs —
        LRU-style aging instead of the hard invalidation of :meth:`swap`.
        """
        old = self.prefetching
        fresh = dict(self.recording)
        for it, table in old.items():
            if it in fresh:
                continue
            if table.age + 1 > max_age:
                self.aged_out_tables += 1
                continue
            table.age += 1
            fresh[it] = table
        self.prefetching = fresh
        self.recording = {}

    def invalidate_triggers(self, changed_vids: np.ndarray) -> int:
        """Drop prefetch-space entries whose trigger vertex is in
        ``changed_vids`` (sorted unique ids) — the ``invalidate_changed``
        policy: a changed vertex's recorded miss stream describes a
        neighborhood that no longer exists.  Returns entries dropped."""
        dropped = 0
        changed = np.asarray(changed_vids, dtype=np.int64)
        for it, table in list(self.prefetching.items()):
            if table.num_entries == 0:
                continue
            stale = np.isin(table.trigger_vid, changed)
            n_stale = int(stale.sum())
            if n_stale == 0:
                continue
            dropped += n_stale
            self.prefetching[it] = table.subset(~stale)
        self.invalidated_entries += dropped
        return dropped

    def tables(self) -> List[AMCEntryTable]:
        return list(self.prefetching.values()) + list(self.recording.values())
