"""AMC's lightweight programming interface (paper §IV, Table V).

The five calls map 1:1 onto the paper's API. In hardware these set
architectural registers; here they configure an :class:`AMCSession` that the
workload driver consults — the same separation as the paper: the *software*
only identifies two data structures and the iteration boundary, everything
else is "hardware" (the trace-driven pipeline in
:mod:`repro.core.amc.prefetcher`).

    sess = AMCSession()
    sess.init(asid=0)                      # AMC.init()
    sess.addr_t_base(t_base, t_size)       # AMC.AddrTBase(addr, size)
    sess.addr_f_base(f_base, f_size)       # AMC.AddrFBase(addr, size)
    ... per iteration ...
    sess.update()                          # AMC.update()  (role swap)
    sess.end()                             # AMC.end()

The evolving-graph drivers (examples/, benchmarks/) call these around the
Ligra loops exactly as the paper's Algorithm 1 does for PGD.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class _ArchRegisters:
    """The architectural state of §IV-A."""

    asid: Optional[int] = None
    target_base: Optional[int] = None
    target_size: int = 0
    target_elem_size: int = 8
    frontier_base: Optional[int] = None
    frontier_size: int = 0
    frontier_elem_size: int = 1
    prefetch_phase: bool = False  # set after the initial iteration
    target_access_count: int = 0
    miss_count: int = 0


class AMCSession:
    """Host-side owner of AMC architectural registers + metadata spaces."""

    def __init__(self):
        self.regs = _ArchRegisters()
        self.active = False
        self.iteration = 0
        self.graph_version = 0
        self._ended = False

    # --- Table V calls ---

    def init(self, asid: int = 0) -> None:
        """Set ASID for permission checks, allocate AMC storage."""
        self.regs = _ArchRegisters(asid=asid)
        self.active = True
        self._ended = False
        self.iteration = 0
        self.graph_version = 0

    def addr_t_base(self, addr: int, size: int, elem_size: int = 8) -> None:
        assert self.active, "AMC.init() first"
        if elem_size < 1:
            raise ValueError(f"target elem_size must be >= 1, got {elem_size}")
        # Validate against the declared frontier range BEFORE committing, so
        # a rejected call leaves the session's registers untouched.
        if self.regs.frontier_base is not None:
            self._validate_elem_ratio(int(elem_size), self.regs.frontier_elem_size)
        self.regs.target_base = int(addr)
        self.regs.target_size = int(size)
        self.regs.target_elem_size = int(elem_size)

    def addr_f_base(self, addr: int, size: int, elem_size: int = 1) -> None:
        assert self.active, "AMC.init() first"
        if elem_size < 1:
            raise ValueError(f"frontier elem_size must be >= 1, got {elem_size}")
        if self.regs.target_base is not None:
            self._validate_elem_ratio(self.regs.target_elem_size, int(elem_size))
        self.regs.frontier_base = int(addr)
        self.regs.frontier_size = int(size)
        self.regs.frontier_elem_size = int(elem_size)

    @staticmethod
    def _validate_elem_ratio(target_elem_size: int, frontier_elem_size: int) -> None:
        """Once both ranges are declared, the §V-C2 address calculation
        scales frontier deltas by target_elem_size // frontier_elem_size —
        reject non-divisible sizes up front instead of truncating silently."""
        if target_elem_size % frontier_elem_size:
            raise ValueError(
                f"AMC address calculation requires target_elem_size "
                f"({target_elem_size}) to be an integer multiple of "
                f"frontier_elem_size ({frontier_elem_size}); the §V-C2 "
                "scaling target_delta = frontier_delta * "
                "(target_elem_size // frontier_elem_size) would silently "
                "truncate"
            )

    def update(self) -> None:
        """Iteration boundary: enable prefetching, swap metadata roles,
        reset the target access counter."""
        assert self.active
        self.regs.prefetch_phase = True
        self.regs.target_access_count = 0
        self.regs.miss_count = 0
        self.iteration += 1

    def new_graph_version(self) -> int:
        """Epoch boundary of an *evolving stream*: the software announces
        that the input graph advanced to its next version (a batch of edge
        updates was applied).

        Distinct from :meth:`update` — the iteration boundary within one
        graph version.  Correlation metadata survives the boundary per the
        host's table lifecycle policy (``repro.stream.lifecycle``); the
        declared TARGET/frontier ranges must remain valid, which the
        stream protocol guarantees by laying out all epochs in one shared
        address space (``repro.stream.protocol``).  Returns the new
        version number.
        """
        assert self.active, "AMC.init() first"
        self.graph_version += 1
        return self.graph_version

    def end(self) -> None:
        """Free AMC storage, reset registers, invalidate AMC Cache."""
        self.active = False
        self._ended = True
        self.regs = _ArchRegisters()

    # --- helpers used by the tracer/driver ---

    def in_target_range(self, addr) -> bool:
        r = self.regs
        if r.target_base is None:
            return False
        return r.target_base <= addr < r.target_base + r.target_size

    def in_frontier_range(self, addr) -> bool:
        r = self.regs
        if r.frontier_base is None:
            return False
        return r.frontier_base <= addr < r.frontier_base + r.frontier_size

    def address_calculation(self, frontier_addr: int) -> int:
        """§V-C2: target_delta = frontier_delta * (target_size/frontier_size)."""
        r = self.regs
        ratio, rem = divmod(r.target_elem_size, r.frontier_elem_size)
        if rem:
            # Registers mutated after the AddrXBase validation — same hazard.
            raise ValueError(
                f"non-divisible element sizes ({r.target_elem_size} vs "
                f"{r.frontier_elem_size}): §V-C2 scaling would truncate"
            )
        fdelta = frontier_addr - r.frontier_base
        return r.target_base + fdelta * ratio

    @property
    def configured(self) -> bool:
        return (
            self.active
            and self.regs.target_base is not None
            and self.regs.frontier_base is not None
        )
