"""AMC's lightweight programming interface (paper §IV, Table V).

The five calls map 1:1 onto the paper's API. In hardware these set
architectural registers; here they configure an :class:`AMCSession` that the
workload driver consults — the same separation as the paper: the *software*
only identifies two data structures and the iteration boundary, everything
else is "hardware" (the trace-driven pipeline in
:mod:`repro.core.amc.prefetcher`).

    sess = AMCSession()
    sess.init(asid=0)                      # AMC.init()
    sess.addr_t_base(t_base, t_size)       # AMC.AddrTBase(addr, size)
    sess.addr_f_base(f_base, f_size)       # AMC.AddrFBase(addr, size)
    ... per iteration ...
    sess.update()                          # AMC.update()  (role swap)
    sess.end()                             # AMC.end()

The evolving-graph drivers (examples/, benchmarks/) call these around the
Ligra loops exactly as the paper's Algorithm 1 does for PGD.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class _ArchRegisters:
    """The architectural state of §IV-A."""

    asid: Optional[int] = None
    target_base: Optional[int] = None
    target_size: int = 0
    target_elem_size: int = 8
    frontier_base: Optional[int] = None
    frontier_size: int = 0
    frontier_elem_size: int = 1
    prefetch_phase: bool = False  # set after the initial iteration
    target_access_count: int = 0
    miss_count: int = 0


class AMCSession:
    """Host-side owner of AMC architectural registers + metadata spaces."""

    def __init__(self):
        self.regs = _ArchRegisters()
        self.active = False
        self.iteration = 0
        self._ended = False

    # --- Table V calls ---

    def init(self, asid: int = 0) -> None:
        """Set ASID for permission checks, allocate AMC storage."""
        self.regs = _ArchRegisters(asid=asid)
        self.active = True
        self._ended = False
        self.iteration = 0

    def addr_t_base(self, addr: int, size: int, elem_size: int = 8) -> None:
        assert self.active, "AMC.init() first"
        self.regs.target_base = int(addr)
        self.regs.target_size = int(size)
        self.regs.target_elem_size = int(elem_size)

    def addr_f_base(self, addr: int, size: int, elem_size: int = 1) -> None:
        assert self.active, "AMC.init() first"
        self.regs.frontier_base = int(addr)
        self.regs.frontier_size = int(size)
        self.regs.frontier_elem_size = int(elem_size)

    def update(self) -> None:
        """Iteration boundary: enable prefetching, swap metadata roles,
        reset the target access counter."""
        assert self.active
        self.regs.prefetch_phase = True
        self.regs.target_access_count = 0
        self.regs.miss_count = 0
        self.iteration += 1

    def end(self) -> None:
        """Free AMC storage, reset registers, invalidate AMC Cache."""
        self.active = False
        self._ended = True
        self.regs = _ArchRegisters()

    # --- helpers used by the tracer/driver ---

    def in_target_range(self, addr) -> bool:
        r = self.regs
        if r.target_base is None:
            return False
        return r.target_base <= addr < r.target_base + r.target_size

    def in_frontier_range(self, addr) -> bool:
        r = self.regs
        if r.frontier_base is None:
            return False
        return r.frontier_base <= addr < r.frontier_base + r.frontier_size

    def address_calculation(self, frontier_addr: int) -> int:
        """§V-C2: target_delta = frontier_delta * (target_size/frontier_size)."""
        r = self.regs
        fdelta = frontier_addr - r.frontier_base
        return r.target_base + fdelta * (r.target_elem_size // r.frontier_elem_size)

    @property
    def configured(self) -> bool:
        return (
            self.active
            and self.regs.target_base is not None
            and self.regs.frontier_base is not None
        )
