"""The AMC prefetcher pipeline (paper §V).

Epoch structure: the programmer's ``AMC.update()`` call defines the
iteration boundary (PGD/CC: one algorithm iteration; BFS/BellmanFord: one
full traversal, per §VI's two-run protocol). Within an epoch, recording is
keyed by the within-epoch iteration index so that replay matches level j of
a BFS run against level j of the previous run, and iteration k of PGD
against iteration k-1 (its epoch has a single iteration).

Recording (§V-A): L2 demand misses of the *composite baseline* (demand +
next-line — the paper's L2 always runs next-line) that fall between two
consecutive L1 target accesses form one correlation entry, capped at 20
misses (split beyond), tagged with the (previous, current) target vertex,
BaseΔ-compressed and appended FIFO to the recording space. Target-range
misses are excluded (§VII-A: the contiguous target array is next-line
territory).

Prefetching (§V-C): entries stream through the AMC Index Identifier in
recorded order while the current frontier advances in processing order — a
two-pointer/searchsorted match on the trigger target address. A hit
decompresses the entry's miss stream and issues it ``lookahead`` accesses
ahead of the matching target access (the frontier buffer + index identifier
run ahead of the target stream; §V-C2's address calculation). Mismatched
(changed) vertices produce no prefetch — exactly AMC's evolving-graph
coverage loss.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.amc.compression import CompressionStats, select_modes
from repro.core.amc.storage import (
    AMCEntryTable,
    AMCStorage,
    INDEX_ENTRY_BYTES,
    intra_rank as _intra_rank,
)
from repro.core.registry import register_prefetcher


@dataclasses.dataclass(frozen=True)
class AMCConfig:
    max_misses_per_entry: int = 20  # paper Fig 16
    lookahead_accesses: int = 90  # frontier/index-identifier run-ahead
    amc_cache_bytes: int = 24 * 1024  # compressed-miss RAM (Table VIII)
    # Off-chip reserve vs input size. The paper reserves 20% (§IV-A) and
    # measures <25% used (Fig 15) at full scale; our 1/8-graph + 1/16-LLC
    # scaling raises per-iteration misses per input byte by ~2.5x, so the
    # scale-equivalent reserve is 0.5 (same drop-at-cap mechanism; the Fig 15
    # benchmark reports actual usage against BOTH reserves).
    storage_fraction: float = 0.50
    match_pairs: bool = False  # require (prev, cur) both to match
    name: str = "amc"


@dataclasses.dataclass
class PrefetchStream:
    name: str
    blocks: np.ndarray
    pos: np.ndarray
    metadata_bytes: int = 0
    info: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class IterationView:
    """Everything AMC sees about one iteration of the running app."""

    iteration: int  # global iteration index
    within_epoch: int  # iteration index inside its epoch
    target_pos: np.ndarray  # positions of L1 target accesses (ascending)
    target_vid: np.ndarray  # their vertex ids (frontier processing order)
    miss_pos: np.ndarray  # baseline-composite L2 miss positions (ascending)
    miss_blocks: np.ndarray  # and block ids (target-range already excluded)


class AMCPrefetcher:
    """Generates the AMC prefetch stream for a workload (see driver)."""

    def __init__(self, config: AMCConfig = AMCConfig()):
        self.config = config

    # ---------------- recording ----------------

    def _record(self, it: IterationView, storage: AMCStorage, stats) -> None:
        cfg = self.config
        tpos, tvid = it.target_pos, it.target_vid
        if len(tpos) == 0:
            return
        tag = np.searchsorted(tpos, it.miss_pos, side="right") - 1
        keep = tag >= 0
        tag = tag[keep]
        mblocks = it.miss_blocks[keep]
        if len(tag) == 0:
            table = AMCEntryTable(
                iteration=it.within_epoch,
                trigger_vid=np.zeros(0, np.int64),
                prev_vid=np.zeros(0, np.int64),
                mode=np.zeros(0, np.int8),
                nmiss=np.zeros(0, np.int64),
                bits=np.zeros(0, np.int64),
                miss_offsets=np.zeros(1, np.int64),
                miss_blocks=mblocks,
            )
            storage.store(table)
            return
        # Split groups of >20 misses into consecutive entries (§V-A binder).
        group_start = np.zeros(len(tag), dtype=bool)
        group_start[0] = True
        group_start[1:] = tag[1:] != tag[:-1]
        gidx = np.cumsum(group_start) - 1
        starts_at = np.flatnonzero(group_start)
        rank = np.arange(len(tag)) - starts_at[gidx]
        sub = rank // cfg.max_misses_per_entry
        # Entry id = (group, sub) pair, densified.
        entry_start = group_start | ((sub > 0) & (rank % cfg.max_misses_per_entry == 0))
        eid = np.cumsum(entry_start) - 1
        n_entries = int(eid[-1]) + 1
        entry_first = np.flatnonzero(entry_start)
        entry_tag = tag[entry_first]

        mode, nmiss, bits = select_modes(mblocks, eid, n_entries)
        if stats is not None:
            stats.add(mode, nmiss, bits)
        offsets = np.zeros(n_entries + 1, dtype=np.int64)
        np.cumsum(nmiss, out=offsets[1:])
        table = AMCEntryTable(
            iteration=it.within_epoch,
            trigger_vid=tvid[entry_tag],
            prev_vid=np.where(entry_tag > 0, tvid[np.maximum(entry_tag - 1, 0)], -1),
            mode=mode,
            nmiss=nmiss,
            bits=bits,
            miss_offsets=offsets,
            miss_blocks=mblocks,
        )
        storage.store(table)

    # ---------------- prefetching ----------------

    def _prefetch(
        self, it: IterationView, rec: Optional[AMCEntryTable], storage: AMCStorage
    ):
        if rec is None or rec.num_entries == 0 or len(it.target_pos) == 0:
            return None
        cfg = self.config
        tpos, tvid = it.target_pos, it.target_vid
        # Index-identifier run-ahead: trigger LA targets early.
        gaps = np.diff(tpos).mean() if len(tpos) > 1 else 1.0
        la = max(int(np.ceil(cfg.lookahead_accesses / max(gaps, 1.0))), 1)
        trig_pos = tpos[np.maximum(np.arange(len(tpos)) - la, 0)]

        # Streamed two-pointer match on trigger vid (both sides sorted within
        # an iteration = frontier processing order).
        le = np.searchsorted(rec.trigger_vid, tvid, side="left")
        re_ = np.searchsorted(rec.trigger_vid, tvid, side="right")
        counts = re_ - le
        matched_j = np.flatnonzero(counts > 0)
        if len(matched_j) == 0:
            storage.charge_read(rec.num_entries * INDEX_ENTRY_BYTES)
            return None
        c = counts[matched_j]
        # Expand entry index ranges [le, re) per matched target.
        eidx = np.repeat(le[matched_j], c) + _intra_rank(c)
        if cfg.match_pairs:
            prev_cur = np.where(matched_j > 0, tvid[np.maximum(matched_j - 1, 0)], -1)
            ok = rec.prev_vid[eidx] == np.repeat(prev_cur, c)
            eidx = eidx[ok]
            owner_j = np.repeat(matched_j, c)[ok]
        else:
            owner_j = np.repeat(matched_j, c)
        if len(eidx) == 0:
            storage.charge_read(rec.num_entries * INDEX_ENTRY_BYTES)
            return None

        # AMC Cache capacity: cap the compressed bytes held per trigger.
        ebytes = rec.bits[eidx] // 8
        cum_per_j = _segment_cumsum(ebytes, owner_j)
        fits = cum_per_j <= cfg.amc_cache_bytes
        eidx, owner_j = eidx[fits], owner_j[fits]

        nm = rec.nmiss[eidx].astype(np.int64)
        miss_idx = np.repeat(rec.miss_offsets[eidx], nm) + _intra_rank(nm)
        pf_blocks = rec.miss_blocks[miss_idx]
        pf_pos = np.repeat(trig_pos[owner_j], nm)

        # Metadata traffic: one pass over the index (streamed), the matched
        # compressed miss bytes read, and the hit-entry writeback (§V-C1).
        matched_bytes = int((rec.bits[eidx] // 8).sum())
        storage.charge_read(rec.num_entries * INDEX_ENTRY_BYTES + matched_bytes)
        storage.write_bytes += matched_bytes
        return pf_blocks, pf_pos

    # ---------------- workload driver entry ----------------

    def generate(
        self, workload, storage: Optional[AMCStorage] = None
    ) -> PrefetchStream:
        """workload: repro.core.driver.WorkloadTrace.

        ``storage`` lets a caller carry the correlation tables across
        workloads (the cross-epoch lifecycle of ``repro.stream.lifecycle``);
        by default a fresh store is allocated, exactly as before.  Metadata
        traffic on the returned stream covers *this call only* (counter
        deltas), so per-epoch accounting stays correct with carried state.
        """
        cfg = self.config
        if storage is None:
            storage = AMCStorage(int(cfg.storage_fraction * workload.input_bytes))
        read0, write0 = storage.read_bytes, storage.write_bytes
        dropped0 = storage.dropped_entries
        stats = CompressionStats()
        views = workload.amc_iteration_views()
        out_blocks: List[np.ndarray] = []
        out_pos: List[np.ndarray] = []
        cur_epoch = None
        for view, epoch in views:
            if epoch != cur_epoch:
                if cur_epoch is not None:
                    storage.swap()  # AMC.update(): role reversal
                cur_epoch = epoch
            rec = storage.lookup(view.within_epoch)
            issued = self._prefetch(view, rec, storage)
            if issued is not None:
                out_blocks.append(issued[0])
                out_pos.append(issued[1])
            self._record(view, storage, stats)
        blocks = (
            np.concatenate(out_blocks) if out_blocks else np.zeros(0, np.int64)
        )
        pos = np.concatenate(out_pos) if out_pos else np.zeros(0, np.int64)
        read_delta = storage.read_bytes - read0
        write_delta = storage.write_bytes - write0
        return PrefetchStream(
            name=cfg.name,
            blocks=blocks,
            pos=pos,
            metadata_bytes=read_delta + write_delta,
            info=dict(
                compression_ratio=stats.ratio,
                mode_counts=stats.mode_counts,
                entries=stats.entries,
                storage_peak_bytes=storage.peak_bytes,  # high-water (whole carry)
                storage_cap_bytes=storage.capacity_bytes,
                dropped_entries=storage.dropped_entries - dropped0,
                metadata_read_bytes=read_delta,
                metadata_write_bytes=write_delta,
            ),
        )


@register_prefetcher(
    "amc",
    trains_on="target_access+baseline_l2_miss",
    storage="20% off-chip reserve + 24KB AMC Cache",
    family="amc",
    configurable=True,
    description="Access-to-Miss Correlation prefetcher (the paper's design)",
)
def amc(**overrides):
    """Factory: AMC stream generator with :class:`AMCConfig` overrides."""
    return AMCPrefetcher(AMCConfig(**overrides)).generate


def _segment_cumsum(values: np.ndarray, seg: np.ndarray) -> np.ndarray:
    """Cumulative sum within contiguous equal-``seg`` runs."""
    if len(values) == 0:
        return values
    cs = np.cumsum(values)
    start = np.zeros(len(values), dtype=bool)
    start[0] = True
    start[1:] = seg[1:] != seg[:-1]
    base = np.where(start, cs - values, 0)
    base = np.maximum.accumulate(np.where(start, base, 0))
    return cs - base
