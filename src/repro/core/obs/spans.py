"""Structured span tracing with cross-process collection.

One *span* is a named, timed event with attributes — the structured
successor of the flat ``{stage: seconds}`` dict that
:mod:`repro.core.exec.timers` used to own.  Spans carry a trace id, a
span id, a parent span id (the enclosing span at open time, per
process), a wall-clock start timestamp (``time.time_ns`` — comparable
across processes), a high-resolution duration (``perf_counter`` delta),
the recording pid, and free-form attributes (spec cache key, cache
hit/miss, engine/emitter choice, shard index, tenant id, epoch, ...).

Three layers of state, all with a no-op fast path so the bench's hot
paths pay nothing when telemetry is off:

- **Stage collector** (``collect_stages``): the legacy flat dict.
  :func:`stage` accumulates durations into it exactly as before —
  bit-identical semantics, test-asserted — and nested collectors shadow
  outer ones for their extent.
- **Tracer** (``trace``): records :class:`Span` objects.  :func:`stage`
  doubles as a span when a tracer is active, so every existing stage
  site shows up on the timeline for free; :func:`span` is the
  attribute-bearing form for new instrumentation.
- **Metrics registry**: the active tracer owns a
  :class:`~repro.core.obs.metrics.MetricsRegistry`; :func:`stage` feeds
  per-stage latency histograms, and the :func:`inc` / :func:`observe` /
  :func:`set_gauge` helpers feed counters and gauges from anywhere.

Cross-process collection: a :class:`Tracer` opened with a directory
exports nothing itself — the pool spawner
(:func:`repro.core.exec.scheduler._spawn_pool`) publishes
:data:`SPAN_DIR_ENV` / :data:`TRACE_ID_ENV` to its children, and any
process that finds those set lazily opens a *file-backed worker tracer*
appending one JSON line per closed span to its own
``spans-<pid>.jsonl`` (one file per process — no write contention, and
a killed worker loses at most its buffered tail, never corrupts the
trace).  The parent's :meth:`Tracer.finish` merges every per-process
file deterministically into one :class:`RunTrace` — same files, same
merge, regardless of read order (sorted by wall start, pid, sequence).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
import uuid
from pathlib import Path
from typing import Dict, Iterator, List, Optional

from repro.core.obs.metrics import MetricsRegistry, merge_snapshots

SPAN_DIR_ENV = "REPRO_TRACE_DIR"
TRACE_ID_ENV = "REPRO_TRACE_ID"

# Version of the span/metrics line format written to trace dirs (and of
# the merged RunTrace document).
TRACE_SCHEMA = 1

_STAGES: Optional[Dict[str, float]] = None  # active stage collector
_METRICS: Optional[MetricsRegistry] = None  # explicit registry override
_TRACER: Optional["Tracer"] = None
_WORKER_PROBED = False  # lazily checked SPAN_DIR_ENV once in this process


@dataclasses.dataclass
class Span:
    """One structured, timed event."""

    name: str
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    ts: int  # wall-clock start, ns since the epoch (cross-process axis)
    dur: float  # seconds, from a perf_counter delta (high resolution)
    pid: int
    proc: str  # process label: "main" or "worker"
    attrs: Dict[str, object]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(**d)


class Tracer:
    """Span recorder for one process.

    The parent opens one via :func:`trace` (buffering spans in memory and
    flushing them to ``spans-<pid>.jsonl`` at :meth:`finish`); spawned
    workers open file-backed ones lazily from :data:`SPAN_DIR_ENV`,
    appending each span as it closes so a worker needs no shutdown hook.
    """

    def __init__(
        self,
        trace_id: Optional[str] = None,
        dir: Optional[os.PathLike] = None,
        proc: str = "main",
    ):
        self.trace_id = trace_id or uuid.uuid4().hex[:16]
        self.dir = Path(dir) if dir is not None else None
        self.proc = proc
        self.pid = os.getpid()
        self.spans: List[Span] = []
        self.metrics = MetricsRegistry()
        self.result: Optional["RunTrace"] = None
        self._stack: List[str] = []  # open span ids (per-process parentage)
        self._seq = 0
        self._metrics_seq = 0
        self._stream = None  # append-mode file (worker tracers)
        if self.dir is not None:
            self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------ recording

    def _next_id(self) -> str:
        self._seq += 1
        return f"{self.pid:x}-{self._seq:x}"

    def open_span(self, name: str, attrs: Dict[str, object]) -> Span:
        s = Span(
            name=name,
            trace_id=self.trace_id,
            span_id=self._next_id(),
            parent_id=self._stack[-1] if self._stack else None,
            ts=time.time_ns(),
            dur=0.0,
            pid=self.pid,
            proc=self.proc,
            attrs=dict(attrs),
        )
        self._stack.append(s.span_id)
        return s

    def close_span(self, s: Span, dur: float) -> None:
        s.dur = dur
        if self._stack and self._stack[-1] == s.span_id:
            self._stack.pop()
        self.spans.append(s)
        if self._stream is not None:
            self._write_line(s.as_dict())

    # --------------------------------------------------------------- files

    def _path(self) -> Path:
        assert self.dir is not None
        return self.dir / f"spans-{self.proc}-{self.pid}.jsonl"

    def _write_line(self, doc: dict) -> None:
        self._stream.write(json.dumps(doc, sort_keys=True) + "\n")
        self._stream.flush()

    def open_stream(self) -> None:
        """Switch to append-per-span mode (worker tracers): a pool worker
        has no reliable shutdown hook, so every closed span lands on disk
        immediately."""
        if self.dir is not None and self._stream is None:
            self._stream = open(self._path(), "a")

    def flush_metrics(self) -> None:
        """Write this process's *cumulative* metrics snapshot as a line.

        Workers call this at task boundaries.  Snapshots are cumulative
        (monotonic per process), so the merge keeps only the last line
        per pid and sums across pids — no delta bookkeeping, and a lost
        tail only loses the most recent increments.
        """
        if self._stream is None or not self.metrics:
            return
        self._metrics_seq += 1
        self._write_line(
            {
                "kind": "metrics",
                "pid": self.pid,
                "proc": self.proc,
                "seq": self._metrics_seq,
                "metrics": self.metrics.snapshot(),
            }
        )

    def finish(self, manifest: Optional[dict] = None) -> "RunTrace":
        """Flush this process's spans/metrics and merge the trace dir.

        Idempotent: repeat calls return the same :class:`RunTrace`.
        """
        if self.result is not None:
            return self.result
        if self.dir is not None:
            self.open_stream()
            for s in self.spans:
                self._write_line(s.as_dict())
            self.flush_metrics()
            self._stream.close()
            self._stream = None
            self.result = RunTrace.load(self.dir, manifest=manifest)
        else:
            self.result = RunTrace(
                trace_id=self.trace_id,
                spans=_sorted_spans(list(self.spans)),
                metrics=merge_snapshots([self.metrics.snapshot()]),
                manifest=manifest,
            )
        return self.result


@dataclasses.dataclass
class RunTrace:
    """A merged, ordered view over every process's spans for one run."""

    trace_id: str
    spans: List[Span]
    metrics: dict  # merged MetricsRegistry snapshot
    manifest: Optional[dict] = None

    @classmethod
    def load(cls, dir: os.PathLike, manifest: Optional[dict] = None) -> "RunTrace":
        """Deterministically merge every ``spans-*.jsonl`` under ``dir``.

        Span order is (wall start ns, pid, span id) — fully determined by
        the files' contents, independent of filesystem listing order or
        how many times the merge runs.  Metrics lines are cumulative per
        process: the last one per pid wins, then pids merge in sorted
        order (counters/histograms sum, gauges last-writer-by-pid).
        Unparseable lines (a worker killed mid-write) are dropped, never
        fatal.
        """
        spans: List[Span] = []
        trace_id = ""
        last_metrics: Dict[int, tuple] = {}  # pid -> (seq, snapshot)
        for path in sorted(Path(dir).glob("spans-*.jsonl")):
            for line in path.read_text().splitlines():
                try:
                    doc = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if doc.get("kind") == "metrics":
                    pid, seq = int(doc["pid"]), int(doc["seq"])
                    if pid not in last_metrics or seq > last_metrics[pid][0]:
                        last_metrics[pid] = (seq, doc["metrics"])
                    continue
                try:
                    s = Span.from_dict(doc)
                except TypeError:
                    continue
                spans.append(s)
                trace_id = trace_id or s.trace_id
        merged = merge_snapshots(
            [snap for _, (_, snap) in sorted(last_metrics.items())]
        )
        return cls(
            trace_id=trace_id,
            spans=_sorted_spans(spans),
            metrics=merged,
            manifest=manifest,
        )

    # ------------------------------------------------------------- queries

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def processes(self) -> List[tuple]:
        """Sorted distinct (pid, proc) pairs that contributed spans."""
        return sorted({(s.pid, s.proc) for s in self.spans})

    def stage_totals(self) -> Dict[str, float]:
        """Per-name duration sums — the flat stage dict, derived."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.dur
        return out

    def summary(self) -> dict:
        """Compact stats block (committed by the bench): span and process
        counts, per-name span counts and duration totals."""
        names: Dict[str, int] = {}
        for s in self.spans:
            names[s.name] = names.get(s.name, 0) + 1
        return {
            "trace_id": self.trace_id,
            "spans": len(self.spans),
            "processes": [f"{proc}:{pid}" for pid, proc in self.processes()],
            "span_counts": dict(sorted(names.items())),
            "span_seconds": {
                k: round(v, 6) for k, v in sorted(self.stage_totals().items())
            },
        }

    # ----------------------------------------------------------------- io

    def as_dict(self) -> dict:
        return {
            "schema": "run-trace",
            "version": TRACE_SCHEMA,
            "trace_id": self.trace_id,
            "manifest": self.manifest,
            "metrics": self.metrics,
            "spans": [s.as_dict() for s in self.spans],
        }

    def save(self, path: os.PathLike) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def read(cls, path: os.PathLike) -> "RunTrace":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != "run-trace":
            raise ValueError(f"{path}: not a run-trace document")
        return cls(
            trace_id=doc["trace_id"],
            spans=[Span.from_dict(d) for d in doc["spans"]],
            metrics=doc.get("metrics") or {},
            manifest=doc.get("manifest"),
        )


def _sorted_spans(spans: List[Span]) -> List[Span]:
    return sorted(spans, key=lambda s: (s.ts, s.pid, s.span_id))


# ------------------------------------------------------------ active state


def _probe_worker_tracer() -> Optional[Tracer]:
    """Lazily open a file-backed tracer when the parent exported a trace
    dir to this (spawned) process.  Checked once per process; the result
    is cached in ``_TRACER``."""
    global _TRACER, _WORKER_PROBED
    if _TRACER is not None:
        return _TRACER
    if _WORKER_PROBED:
        return None
    _WORKER_PROBED = True
    dir = os.environ.get(SPAN_DIR_ENV)
    if not dir:
        return None
    _TRACER = Tracer(
        trace_id=os.environ.get(TRACE_ID_ENV), dir=dir, proc="worker"
    )
    _TRACER.open_stream()
    return _TRACER


def current_tracer() -> Optional[Tracer]:
    """The active tracer: an explicit :func:`trace` context, else a
    worker tracer adopted from the environment, else None."""
    return _TRACER if _TRACER is not None else _probe_worker_tracer()


def tracing() -> bool:
    return current_tracer() is not None


def current_metrics() -> Optional[MetricsRegistry]:
    """The active metrics registry: an explicit :func:`metrics_registry`
    context shadows the active tracer's registry."""
    if _METRICS is not None:
        return _METRICS
    t = current_tracer()
    return t.metrics if t is not None else None


@contextlib.contextmanager
def trace(
    dir: Optional[os.PathLike] = None,
    trace_id: Optional[str] = None,
) -> Iterator[Tracer]:
    """Activate span collection for the enclosed block.

    With ``dir``, the trace is cross-process capable: the pool spawner
    exports the dir to workers, each process appends its own JSONL file,
    and ``tracer.finish()`` (called automatically on exit; idempotent)
    merges them into ``tracer.result``.  Without ``dir`` the trace is
    in-process only (cheap, for tests and ad-hoc timing).  Nested traces
    shadow outer ones for their extent, like stage collectors.
    """
    global _TRACER
    t = Tracer(trace_id=trace_id, dir=dir)
    prev, _TRACER = _TRACER, t
    try:
        yield t
    finally:
        _TRACER = prev
        t.finish()


@contextlib.contextmanager
def metrics_registry(
    into: Optional[MetricsRegistry] = None,
) -> Iterator[MetricsRegistry]:
    """Activate a standalone metrics registry (no tracer required)."""
    global _METRICS
    reg = into if into is not None else MetricsRegistry()
    prev, _METRICS = _METRICS, reg
    try:
        yield reg
    finally:
        _METRICS = prev


# ----------------------------------------------------- instrumentation API


@contextlib.contextmanager
def span(name: str, **attrs) -> Iterator[Optional[Span]]:
    """Record one attribute-bearing span (no-op without an active tracer).

    Yields the open :class:`Span` so call sites can attach attributes
    discovered mid-flight (``sp.attrs["cache"] = "hit"``), or ``None``
    when tracing is off — guard late-attr writes with ``if sp:``.
    """
    tracer = current_tracer()
    if tracer is None:
        yield None
        return
    s = tracer.open_span(name, attrs)
    t0 = time.perf_counter()
    try:
        yield s
    finally:
        tracer.close_span(s, time.perf_counter() - t0)


@contextlib.contextmanager
def stage(name: str) -> Iterator[None]:
    """Accumulate this block's duration under ``name``.

    The legacy stage-timer contract, unchanged: with an active
    :func:`collect_stages` collector the duration accumulates into its
    dict (bit-identical to the pre-span implementation — one
    ``perf_counter`` delta, added once).  Additionally, when a tracer is
    active the same interval is recorded as a span of the same name (the
    one measured duration is shared, so ``RunTrace.stage_totals()``
    equals the collector dict exactly), and when a metrics registry is
    active the duration feeds the ``stage.<name>`` latency histogram.
    With none of the three active this is a no-op.
    """
    tracer = current_tracer()
    reg = _METRICS if _METRICS is not None else (
        tracer.metrics if tracer is not None else None
    )
    if _STAGES is None and tracer is None and reg is None:
        yield
        return
    s = tracer.open_span(name, {}) if tracer is not None else None
    t0 = time.perf_counter()
    try:
        yield
    finally:
        dt = time.perf_counter() - t0
        if s is not None:
            tracer.close_span(s, dt)
        if _STAGES is not None:
            _STAGES[name] = _STAGES.get(name, 0.0) + dt
        if reg is not None:
            reg.observe(f"stage.{name}", dt)


@contextlib.contextmanager
def collect_stages(
    into: Optional[Dict[str, float]] = None,
) -> Iterator[Dict[str, float]]:
    """Collect ``stage()`` durations from the enclosed block into a dict.

    Durations accumulate per stage name, so a block that builds several
    workloads reports total seconds spent in each pipeline stage.  Nested
    collectors shadow outer ones for their extent.
    """
    global _STAGES
    times = into if into is not None else {}
    prev, _STAGES = _STAGES, times
    try:
        yield times
    finally:
        _STAGES = prev


def record(name: str, value: float = 1.0) -> None:
    """Accumulate ``value`` under ``name`` in the active stage collector.

    The out-of-band counterpart of :func:`stage` for durations or counts
    with no contiguous block to wrap (pipeline overlap windows, scheduler
    decisions).  Also feeds the active metrics registry as a counter.
    No-op when neither is active.
    """
    if _STAGES is not None:
        _STAGES[name] = _STAGES.get(name, 0.0) + value
    reg = current_metrics()
    if reg is not None:
        reg.inc(name, value)


def inc(name: str, value: float = 1.0) -> None:
    """Increment counter ``name`` in the active registry (no-op off)."""
    reg = current_metrics()
    if reg is not None:
        reg.inc(name, value)


def observe(name: str, value: float) -> None:
    """Observe ``value`` into histogram ``name`` (no-op off)."""
    reg = current_metrics()
    if reg is not None:
        reg.observe(name, value)


def set_gauge(name: str, value: float) -> None:
    """Set gauge ``name`` (no-op off)."""
    reg = current_metrics()
    if reg is not None:
        reg.set_gauge(name, value)


def flush_worker_metrics() -> None:
    """Flush the worker tracer's cumulative metrics snapshot (task
    boundaries call this so parent merges see worker-side counters)."""
    t = current_tracer()
    if t is not None and t._stream is not None:
        t.flush_metrics()


def _reset_for_tests() -> None:
    """Drop all active state incl. the worker-env probe (test helper)."""
    global _STAGES, _METRICS, _TRACER, _WORKER_PROBED
    _STAGES = None
    _METRICS = None
    _TRACER = None
    _WORKER_PROBED = False


__all__ = [
    "RunTrace",
    "SPAN_DIR_ENV",
    "Span",
    "TRACE_ID_ENV",
    "TRACE_SCHEMA",
    "Tracer",
    "collect_stages",
    "current_metrics",
    "current_tracer",
    "flush_worker_metrics",
    "inc",
    "metrics_registry",
    "observe",
    "record",
    "set_gauge",
    "span",
    "stage",
    "trace",
    "tracing",
]
