"""Structured run telemetry: spans, metrics, and run manifests.

See docs/OBSERVABILITY.md for the span model and attribute conventions.
"""

from repro.core.obs.manifest import git_sha, run_manifest
from repro.core.obs.metrics import (
    MetricsRegistry,
    histogram_quantile,
    merge_snapshots,
)
from repro.core.obs.spans import (
    SPAN_DIR_ENV,
    TRACE_ID_ENV,
    TRACE_SCHEMA,
    RunTrace,
    Span,
    Tracer,
    collect_stages,
    current_metrics,
    current_tracer,
    flush_worker_metrics,
    inc,
    metrics_registry,
    observe,
    record,
    set_gauge,
    span,
    stage,
    trace,
    tracing,
)

__all__ = [
    "MetricsRegistry",
    "RunTrace",
    "SPAN_DIR_ENV",
    "Span",
    "TRACE_ID_ENV",
    "TRACE_SCHEMA",
    "Tracer",
    "collect_stages",
    "current_metrics",
    "current_tracer",
    "flush_worker_metrics",
    "git_sha",
    "histogram_quantile",
    "inc",
    "merge_snapshots",
    "metrics_registry",
    "observe",
    "record",
    "run_manifest",
    "set_gauge",
    "span",
    "stage",
    "trace",
    "tracing",
]
