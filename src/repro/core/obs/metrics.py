"""Metrics registry: counters, gauges, and log2-bucket histograms.

The registry is deliberately tiny and dependency-free: metric state is
plain dicts of floats so a snapshot is JSON out of the box (committed
into BENCH documents, flushed from workers as JSONL lines) and merging
per-process snapshots is pure arithmetic.

- **Counters** are monotonic sums (cache hits, trace-reuse hits,
  contention events).  Merge = sum.
- **Gauges** are last-written values (peak RSS, pool size).  Merge =
  last writer in pid order; per-process gauges should be namespaced by
  the writer if the distinction matters.
- **Histograms** bucket observations by ``floor(log2(value / 1e-6))``
  — microsecond-resolution exponential buckets that cover nanoseconds
  to hours in ~50 buckets — and also carry count/sum/min/max so means
  and totals are exact even though the distribution is approximate.
  Merge = sum counts per bucket, combine the exact moments.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional

_HIST_FLOOR = 1e-6  # bucket 0 boundary: one microsecond


def bucket_of(value: float) -> int:
    """Exponential bucket index for ``value`` (seconds or any unit)."""
    if value <= _HIST_FLOOR:
        return 0
    return max(0, int(math.floor(math.log2(value / _HIST_FLOOR))) + 1)


def bucket_le(index: int) -> float:
    """Inclusive upper bound of bucket ``index``."""
    if index <= 0:
        return _HIST_FLOOR
    return _HIST_FLOOR * (2.0**index)


class MetricsRegistry:
    """Counters, gauges, and histograms for one process."""

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, dict] = {}

    def __bool__(self) -> bool:
        return bool(self.counters or self.gauges or self.histograms)

    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = {
                "count": 0,
                "sum": 0.0,
                "min": float(value),
                "max": float(value),
                "buckets": {},
            }
        h["count"] += 1
        h["sum"] += float(value)
        h["min"] = min(h["min"], float(value))
        h["max"] = max(h["max"], float(value))
        b = str(bucket_of(value))
        h["buckets"][b] = h["buckets"].get(b, 0) + 1

    # ------------------------------------------------------------- queries

    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def ratio(self, hit: str, miss: str) -> Optional[float]:
        """hit / (hit + miss), or None when nothing was counted."""
        h, m = self.counter(hit), self.counter(miss)
        return h / (h + m) if (h + m) > 0 else None

    def snapshot(self) -> dict:
        """JSON-ready cumulative state (deep-copied)."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {
                k: {**h, "buckets": dict(h["buckets"])}
                for k, h in self.histograms.items()
            },
        }


def merge_snapshots(snapshots: Iterable[dict]) -> dict:
    """Merge per-process cumulative snapshots into one.

    Counters and histogram buckets/moments sum; gauges take the last
    writer in iteration order (callers pass snapshots sorted by pid, so
    the merge is deterministic).
    """
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, dict] = {}
    for snap in snapshots:
        for k, v in (snap.get("counters") or {}).items():
            counters[k] = counters.get(k, 0.0) + v
        gauges.update(snap.get("gauges") or {})
        for k, h in (snap.get("histograms") or {}).items():
            agg = hists.get(k)
            if agg is None:
                hists[k] = {**h, "buckets": dict(h["buckets"])}
                continue
            agg["count"] += h["count"]
            agg["sum"] += h["sum"]
            agg["min"] = min(agg["min"], h["min"])
            agg["max"] = max(agg["max"], h["max"])
            for b, n in h["buckets"].items():
                agg["buckets"][b] = agg["buckets"].get(b, 0) + n
    return {"counters": counters, "gauges": gauges, "histograms": hists}


def histogram_quantile(hist: dict, q: float) -> float:
    """Approximate quantile from bucket counts (upper-bound estimate)."""
    total = hist["count"]
    if total == 0:
        return 0.0
    target = q * total
    seen = 0.0
    for b in sorted(hist["buckets"], key=int):
        seen += hist["buckets"][b]
        if seen >= target:
            return min(bucket_le(int(b)), hist["max"])
    return hist["max"]


__all__: List[str] = [
    "MetricsRegistry",
    "bucket_le",
    "bucket_of",
    "histogram_quantile",
    "merge_snapshots",
]
