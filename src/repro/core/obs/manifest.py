"""Run manifest: the provenance block attached to every trace.

Answers "what code, what configuration, what machine produced this
run?" — the questions BENCH archaeology has had to reconstruct from
commit timestamps so far.  Captured once per run and attached to
``ExperimentResult.telemetry`` and BENCH schema v8 documents.

Everything repo-specific is imported lazily inside :func:`run_manifest`:
this module is imported by ``repro.core.obs`` which is imported by the
stage-timer shim, so an eager import of the driver here would cycle.
"""

from __future__ import annotations

import os
import platform
import subprocess
import sys
from typing import Optional

_GIT_SHA: Optional[str] = None
_GIT_PROBED = False


def git_sha() -> Optional[str]:
    """HEAD sha of the repo containing this file (cached; None outside
    a git checkout or without a git binary)."""
    global _GIT_SHA, _GIT_PROBED
    if _GIT_PROBED:
        return _GIT_SHA
    _GIT_PROBED = True
    try:
        _GIT_SHA = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True,
            text=True,
            timeout=10,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        _GIT_SHA = None
    return _GIT_SHA


def run_manifest(sched: Optional[dict] = None, **extra) -> dict:
    """Provenance snapshot: git sha, resolved engine/emitter, schema
    versions, interpreter/platform, and (when the caller has one) the
    scheduler's ``SchedDecision`` record plus free-form extras."""
    from repro.apps.trace import current_emitter
    from repro.core.driver import TRACE_CODE_VERSION
    from repro.core.exec.artifacts import ARTIFACT_SCHEMA
    from repro.core.obs.spans import TRACE_SCHEMA
    from repro.memsim.engine import current_engine

    doc = {
        "git_sha": git_sha(),
        "engine": current_engine(),
        "emitter": current_emitter(),
        "trace_code_version": TRACE_CODE_VERSION,
        "artifact_schema": ARTIFACT_SCHEMA,
        "trace_schema": TRACE_SCHEMA,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "pid": os.getpid(),
    }
    if sched is not None:
        doc["sched"] = sched
    doc.update(extra)
    return doc


__all__ = ["git_sha", "run_manifest"]
