"""First-class prefetcher registry: the declarative half of the suite.

The paper compares AMC against seven prior prefetchers (Table I), each with
its own training stream, storage budget, and composite policy.  Those
properties used to live as prose in docstrings and as convention in a bare
``Dict[str, Callable]``; here they are carried as a declarative
:class:`PrefetcherSpec` attached at definition site:

    @register_prefetcher(
        "vldp", trains_on="l2_access", storage="on-chip delta tables",
        family="spatial",
    )
    def vldp(workload) -> PrefetchStream: ...

Configurable prefetchers (AMC) register a *factory* instead — a callable
taking config kwargs and returning a stream generator:

    @register_prefetcher("amc", trains_on="target_access+baseline_l2_miss",
                         configurable=True, ...)
    def amc(**overrides) -> Prefetcher:
        return AMCPrefetcher(AMCConfig(**overrides)).generate

Lookup is by name (``get_prefetcher("vldp")``), and the
:class:`~repro.core.experiment.Experiment` builder resolves its
``prefetchers=[...]`` argument through :func:`resolve_prefetchers`.  The
built-in suite modules are imported lazily on first lookup, so importing
this module alone is enough to reach every registered prefetcher.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Callable, Dict, List, Optional, Protocol, Tuple, runtime_checkable


@runtime_checkable
class Prefetcher(Protocol):
    """A stream generator: ``WorkloadTrace -> PrefetchStream``.

    Every evaluated prefetcher — AMC and all baselines — reduces to this one
    callable shape; the registry layers metadata on top without changing it.
    """

    def __call__(self, workload) -> "PrefetchStream":  # noqa: F821
        ...


class DuplicatePrefetcherError(ValueError):
    """A prefetcher name was registered twice without ``replace=True``."""


class UnknownPrefetcherError(KeyError):
    """Requested prefetcher name is not in the registry."""


@dataclasses.dataclass(frozen=True)
class PrefetcherSpec:
    """Declarative description of one evaluated prefetcher.

    ``trains_on`` names the training stream (paper Table I): ``l2_access``
    (the L1-miss stream), ``l2_miss``, ``baseline_l2_miss`` (composite
    demand + next-line misses), ``software`` (programmer-marked), or
    ``oracle``.  ``composite`` marks whether the prefetcher is scored in the
    paper's composite (next-line + X) L2 configuration.
    """

    name: str
    fn: Callable  # generator itself, or a factory when ``configurable``
    trains_on: str
    storage: str = ""
    family: str = ""  # spatial | temporal | replay | dataflow | amc | bound
    composite: bool = True
    configurable: bool = False
    description: str = ""

    def instantiate(self, **overrides) -> Prefetcher:
        """Return a stream generator, applying config ``overrides``.

        Non-configurable prefetchers reject overrides loudly rather than
        silently ignoring them.
        """
        if self.configurable:
            return self.fn(**overrides)
        if overrides:
            raise TypeError(
                f"prefetcher {self.name!r} is not configurable; "
                f"got overrides {sorted(overrides)}"
            )
        return self.fn


_REGISTRY: Dict[str, PrefetcherSpec] = {}
_BUILTINS_LOADED = False  # False | "loading" | True


def _ensure_builtins_loaded() -> None:
    """Import the suite modules so their decorators have run."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:  # True, or "loading" during the import below
        return
    _BUILTINS_LOADED = "loading"
    before = set(_REGISTRY)
    modules_before = set(sys.modules)
    try:
        # repro.core.prefetchers imports every baseline module and the AMC
        # pipeline, each of which self-registers at import time.
        import repro.core.prefetchers  # noqa: F401
    except BaseException:
        # Roll back this attempt's registrations (a retry would otherwise
        # die on DuplicatePrefetcherError instead of the root cause) AND
        # evict the suite modules this attempt imported: modules that
        # succeeded stay cached in sys.modules, so without eviction a retry
        # would never re-execute their decorators and their prefetchers
        # would be unresolvable for the life of the process.
        for name in set(_REGISTRY) - before:
            del _REGISTRY[name]
        for mod in set(sys.modules) - modules_before:
            if mod.startswith("repro.core."):
                del sys.modules[mod]
        _BUILTINS_LOADED = False
        raise
    _BUILTINS_LOADED = True


def register_prefetcher(
    name: str,
    *,
    trains_on: str,
    storage: str = "",
    family: str = "",
    composite: bool = True,
    configurable: bool = False,
    description: Optional[str] = None,
    replace: bool = False,
) -> Callable:
    """Decorator: register ``fn`` under ``name`` with its declarative spec.

    The decorated function is returned unchanged (with a ``.spec``
    attribute), so plain-function call sites keep working.
    """

    def decorate(fn: Callable) -> Callable:
        # Load the built-in suite first so a user registration colliding
        # with a builtin fails here, in the caller's frame, instead of
        # poisoning a later lazy import of the suite modules.
        _ensure_builtins_loaded()
        if name in _REGISTRY and not replace:
            raise DuplicatePrefetcherError(
                f"prefetcher {name!r} already registered "
                f"(by {_REGISTRY[name].fn!r}); pass replace=True to override"
            )
        desc = description
        if desc is None:
            doc_lines = (fn.__doc__ or "").strip().splitlines()
            desc = doc_lines[0] if doc_lines else ""
        spec = PrefetcherSpec(
            name=name,
            fn=fn,
            trains_on=trains_on,
            storage=storage,
            family=family,
            composite=composite,
            configurable=configurable,
            description=desc,
        )
        _REGISTRY[name] = spec
        fn.spec = spec
        return fn

    return decorate


def get_prefetcher(name: str) -> PrefetcherSpec:
    """Look up a registered prefetcher spec by name."""
    _ensure_builtins_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownPrefetcherError(
            f"unknown prefetcher {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_prefetchers() -> List[str]:
    """All registered names, in registration order."""
    _ensure_builtins_loaded()
    return list(_REGISTRY)


def resolve_prefetchers(refs) -> List[Tuple[str, Prefetcher]]:
    """Normalize an ``Experiment(prefetchers=...)`` argument.

    Accepts an iterable mixing registry names, :class:`PrefetcherSpec`
    instances, and ``(name, generator)`` pairs, or a ``{name: generator}``
    mapping.  Returns ordered ``(name, generator)`` pairs; duplicate names
    are rejected.
    """
    if isinstance(refs, str):  # a bare name would otherwise iterate per-char
        refs = [refs]
    elif hasattr(refs, "items"):
        refs = list(refs.items())
    out: List[Tuple[str, Prefetcher]] = []
    seen = set()
    for ref in refs:
        if isinstance(ref, str):
            pair = (ref, get_prefetcher(ref).instantiate())
        elif isinstance(ref, PrefetcherSpec):
            pair = (ref.name, ref.instantiate())
        elif isinstance(ref, tuple) and len(ref) == 2 and callable(ref[1]):
            pair = (str(ref[0]), ref[1])
        else:
            raise TypeError(
                "prefetcher reference must be a registry name, a "
                f"PrefetcherSpec, or a (name, generator) pair; got {ref!r}"
            )
        if pair[0] in seen:
            raise ValueError(f"duplicate prefetcher name {pair[0]!r} in experiment")
        seen.add(pair[0])
        out.append(pair)
    return out


__all__ = [
    "Prefetcher",
    "PrefetcherSpec",
    "DuplicatePrefetcherError",
    "UnknownPrefetcherError",
    "register_prefetcher",
    "get_prefetcher",
    "list_prefetchers",
    "resolve_prefetchers",
]
