"""Declarative experiment API: one call evaluates a (kernel x dataset x
prefetcher) grid.

This is the unified front door over the paper's evaluation methodology
(§VI-§VII): declare *what* to evaluate —

    result = Experiment(
        kernels=["pgd", "bfs"],
        datasets=["comdblp", "amazon"],
        prefetchers=["amc", "vldp", "rnr"],
    ).run()
    result.metrics(kernel="pgd", dataset="comdblp", prefetcher="amc").speedup

— and the builder owns the *how*: workload construction through
:class:`~repro.core.driver.WorkloadSpec` (Algorithm-1 session wiring
included), a :class:`WorkloadCache` so each trace is built once and reused
across every prefetcher (and across experiments sharing the cache), registry
resolution of prefetcher names, and composite (next-line + X) scoring of
every grid cell.  The structured :class:`ExperimentResult` returns tidy
per-cell rows ready for JSON dumps or figure assembly.

Scoring one stream is :func:`score_prefetcher` — the single code path for
every caller (grid cells, stream epochs, ad-hoc scoring), so results are
comparable everywhere.  Kernel names — including direction variants like
``bfs_do`` and ``pgd_pull`` — resolve through the declarative kernel
registry (:mod:`repro.apps.registry`); dataset and prefetcher names through
theirs.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections.abc import Mapping, Sequence as _SequenceABC
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.driver import WorkloadSpec, WorkloadTrace, make_session
from repro.core.exec.artifacts import ArtifactCache
from repro.core.exec.timers import record, stage
from repro.core.obs import spans as obs
from repro.core.registry import Prefetcher, resolve_prefetchers
from repro.memsim import (
    SCALED,
    HierarchyConfig,
    PrefetchMetrics,
    current_engine,
    evaluate,
    simulate_with_prefetch,
    simulate_with_prefetch_batch,
)


def score_prefetcher(
    workload: WorkloadTrace, name: str, generate: Prefetcher
) -> PrefetchMetrics:
    """Score one prefetcher in the composite (next-line + X) configuration."""
    with obs.span(
        "score_cell",
        prefetcher=name,
        kernel=workload.spec.kernel,
        dataset=workload.spec.dataset,
    ), stage("score"):
        stream = generate(workload)
        blocks = np.concatenate([workload.nl_blocks, stream.blocks])
        pos = np.concatenate([workload.nl_pos, stream.pos])
        issuer = np.concatenate(
            [
                np.zeros(len(workload.nl_blocks), np.int8),
                np.ones(len(stream.blocks), np.int8),
            ]
        )
        outcome = simulate_with_prefetch(
            workload.profile,
            blocks,
            pos,
            pf_issuer=issuer,
            metadata_bytes=stream.metadata_bytes,
        )
        m = evaluate(
            name,
            workload.profile,
            outcome,
            baseline_outcome=workload.nl_outcome,
            eval_from_pos=workload.eval_from_pos,
            issuer=1,
        )
        m.info = stream.info  # attach prefetcher-side stats
    return m


def score_prefetchers_batched(
    workload: WorkloadTrace, pairs: Sequence[Tuple[str, Prefetcher]]
) -> List[PrefetchMetrics]:
    """Score a family of prefetchers against one workload in one dispatch.

    Under the ``fused`` engine every prefetcher's merged L2 stream joins a
    single vmapped L2→LLC scan (:func:`simulate_with_prefetch_batch`), so
    the per-prefetcher ``score_cache_pass`` launches collapse into one
    batched launch; other engines — and single-member families — fall back
    to looping :func:`score_prefetcher`.  Metrics are bit-identical to the
    loop either way (test-asserted), so callers may mix paths freely.
    """
    if len(pairs) <= 1 or current_engine() != "fused":
        return [score_prefetcher(workload, n, g) for n, g in pairs]
    with obs.span(
        "score_batch",
        prefetchers=",".join(n for n, _ in pairs),
        kernel=workload.spec.kernel,
        dataset=workload.spec.dataset,
    ), stage("score"):
        items, metas, infos = [], [], []
        for name, gen in pairs:
            # Per-cell child span over the prefetcher-specific compute
            # (stream generation — table training etc.); the joint
            # simulate/evaluate time stays on the parent batch span.
            with obs.span(
                "score_cell",
                prefetcher=name,
                kernel=workload.spec.kernel,
                dataset=workload.spec.dataset,
                batched=True,
            ):
                stream = gen(workload)
            blocks = np.concatenate([workload.nl_blocks, stream.blocks])
            pos = np.concatenate([workload.nl_pos, stream.pos])
            issuer = np.concatenate(
                [
                    np.zeros(len(workload.nl_blocks), np.int8),
                    np.ones(len(stream.blocks), np.int8),
                ]
            )
            items.append((blocks, pos, issuer))
            metas.append(stream.metadata_bytes)
            infos.append(stream.info)
        outcomes = simulate_with_prefetch_batch(workload.profile, items, metas)
        out = []
        for (name, _), outcome, info in zip(pairs, outcomes, infos):
            m = evaluate(
                name,
                workload.profile,
                outcome,
                baseline_outcome=workload.nl_outcome,
                eval_from_pos=workload.eval_from_pos,
                issuer=1,
            )
            m.info = info
            out.append(m)
    return out


def _retarget_trace(trace: WorkloadTrace, spec) -> WorkloadTrace:
    """A content-identical trace re-bound to ``spec``.

    Arrays are shared (they are bit-identical by construction of the
    content key); the spec and its derived AMC session are fresh, exactly
    as :func:`repro.core.exec.artifacts._unpack` rebinds a loaded
    artifact — so scoring a reused trace equals scoring a re-emission.
    """
    return dataclasses.replace(
        trace, spec=spec, session=make_session(spec, trace.cfg_trace)
    )


class WorkloadCache:
    """Build-once cache of :class:`WorkloadTrace` keyed by ``WorkloadSpec``.

    Each workload in an :class:`Experiment` is built once and scored by
    every prefetcher; pass the same cache instance to several experiments
    to reuse builds across them too.

    ``artifacts`` optionally backs the in-memory store with the on-disk
    :class:`~repro.core.exec.artifacts.ArtifactCache`: misses consult the
    artifact store before building, and fresh builds are persisted there —
    so repeat sweeps and parallel runs skip rebuilds across processes.

    Content-keyed specs (those exposing ``content_key()``, e.g. stream
    epoch specs) additionally deduplicate *within* the in-memory store:
    two distinct specs whose traces are determined by identical content —
    epochs a churn model left unchanged, the same epoch reached through
    different stream parameters — share one build, retargeted per spec
    (``reuses`` counts these alias hits).
    """

    def __init__(self, artifacts: Optional[ArtifactCache] = None):
        self._store: Dict[WorkloadSpec, WorkloadTrace] = {}
        self._by_content: Dict[str, WorkloadTrace] = {}
        self.artifacts = artifacts
        self.builds = 0
        self.hits = 0
        self.loads = 0  # artifact-cache (disk) hits
        self.reuses = 0  # in-memory content-alias hits (distinct specs)

    def get_or_build(self, spec: WorkloadSpec) -> WorkloadTrace:
        if spec in self._store:
            self.hits += 1
            obs.inc("workload_cache.hits")
            return self._store[spec]
        content = getattr(spec, "content_key", None)
        ck = (
            json.dumps(content(), sort_keys=True) if callable(content) else None
        )
        with obs.span(
            "get_or_build", kernel=spec.kernel, dataset=spec.dataset
        ) as sp:
            trace = (
                self.artifacts.load(spec) if self.artifacts is not None else None
            )
            if trace is not None:
                self.loads += 1
                obs.inc("workload_cache.loads")
                if sp:
                    sp.attrs["cache"] = "load"
            elif ck is not None and ck in self._by_content:
                trace = _retarget_trace(self._by_content[ck], spec)
                self.reuses += 1
                obs.inc("workload_cache.reuses")
                if sp:
                    sp.attrs["cache"] = "reuse"
            if trace is None:
                self.builds += 1
                obs.inc("workload_cache.builds")
                if sp:
                    sp.attrs["cache"] = "build"
                t0 = time.perf_counter()
                trace = spec.build()
                if self.artifacts is not None:
                    self.artifacts.save(spec, trace)
                    self.artifacts.record_cost(
                        spec, build_s=time.perf_counter() - t0
                    )
            if ck is not None:
                self._by_content.setdefault(ck, trace)
            self._store[spec] = trace
            return trace

    def evict(self, spec: WorkloadSpec) -> None:
        """Drop the in-memory entry (the artifact, if any, stays on disk).

        Lets long sweeps bound peak memory at one trace: process a
        workload, write its results, evict, move on.
        """
        self._store.pop(spec, None)

    def __len__(self) -> int:
        return len(self._store)


class _LazyWorkloads(Mapping):
    """``ExperimentResult.workloads`` view that materializes traces on
    first access (artifact-cache load, else rebuild).

    After a parallel run the built traces live in the artifact store, not
    in the parent process; loading all of them eagerly would charge every
    grid run for workloads the caller never reads.  Keys are present up
    front (iteration, ``len``, membership are free); values materialize
    through the experiment's workload cache on demand — including via
    ``dict(...)``/``.items()``, which go through ``__getitem__``.
    """

    def __init__(self, loader, specs):
        self._specs = list(specs)
        self._keys = set(self._specs)
        self._loader = loader

    def __getitem__(self, spec):
        if spec not in self._keys:
            raise KeyError(spec)
        return self._loader(spec)

    def __contains__(self, spec):  # the Mapping mixin would materialize
        return spec in self._keys

    def __iter__(self):
        return iter(self._specs)

    def __len__(self):
        return len(self._specs)


class _PipelinedTraces(_SequenceABC):
    """Sequence view over a stream's epoch traces that blocks on each
    epoch's *background build* on first access, then loads it through the
    workload cache — the handoff between the spawn pool and the in-parent
    lifecycle scorer.  Indexing epoch 0 does not wait for epochs 1..E, so
    scoring overlaps the remaining builds."""

    def __init__(self, pipeline, specs, cache: WorkloadCache):
        self._pipeline = pipeline
        self._specs = list(specs)
        self._cache = cache

    def __len__(self) -> int:
        return len(self._specs)

    def __getitem__(self, i: int) -> WorkloadTrace:
        spec = self._specs[i]  # IndexError here ends Sequence iteration
        self._pipeline.wait(spec)
        return self._cache.get_or_build(spec)


@dataclasses.dataclass(frozen=True)
class CellResult:
    """One grid cell: a prefetcher scored on one workload.

    Stream cells (from a :class:`repro.stream.protocol.StreamSpec`
    workload) additionally carry the epoch index and, for lifecycle-aware
    prefetchers, the table-lifecycle policy; serving cells (from a
    :class:`repro.serve.protocol.ServeSpec`) carry the tenant index and,
    for AMC-family prefetchers, the table mode.  All stay ``None`` for
    plain workload cells so the legacy row schema is unchanged.
    """

    kernel: str
    dataset: str
    prefetcher: str
    seed: int
    metrics: PrefetchMetrics
    spec: Optional[WorkloadSpec] = None  # full workload identity
    epoch: Optional[int] = None  # stream cells only
    lifecycle: Optional[str] = None  # stream cells with carried tables
    tenant: Optional[int] = None  # serving cells only
    table_mode: Optional[str] = None  # serving cells, AMC family


@dataclasses.dataclass
class ExperimentResult:
    """Structured result over the full evaluation grid.

    ``workloads`` is keyed by the full :class:`WorkloadSpec` (specs
    differing only in hierarchy or element sizes stay distinct); filter
    cells by ``spec=`` when kernel/dataset/seed alone are ambiguous.
    """

    cells: List[CellResult]
    # A plain dict after a serial run; a lazy Mapping after a parallel run.
    workloads: Mapping[WorkloadSpec, WorkloadTrace]
    # The cost model's scheduling decision (a SchedDecision dict) when the
    # run resolved ``workers=None`` itself; None when the caller forced a
    # worker count.
    sched: Optional[dict] = None
    # Epoch traces served from the content-addressed cache instead of
    # being re-emitted (delta-aware reuse; counts stream epochs only).
    trace_reuse: int = 0
    # Run telemetry (see docs/OBSERVABILITY.md): the run manifest (git
    # sha, resolved engine/emitter, schema versions, SchedDecision),
    # workload-cache counters, and — when a tracer was active — the trace
    # id tying this result to its merged RunTrace.
    telemetry: Optional[dict] = None

    def select(self, **filters) -> List[CellResult]:
        """Cells matching all given kernel/dataset/prefetcher/seed filters."""
        out = self.cells
        for field, want in filters.items():
            out = [c for c in out if getattr(c, field) == want]
        return out

    def metrics(self, **filters) -> PrefetchMetrics:
        """The unique cell's metrics matching the filters (error otherwise)."""
        hits = self.select(**filters)
        if len(hits) != 1:
            raise KeyError(
                f"filters {filters} matched {len(hits)} cells, expected 1"
            )
        return hits[0].metrics

    def suite(self, kernel: str, dataset: str, seed: int = 0) -> Dict[str, PrefetchMetrics]:
        """Legacy-shaped ``{prefetcher: metrics}`` view of one workload cell."""
        cells = self.select(kernel=kernel, dataset=dataset, seed=seed)
        if not cells:
            raise KeyError(
                f"({kernel}, {dataset}, seed={seed}) matched no cells; "
                f"workloads run: {sorted(set((c.kernel, c.dataset, c.seed) for c in self.cells))}"
            )
        out: Dict[str, PrefetchMetrics] = {}
        for c in cells:
            if c.prefetcher in out:
                raise KeyError(
                    f"({kernel}, {dataset}, seed={seed}) matched multiple "
                    "workload specs; use select(spec=...) to disambiguate"
                )
            out[c.prefetcher] = c.metrics
        return out

    def rows(self) -> List[dict]:
        """Tidy per-cell rows: grid coordinates + flattened metrics.

        Stream cells gain ``epoch`` (and ``lifecycle``) columns; serving
        cells gain ``tenant`` (and ``table_mode``); plain cells keep the
        exact legacy schema.
        """
        out = []
        for c in self.cells:
            row = dict(
                kernel=c.kernel,
                dataset=c.dataset,
                prefetcher=c.prefetcher,
                seed=c.seed,
            )
            if c.epoch is not None:
                row["epoch"] = c.epoch
                row["lifecycle"] = c.lifecycle
            if c.tenant is not None:
                row["tenant"] = c.tenant
                row["table_mode"] = c.table_mode
            row.update(c.metrics.row())
            out.append(row)
        return out

    def workload(self, kernel: str, dataset: str, seed: int = 0) -> WorkloadTrace:
        """The unique built trace for (kernel, dataset, seed); with several
        specs sharing those coordinates, index ``workloads`` by spec."""
        hits = [
            s
            for s in self.workloads
            if (s.kernel, s.dataset, s.seed) == (kernel, dataset, seed)
        ]
        if len(hits) != 1:
            raise KeyError(
                f"({kernel}, {dataset}, seed={seed}) matched {len(hits)} "
                "workloads; index result.workloads by WorkloadSpec instead"
            )
        return self.workloads[hits[0]]


class Experiment:
    """Declarative builder for a prefetcher-evaluation grid.

    Either give ``kernels`` + ``datasets`` (the cross product is taken, once
    per seed) or pass explicit ``workloads=[WorkloadSpec(...), ...]``.
    ``prefetchers`` accepts registry names, :class:`PrefetcherSpec` objects,
    ``(name, generator)`` pairs, or a mapping — see
    :func:`repro.core.registry.resolve_prefetchers`.
    """

    def __init__(
        self,
        kernels: Optional[Sequence[str]] = None,
        datasets: Optional[Sequence[str]] = None,
        prefetchers: Iterable = ("amc",),
        hierarchy: HierarchyConfig = SCALED,
        seeds: Sequence[int] = (0,),
        workloads: Optional[Sequence[WorkloadSpec]] = None,
        cache: Optional[WorkloadCache] = None,
    ):
        if workloads is not None:
            if kernels is not None or datasets is not None:
                raise ValueError("pass either workloads= or kernels=+datasets=")
            if hierarchy is not SCALED or tuple(seeds) != (0,):
                raise ValueError(
                    "hierarchy=/seeds= apply to the kernels=+datasets= grid; "
                    "with workloads=, declare them on each WorkloadSpec"
                )
            # Multi-epoch stream scenarios (repro.stream.protocol.StreamSpec)
            # and multi-tenant serving scenarios (repro.serve.protocol.
            # ServeSpec) mix freely with plain workloads; they expand into
            # per-epoch / per-tenant workload specs at run time and score
            # through their protocol modules (duck-typed so those modules
            # load lazily).
            self.stream_specs = [
                w for w in workloads if getattr(w, "is_stream", False)
            ]
            self.serve_specs = [
                w for w in workloads if getattr(w, "is_serve", False)
            ]
            self.workload_specs = [
                w
                for w in workloads
                if not getattr(w, "is_stream", False)
                and not getattr(w, "is_serve", False)
            ]
        else:
            self.stream_specs = []
            self.serve_specs = []
            if not kernels or not datasets:
                raise ValueError("kernels= and datasets= must both be non-empty")
            self.workload_specs = [
                WorkloadSpec(kernel=k, dataset=d, hierarchy=hierarchy, seed=s)
                for k in kernels
                for d in datasets
                for s in seeds
            ]
        # Fail fast on typo'd names at declaration time, not first build.
        for spec in self.workload_specs + self.stream_specs + self.serve_specs:
            spec.validate_names()
        self.prefetchers: List[Tuple[str, Prefetcher]] = resolve_prefetchers(
            prefetchers
        )
        self.cache = cache if cache is not None else WorkloadCache()

    @property
    def prefetcher_names(self) -> List[str]:
        return [name for name, _ in self.prefetchers]

    @property
    def grid(self) -> List[Tuple[WorkloadSpec, str]]:
        """The full (workload, prefetcher) evaluation grid, in run order."""
        return [
            (spec, name)
            for spec in self.workload_specs
            for name in self.prefetcher_names
        ]

    def run(
        self,
        verbose: bool = False,
        workers: Optional[int] = None,
        pipeline: bool = True,
    ) -> ExperimentResult:
        """Build every workload (cached) and score every grid cell.

        ``workers=N`` (N >= 2) opts into the parallel execution engine:
        cells are sharded across a spawned process pool, grouped by
        workload so each trace is built once, with built traces persisted
        in the workload artifact cache.  Cell ordering and every metric
        are bit-identical to the serial path.  ``workers=1`` forces the
        serial reference implementation; the default (``workers=None``)
        consults the scheduler's cost model
        (:func:`repro.core.exec.scheduler.plan_execution`): task costs are
        estimated from artifact-cache metadata (spec-derived on a cold
        cache), and a pool is spawned only when its predicted time —
        spawn overhead plus the load-balanced makespan — beats running
        in-process.  On a single core, under memory pressure, or with
        unpicklable ad-hoc prefetchers (which cannot cross the spawn
        boundary) the run degrades to serial with no pool at all.  The
        decision is surfaced as ``result.sched``.

        ``pipeline`` selects the overlapped schedule (score tasks
        dispatched as their builds complete) over the legacy phased
        materialize-all-then-score-all schedule; both are bit-identical
        to serial, the flag exists for the bench's A/B comparison.

        Stream workloads expand into per-epoch traces (built/cached like
        any workload — under ``workers=N`` the epochs of every stream are
        materialized across the pool and handed to the scorer as each
        build lands) and are scored *in the parent* by the stream
        protocol, whose cross-epoch table lifecycle is inherently
        sequential; stream results are therefore byte-identical between
        serial and parallel runs too.  Serving workloads follow the same
        contract: per-tenant traces materialize across the pool, the
        interleaved shared-LLC scoring runs in the parent.  Epoch traces
        are content-keyed, so epochs whose graph the churn model left
        unchanged are *reused* rather than re-emitted
        (``result.trace_reuse`` counts them).
        """
        with obs.span(
            "experiment_run",
            workloads=len(self.workload_specs),
            streams=len(self.stream_specs),
            serves=len(self.serve_specs),
            prefetchers=self.prefetcher_names,
        ):
            result = self._run_impl(verbose, workers, pipeline)
        result.telemetry = self._telemetry(result.sched)
        return result

    def _run_impl(
        self, verbose: bool, workers: Optional[int], pipeline: bool
    ) -> ExperimentResult:
        sched = None
        if workers is None:
            sched = self._plan_schedule()
            record(f"sched_decision[{sched.mode}]")
            workers = sched.workers
        if workers > 1:
            if self.workload_specs:
                result = self._run_parallel(workers, verbose, pipeline)
            else:  # stream/serve-only grid: no cells to shard, only builds
                result = ExperimentResult(cells=[], workloads={})
            if self.stream_specs:
                self._append_stream_cells(result, verbose, workers=workers)
            if self.serve_specs:
                self._append_serve_cells(result, verbose, workers=workers)
            result.sched = sched.as_dict() if sched is not None else None
            return result
        cells: List[CellResult] = []
        traces: Dict[WorkloadSpec, WorkloadTrace] = {}
        for spec in self.workload_specs:
            if getattr(spec, "is_sharded", False):
                # Sharded cells stream from the on-disk shard store (never a
                # whole WorkloadTrace), so they always need an artifact cache
                # — attach the default one exactly as the parallel path does.
                from repro.core.exec import sharded

                if self.cache.artifacts is None:
                    self.cache.artifacts = ArtifactCache()
                for name, m in sharded.score_sharded(
                    spec, self.prefetchers, self.cache.artifacts
                ):
                    cells.append(
                        CellResult(
                            kernel=spec.kernel,
                            dataset=spec.dataset,
                            prefetcher=name,
                            seed=spec.seed,
                            metrics=m,
                            spec=spec,
                        )
                    )
                    if verbose:
                        print(
                            f"[{spec.kernel}/{spec.dataset}] {name}: "
                            f"speedup {m.speedup:.2f} coverage {m.coverage:.2f} "
                            f"accuracy {m.accuracy:.2f}"
                        )
                continue
            w = self.cache.get_or_build(spec)
            traces[spec] = w
            t0 = time.perf_counter()
            metrics = score_prefetchers_batched(w, self.prefetchers)
            if self.cache.artifacts is not None and self.prefetchers:
                self.cache.artifacts.record_cost(
                    spec,
                    score_s_per_prefetcher=(
                        (time.perf_counter() - t0) / len(self.prefetchers)
                    ),
                )
            for (name, gen), m in zip(self.prefetchers, metrics):
                cells.append(
                    CellResult(
                        kernel=spec.kernel,
                        dataset=spec.dataset,
                        prefetcher=name,
                        seed=spec.seed,
                        metrics=m,
                        spec=spec,
                    )
                )
                if verbose:
                    print(
                        f"[{spec.kernel}/{spec.dataset}] {name}: "
                        f"speedup {m.speedup:.2f} coverage {m.coverage:.2f} "
                        f"accuracy {m.accuracy:.2f}"
                    )
        result = ExperimentResult(cells=cells, workloads=traces)
        if self.stream_specs:
            self._append_stream_cells(result, verbose, workers=None)
        if self.serve_specs:
            self._append_serve_cells(result, verbose, workers=None)
        result.sched = sched.as_dict() if sched is not None else None
        return result

    def _telemetry(self, sched: Optional[dict]) -> dict:
        """Provenance + counters block for ``ExperimentResult.telemetry``."""
        from repro.core.obs.manifest import run_manifest

        doc = {
            "manifest": run_manifest(sched=sched),
            "workload_cache": {
                "hits": self.cache.hits,
                "builds": self.cache.builds,
                "loads": self.cache.loads,
                "reuses": self.cache.reuses,
            },
        }
        tracer = obs.current_tracer()
        if tracer is not None:
            doc["trace_id"] = tracer.trace_id
        return doc

    def _plan_schedule(self):
        """Resolve ``workers=None`` through the scheduler's cost model.

        Every independent build in the run — plain workloads, stream
        epochs, serve tenants — is costed against the artifact store;
        :func:`repro.core.exec.scheduler.plan_execution` then picks
        serial in-process execution or a pipelined pool sized from the
        predicted makespan.  Unpicklable ad-hoc prefetchers force serial
        (``workers=N`` rejects them loudly, but a *default* must
        tolerate them)."""
        import os
        import pickle

        from repro.core.exec import scheduler  # lazy: avoids import cycle

        try:
            for _, gen in self.prefetchers:
                pickle.dumps(gen)
        except Exception:
            return scheduler.SchedDecision(
                mode="serial",
                workers=1,
                est_serial_s=0.0,
                est_pool_s=None,
                reason=(
                    "unpicklable ad-hoc prefetchers cannot cross the "
                    "spawn boundary"
                ),
                cores=os.cpu_count() or 1,
                n_tasks=0,
                measured_frac=0.0,
            )
        specs = list(self.workload_specs)
        for s in self.stream_specs:
            specs.extend(s.epoch_specs())
        for s in self.serve_specs:
            specs.extend(s.tenant_workloads())
        artifacts = (
            self.cache.artifacts
            if self.cache.artifacts is not None
            else ArtifactCache()
        )
        return scheduler.plan_execution(specs, len(self.prefetchers), artifacts)

    def _auto_workers(self) -> int:
        """The worker count ``workers=None`` resolves to (see
        :meth:`_plan_schedule`); kept as the stable introspection point."""
        return self._plan_schedule().workers

    def _append_stream_cells(
        self, result: ExperimentResult, verbose: bool, workers: Optional[int]
    ) -> None:
        """Score every stream scenario and fold its per-epoch cells in.

        Parallel runs hand epochs off as they materialize: the lifecycle
        scorer starts on epoch 0 while later epochs are still building in
        the pool (:class:`~repro.core.exec.scheduler.MaterializePipeline`
        + :class:`_PipelinedTraces`), instead of waiting for all builds.
        Either path counts delta-aware reuse — unique epoch specs whose
        trace came from the content-addressed cache (or an in-memory
        content alias) rather than a fresh emission — into
        ``result.trace_reuse``; the count is identical serial vs pooled.
        """
        from repro.stream import protocol  # lazy: the protocol imports us

        epoch_specs = {
            es: None for spec in self.stream_specs for es in spec.epoch_specs()
        }
        builds_before = self.cache.builds
        pipeline = None
        if workers is not None and workers > 1:
            # Epochs are independent *builds*: fan them across the pool,
            # then walk the lifecycle sequentially in the parent, pulling
            # each epoch as its build lands.
            from repro.core.exec import scheduler

            if self.cache.artifacts is None:
                self.cache.artifacts = ArtifactCache()
            pipeline = scheduler.MaterializePipeline(
                list(epoch_specs),
                workers=workers,
                artifacts=self.cache.artifacts,
            )
        try:
            for spec in self.stream_specs:
                if pipeline is not None:
                    traces: Sequence = _PipelinedTraces(
                        pipeline, spec.epoch_specs(), self.cache
                    )
                else:
                    traces = [
                        self.cache.get_or_build(es) for es in spec.epoch_specs()
                    ]
                for cell in protocol.score_stream(spec, self.prefetchers, traces):
                    result.cells.append(
                        CellResult(
                            kernel=spec.kernel,
                            dataset=spec.dataset,
                            prefetcher=cell.prefetcher,
                            seed=spec.seed,
                            metrics=cell.metrics,
                            spec=cell.spec,
                            epoch=cell.epoch,
                            lifecycle=cell.lifecycle,
                        )
                    )
                    if verbose:
                        m = cell.metrics
                        print(
                            f"[{spec.kernel}/{spec.dataset}@e{cell.epoch}] "
                            f"{cell.prefetcher}: speedup {m.speedup:.2f} "
                            f"coverage {m.coverage:.2f} accuracy {m.accuracy:.2f}"
                        )
        finally:
            if pipeline is not None:
                pipeline.close()
        if pipeline is not None:
            result.trace_reuse += pipeline.n_specs - pipeline.n_built
        else:
            result.trace_reuse += len(epoch_specs) - (
                self.cache.builds - builds_before
            )
        if isinstance(result.workloads, dict):
            for spec in self.stream_specs:
                for es in spec.epoch_specs():
                    result.workloads[es] = self.cache.get_or_build(es)
        else:
            result.workloads = _LazyWorkloads(
                self.cache.get_or_build,
                list(result.workloads) + list(epoch_specs),
            )

    def _append_serve_cells(
        self, result: ExperimentResult, verbose: bool, workers: Optional[int]
    ) -> None:
        """Score every serving scenario and fold its per-tenant cells in."""
        from repro.serve import protocol  # lazy: the protocol imports us

        tenant_specs = {
            ws: None
            for spec in self.serve_specs
            for ws in spec.tenant_workloads()
        }
        if workers is not None and workers > 1:
            # Tenants are independent *builds*: materialize them across
            # the pool, then run the interleaved scoring in the parent.
            from repro.core.exec import scheduler

            if self.cache.artifacts is None:
                self.cache.artifacts = ArtifactCache()
            scheduler.materialize_specs(
                list(tenant_specs),
                workers=workers,
                artifacts=self.cache.artifacts,
            )
        for spec in self.serve_specs:
            traces = [
                self.cache.get_or_build(ws) for ws in spec.tenant_workloads()
            ]
            for cell in protocol.score_serve(spec, self.prefetchers, traces):
                ws = cell.spec
                result.cells.append(
                    CellResult(
                        kernel=ws.kernel,
                        dataset=ws.dataset,
                        prefetcher=cell.prefetcher,
                        seed=ws.seed,
                        metrics=cell.metrics,
                        spec=ws,
                        tenant=cell.tenant,
                        table_mode=cell.table_mode,
                    )
                )
                if verbose:
                    m = cell.metrics
                    mode = cell.table_mode or "stateless"
                    print(
                        f"[{ws.kernel}/{ws.dataset}@t{cell.tenant}] "
                        f"{cell.prefetcher}/{mode}: speedup {m.speedup:.2f} "
                        f"coverage {m.coverage:.2f} accuracy {m.accuracy:.2f}"
                    )
        if isinstance(result.workloads, dict):
            for ws in tenant_specs:
                result.workloads[ws] = self.cache.get_or_build(ws)
        else:
            known = set(result.workloads)
            result.workloads = _LazyWorkloads(
                self.cache.get_or_build,
                list(result.workloads)
                + [ws for ws in tenant_specs if ws not in known],
            )

    def _run_parallel(
        self, workers: int, verbose: bool, pipeline: bool = True
    ) -> ExperimentResult:
        from repro.core.exec import scheduler  # lazy: avoids import cycle

        if self.cache.artifacts is None:
            # Workers share builds through the artifact store; attach the
            # default one so the in-process cache sees the same artifacts.
            self.cache.artifacts = ArtifactCache()
        metrics, prebuilt = scheduler.run_grid(
            self.workload_specs,
            self.prefetchers,
            workers=workers,
            artifacts=self.cache.artifacts,
            verbose=verbose,
            pipeline=pipeline,
        )
        # Later experiments sharing this cache reuse any parent-side builds.
        for spec, trace in prebuilt.items():
            self.cache._store.setdefault(spec, trace)
        cells = [
            CellResult(
                kernel=spec.kernel,
                dataset=spec.dataset,
                prefetcher=name,
                seed=spec.seed,
                metrics=metrics[(spec, name)],
                spec=spec,
            )
            for spec in self.workload_specs
            for name in self.prefetcher_names
        ]
        # Workers persisted their traces in the artifact store; materialize
        # them lazily so runs that only read metrics never pay the loads.
        # Sharded cells have no whole-trace artifact to load, so they are
        # never part of the workloads mapping (serial runs agree).
        workloads = _LazyWorkloads(
            self.cache.get_or_build,
            dict.fromkeys(
                s
                for s in self.workload_specs
                if not getattr(s, "is_sharded", False)
            ),
        )
        return ExperimentResult(cells=cells, workloads=workloads)


__all__ = [
    "CellResult",
    "Experiment",
    "ExperimentResult",
    "WorkloadCache",
    "score_prefetcher",
    "score_prefetchers_batched",
]
