"""Trivial / modeled prefetchers: extra next-line, DROPLET/Prodigy model, IDEAL."""
from __future__ import annotations

import numpy as np

from repro.core.amc.prefetcher import PrefetchStream
from repro.core.registry import register_prefetcher


@register_prefetcher(
    "nextline2",
    trains_on="l2_access",
    storage="none",
    family="spatial",
)
def nextline_extra(workload) -> PrefetchStream:
    """A second next-line (degree 2 total with the baseline's)."""
    pos, blocks, _, _ = workload.l2_stream()
    keep = np.ones(len(blocks), dtype=bool)
    keep[1:] = blocks[1:] != blocks[:-1]
    return PrefetchStream("nextline2", blocks[keep] + 2, pos[keep])


@register_prefetcher(
    "prodigy",
    trains_on="baseline_l2_miss",
    storage="software data-flow graph",
    family="dataflow",
)
def droplet_model(workload) -> PrefetchStream:
    """DROPLET/Prodigy dependency-prefetch model (paper §VII-A quantitative
    comparison, via the RnR paper's DROPLET model).

    Two modeled deficiencies: (1) a vertex-property address is computed only
    when the edge value it depends on arrives from DRAM, so the prefetch
    leads the demand by roughly one L2->core hop (accurate but barely
    early); (2) no control-flow knowledge — the dataflow walks *every*
    present vertex's neighbors, so data for inactive vertices is fetched
    too, thrashing the L2 (the paper: Prodigy "cannot account for additional
    control-flow information that leads to cache thrashing")."""
    mpos, mblocks, _ = workload.baseline_miss_stream()
    lead = 2
    pf_b = [mblocks.copy()]
    pf_p = [np.maximum(mpos - lead, 0)]
    # Control-flow-blind overfetch: P-array rows of untouched vertices,
    # paced across each iteration (volume ~= inactive fraction).
    from repro.apps.trace import P_ID
    from repro.memsim.config import BLOCK_BITS

    p_base, p_size = workload.cfg_trace.region(P_ID)
    p_lo = p_base >> BLOCK_BITS
    p_blocks_total = p_size >> BLOCK_BITS
    views = workload.amc_iteration_views()
    for view, _ in views:
        if len(view.target_pos) < 2:
            continue
        touched = np.unique(view.miss_blocks)
        allp = np.arange(p_lo, p_lo + p_blocks_total, dtype=np.int64)
        untouched = np.setdiff1d(allp, touched, assume_unique=True)
        if len(untouched) == 0:
            continue
        span_lo, span_hi = int(view.target_pos[0]), int(view.target_pos[-1])
        reppos = span_lo + (
            np.arange(len(untouched), dtype=np.int64)
            * max(span_hi - span_lo, 1)
        ) // len(untouched)
        pf_b.append(untouched)
        pf_p.append(reppos)
    return PrefetchStream(
        "prodigy",
        np.concatenate(pf_b),
        np.concatenate(pf_p),
        metadata_bytes=0,
    )


@register_prefetcher(
    "ideal",
    trains_on="oracle",
    storage="none",
    family="bound",
)
def ideal_l2(workload) -> PrefetchStream:
    """IDEAL (infinite L2) bound: every baseline miss prefetched exactly one
    fill-window early — used as the Fig 8 'IDEAL' reference."""
    mpos, mblocks, _ = workload.baseline_miss_stream()
    lead = 2 * workload.profile.cfg.pf_fill_window
    return PrefetchStream("ideal", mblocks.copy(), np.maximum(mpos - lead, 0))
