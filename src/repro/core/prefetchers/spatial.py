"""Spatial baselines: VLDP (cascaded delta tables) and Bingo (footprints).

Both learn within-page patterns. Tables are trained on the previous epoch's
L2 access stream (epoch-causal, like the temporal baselines); triggers are
composite-baseline L2 misses.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.amc.prefetcher import PrefetchStream
from repro.core.registry import register_prefetcher

PAGE_BLOCKS = 64  # 4KB page / 64B line


def _page_off(blocks: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    return blocks >> 6, blocks & 63


def _majority_table(keys: np.ndarray, nexts: np.ndarray):
    """key -> most frequent next value. Returns (sorted_keys, best_next)."""
    if len(keys) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    order = np.lexsort((nexts, keys))
    k, nx = keys[order], nexts[order]
    # count runs of (key, next)
    new_pair = np.ones(len(k), dtype=bool)
    new_pair[1:] = (k[1:] != k[:-1]) | (nx[1:] != nx[:-1])
    pair_start = np.flatnonzero(new_pair)
    pair_count = np.diff(np.append(pair_start, len(k)))
    pk, pn = k[pair_start], nx[pair_start]
    # per key pick the max-count pair
    new_key = np.ones(len(pk), dtype=bool)
    new_key[1:] = pk[1:] != pk[:-1]
    key_id = np.cumsum(new_key) - 1
    best = np.full(key_id[-1] + 1, -1, dtype=np.int64)
    best_cnt = np.zeros(key_id[-1] + 1, dtype=np.int64)
    np.maximum.at(best_cnt, key_id, pair_count)
    take = pair_count == best_cnt[key_id]
    # later duplicates overwrite; deterministic enough for a majority table
    best[key_id[take]] = pn[take]
    return pk[np.flatnonzero(new_key)], best


def _lookup(sorted_keys: np.ndarray, values: np.ndarray, q: np.ndarray):
    if len(sorted_keys) == 0:
        return np.full(len(q), -(10**9), dtype=np.int64)
    li = np.searchsorted(sorted_keys, q)
    li_c = np.minimum(li, len(sorted_keys) - 1)
    ok = sorted_keys[li_c] == q
    return np.where(ok, values[li_c], -(10**9))


def _window_dedupe(blocks: np.ndarray, pos: np.ndarray, window: int) -> np.ndarray:
    """Keep an issue only if the previous issue of the same block is more
    than ``window`` accesses earlier (L2 residency horizon proxy). Returns a
    boolean keep-mask in the input order."""
    n = len(blocks)
    key = (blocks.astype(np.int64) << np.int64(31)) | np.maximum(pos, 0)
    order = np.argsort(key)
    b, p = blocks[order], pos[order]
    same = np.zeros(n, dtype=bool)
    same[1:] = b[1:] == b[:-1]
    gap_ok = np.ones(n, dtype=bool)
    gap_ok[1:] = (p[1:] - p[:-1]) > window
    keep_sorted = ~same | gap_ok
    keep = np.zeros(n, dtype=bool)
    keep[order] = keep_sorted
    return keep


def _page_deltas(blocks: np.ndarray, pos: np.ndarray):
    """Sort by (page, stream order); return per-access page, delta history."""
    page, off = _page_off(blocks)
    key = (page.astype(np.int64) << np.int64(31)) | np.arange(len(blocks))
    order = np.argsort(key)
    pg, of, po = page[order], off[order], pos[order]
    new_pg = np.ones(len(pg), dtype=bool)
    new_pg[1:] = pg[1:] != pg[:-1]
    d = np.zeros(len(pg), dtype=np.int64)
    d[1:] = of[1:] - of[:-1]
    d[new_pg] = 0  # no delta at page start
    valid = ~new_pg

    def hist(k):
        h = np.full(len(pg), -(10**8), dtype=np.int64)
        h[k:] = d[: len(pg) - k] if k else d
        # invalidate histories crossing page starts
        bad = np.zeros(len(pg), dtype=bool)
        for j in range(k + 1):
            b = np.zeros(len(pg), dtype=bool)
            b[j:] = new_pg[: len(pg) - j] if j else new_pg
            bad |= b
        h[bad] = -(10**8)
        return h

    return order, pg, of, po, d, valid, hist


_B = np.int64(1 << 14)  # delta packing radix (deltas in [-64, 63])


def _pack2(a, b):
    return (a + 64) * _B + (b + 64)


def _pack3(a, b, c):
    return ((a + 64) * _B + (b + 64)) * _B + (c + 64)


@register_prefetcher(
    "vldp",
    trains_on="l2_access",
    storage="on-chip cascaded delta tables",
    family="spatial",
)
def vldp(workload) -> PrefetchStream:
    """VLDP [51]: cascaded DPT1..3 + OPT, degree 4 (paper Table VIII).

    Prediction priority: longest delta-history match (DPT3 > DPT2 > DPT1 >
    OPT). Chaining beyond the first prediction follows DPT1. Storage is
    on-chip (~1KB) => no off-chip metadata traffic.
    """
    pos, blocks, _, epochs = workload.l2_stream()
    miss_mask = ~workload.nl_outcome.demand_l2_hit
    out_b, out_p = [], []
    tables: Optional[dict] = None
    for e in np.unique(epochs):
        s = epochs == e
        blk_e, pos_e, miss_e = blocks[s], pos[s], miss_mask[s]
        order, pg, of, po, d, valid, hist = _page_deltas(blk_e, pos_e)

        if tables is not None and len(blk_e):
            h1, h2, h3 = hist(1), hist(2), hist(3)
            # triggers: misses with at least one past delta in page
            mi = miss_e[order] & valid
            q1 = _lookup(tables["t1"][0], tables["t1"][1], d)
            q2 = _lookup(tables["t2"][0], tables["t2"][1], _pack2(h1, d))
            q3 = _lookup(tables["t3"][0], tables["t3"][1], _pack3(h2, h1, d))
            pred = np.where(q3 > -(10**8), q3, np.where(q2 > -(10**8), q2, q1))
            # OPT: first access in page predicts via first-offset table
            first = ~valid
            qo = _lookup(tables["opt"][0], tables["opt"][1], of)
            pred = np.where(first, qo, pred)
            mi = miss_e[order] & (pred > -(10**8))
            base_off = of
            cur_off = base_off
            cur_delta = pred
            ep_b, ep_p = [], []
            for step in range(4):
                nxt = cur_off + cur_delta
                ok = mi & (nxt >= 0) & (nxt < PAGE_BLOCKS) & (cur_delta > -(10**8))
                ep_b.append((pg[ok] << 6) | nxt[ok])
                ep_p.append(po[ok])
                if step < 3:
                    cur_off = np.where(ok, nxt, cur_off)
                    nd = _lookup(tables["t1"][0], tables["t1"][1], cur_delta)
                    cur_delta = nd
                    mi = ok
            # In-flight/residency filter: successive triggers walking the
            # same pattern re-predict the same lines; re-issue a block only
            # after its previous issue has likely aged out of L2.
            eb = np.concatenate(ep_b)
            ep = np.concatenate(ep_p)
            if len(eb):
                keep = _window_dedupe(eb, ep, window=1500)
                out_b.append(eb[keep])
                out_p.append(ep[keep])

        # train tables on this epoch for the next one
        h1, h2, h3 = hist(1), hist(2), hist(3)
        nxt_d = np.full(len(d), -(10**8), dtype=np.int64)
        nxt_d[:-1] = d[1:]
        same_pg = np.zeros(len(d), dtype=bool)
        same_pg[:-1] = pg[1:] == pg[:-1]
        tr = valid & same_pg & (nxt_d > -(10**8))
        t1 = _majority_table(d[tr], nxt_d[tr])
        tr2 = tr & (h1 > -(10**8))
        t2 = _majority_table(_pack2(h1[tr2], d[tr2]), nxt_d[tr2])
        tr3 = tr2 & (h2 > -(10**8))
        t3 = _majority_table(_pack3(h2[tr3], h1[tr3], d[tr3]), nxt_d[tr3])
        first = np.ones(len(d), dtype=bool)
        first[1:] = pg[1:] != pg[:-1]
        fo = first.copy()
        fo[:-1] &= same_pg[:-1]
        opt = _majority_table(of[first & same_pg], nxt_d[first & same_pg])
        tables = dict(t1=t1, t2=t2, t3=t3, opt=opt)

    b = np.concatenate(out_b) if out_b else np.zeros(0, np.int64)
    p = np.concatenate(out_p) if out_p else np.zeros(0, np.int64)
    return PrefetchStream("vldp", b, p, metadata_bytes=0)


@register_prefetcher(
    "bingo",
    trains_on="l2_access",
    storage="on-chip footprint history table",
    family="spatial",
)
def bingo(workload) -> PrefetchStream:
    """Bingo [6]: per-region footprint replay, 2KB regions, degree<=32.

    The trigger is the first miss in a region per epoch; the prediction is
    the footprint (set of blocks) the region exhibited in the previous
    epoch. 119KB on-chip history => no off-chip metadata."""
    REGION = 32  # blocks per 2KB region
    pos, blocks, _, epochs = workload.l2_stream()
    miss_mask = ~workload.nl_outcome.demand_l2_hit
    out_b, out_p = [], []
    prev_fp: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
    for e in np.unique(epochs):
        s = epochs == e
        blk_e, pos_e, miss_e = blocks[s], pos[s], miss_mask[s]
        region = blk_e // REGION
        # Footprint = blocks touched within one generation (a window after
        # the region's first access), per Bingo's trigger->eviction history,
        # NOT the whole epoch's region traffic.
        if len(blk_e):
            order_r = np.argsort(region, kind="stable")
            rr, pp, bb = region[order_r], pos_e[order_r], blk_e[order_r]
            starts = np.ones(len(rr), dtype=bool)
            starts[1:] = rr[1:] != rr[:-1]
            start_idx = np.flatnonzero(starts)
            counts = np.diff(np.append(start_idx, len(rr)))
            region_first = np.repeat(pp[start_idx], counts)
            in_gen = pp <= region_first + 1500
            fp_keys = np.unique(
                rr[in_gen] * np.int64(1 << 26) + bb[in_gen]
            )
        else:
            fp_keys = np.zeros(0, np.int64)
        fp_region = fp_keys >> 26
        fp_block = fp_keys & ((1 << 26) - 1)
        if prev_fp is not None and len(blk_e):
            pr, pb, p_off = prev_fp
            # a region "generation" restarts once its blocks age out of L2;
            # the first miss of each generation triggers footprint replay
            mi = np.flatnonzero(miss_e)
            if len(mi):
                r_mi = region[mi]
                first_mask = _window_dedupe(r_mi, pos_e[mi], window=1500)
                trig = mi[first_mask]
                t_region = region[trig]
                lo = np.searchsorted(pr, t_region, side="left")
                hi = np.searchsorted(pr, t_region, side="right")
                counts = np.minimum(hi - lo, 32)
                tot = int(counts.sum())
                if tot:
                    starts = np.zeros(len(counts), dtype=np.int64)
                    np.cumsum(counts[:-1], out=starts[1:])
                    idx = np.repeat(lo, counts) + (
                        np.arange(tot) - np.repeat(starts, counts)
                    )
                    out_b.append(pb[idx])
                    out_p.append(np.repeat(pos_e[trig], counts))
        prev_fp = (fp_region, fp_block, None)
    b = np.concatenate(out_b) if out_b else np.zeros(0, np.int64)
    p = np.concatenate(out_p) if out_p else np.zeros(0, np.int64)
    return PrefetchStream("bingo", b, p, metadata_bytes=0)
