"""Baseline prefetchers evaluated against AMC (paper Table I / §VII).

All are L2 prefetchers trained on the L2 access stream (= L1 misses), as in
the paper ("trained on L1 data cache access/miss and assigned as L2
prefetcher"), except RnR which trains on L2 misses at L2. PC localization
uses the accessing array id — exactly the paper's Table II model, where PCs
A/B/C map to the V/N/P arrays.

Online learning is modeled *epoch-causally*: epoch k's predictions use
tables trained on epochs < k (spatial prefetchers additionally warm up
within-epoch). This slightly favors the baselines (instant table
convergence), which is conservative for AMC's relative claims.
"""
from repro.core.prefetchers.simple import nextline_extra, droplet_model, ideal_l2
from repro.core.prefetchers.temporal import isb, misb, domino
from repro.core.prefetchers.spatial import vldp, bingo
from repro.core.prefetchers.rnr import rnr

SUITE = {
    "vldp": vldp,
    "bingo": bingo,
    "isb": isb,
    "misb": misb,
    "rnr": rnr,
    "domino": domino,
    "prodigy": droplet_model,
}

__all__ = [
    "nextline_extra",
    "droplet_model",
    "ideal_l2",
    "isb",
    "misb",
    "domino",
    "vldp",
    "bingo",
    "rnr",
    "SUITE",
]
