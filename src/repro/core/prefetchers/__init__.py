"""Baseline prefetchers evaluated against AMC (paper Table I / §VII).

All are L2 prefetchers trained per their declared ``trains_on`` stream —
the spatial prefetchers (VLDP, Bingo) on the L2 access stream (= L1
misses), the temporal ones (ISB, MISB, Domino) and RnR on L2 misses — as in
the paper ("trained on L1 data cache access/miss and assigned as L2
prefetcher"). PC localization uses the accessing array id — exactly the
paper's Table II model, where PCs A/B/C map to the V/N/P arrays.

Online learning is modeled *epoch-causally*: epoch k's predictions use
tables trained on epochs < k (spatial prefetchers additionally warm up
within-epoch). This slightly favors the baselines (instant table
convergence), which is conservative for AMC's relative claims.

Registry
--------
Every prefetcher self-registers at definition site via
``@register_prefetcher(name, trains_on=..., ...)``
(:mod:`repro.core.registry`), which carries its training stream, storage
budget, family, and composite policy as a declarative
:class:`~repro.core.registry.PrefetcherSpec`.  Resolve by name::

    from repro.core.registry import get_prefetcher
    gen = get_prefetcher("vldp").instantiate()          # baselines
    gen = get_prefetcher("amc").instantiate(lookahead_accesses=30)  # configurable

The PR-1 deprecation shims (``SUITE``, ``repro.core.run_prefetcher_suite``)
have been removed per their stated policy — no in-repo caller or test
depends on them anymore.  Resolve prefetchers by name through the registry
and score through :class:`repro.core.Experiment` or
:func:`repro.core.experiment.score_prefetcher`.
"""
from repro.core.prefetchers.simple import nextline_extra, droplet_model, ideal_l2
from repro.core.prefetchers.temporal import isb, misb, domino
from repro.core.prefetchers.spatial import vldp, bingo
from repro.core.prefetchers.rnr import rnr

# Registers "amc" (the modules above register the seven baselines + extras).
import repro.core.amc.prefetcher  # noqa: F401

# The seven Table I baselines, in the paper's presentation order.
BASELINE_NAMES = ("vldp", "bingo", "isb", "misb", "rnr", "domino", "prodigy")


__all__ = [
    "nextline_extra",
    "droplet_model",
    "ideal_l2",
    "isb",
    "misb",
    "domino",
    "vldp",
    "bingo",
    "rnr",
    "BASELINE_NAMES",
]
