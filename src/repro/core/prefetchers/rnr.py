"""RnR [68]: software-assisted record-and-replay (record ONCE, replay forever).

RnR records the L2 miss sequence of the software-marked irregular structures
during the initial iteration and replays that exact sequence in every later
iteration, paced by a window counter. It has no re-recording — which is
precisely what breaks on evolving graphs (the paper's motivation for AMC).

Model: record epoch 0's miss stream per within-epoch iteration; in every
later epoch replay it, interpolating replay positions across the matching
iteration's span (window-count pacing) with the RnR buffer lead. Drift
between the recorded pattern and the changed iteration's actual needs shows
up as useless/early prefetches, exactly as in the paper (1.7% coverage on
PGD-class dynamics, competitive on near-static BellmanFord).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.amc.prefetcher import PrefetchStream
from repro.core.registry import register_prefetcher


@register_prefetcher(
    "rnr",
    trains_on="l2_miss",
    storage="off-chip recorded miss sequence (record once)",
    family="replay",
)
def rnr(workload) -> PrefetchStream:
    views = workload.amc_iteration_views()
    lead = 2 * workload.profile.cfg.pf_fill_window
    recorded: Dict[int, np.ndarray] = {}
    out_b, out_p = [], []
    meta = 0
    for view, epoch in views:
        if epoch == 0:
            # record-once phase (software replay-timing control, §Table I)
            recorded[view.within_epoch] = view.miss_blocks
            meta += len(view.miss_blocks) * 6  # 46-bit offsets stored off-chip
            continue
        rec = recorded.get(view.within_epoch)
        if rec is None or len(rec) == 0 or len(view.target_pos) == 0:
            continue
        span_lo = int(view.target_pos[0])
        span_hi = int(view.target_pos[-1]) + 1
        L = len(rec)
        # window-count pacing across the iteration's span
        replay_pos = span_lo + (np.arange(L, dtype=np.int64) * max(span_hi - span_lo, 1)) // L
        out_b.append(rec)
        out_p.append(np.maximum(replay_pos - lead, 0))
        meta += L * 6
    b = np.concatenate(out_b) if out_b else np.zeros(0, np.int64)
    p = np.concatenate(out_p) if out_p else np.zeros(0, np.int64)
    return PrefetchStream("rnr", b, p, metadata_bytes=meta)
