"""Temporal baselines: ISB, MISB (PC-localized), Domino (pair-correlated).

Predictions are *epoch-causal*: epoch k uses streams recorded in epoch k-1.
A high-water-mark dedupe models the hardware stream pointer: while the
pattern is followed, each trigger issues only the not-yet-issued tail of its
degree window (otherwise temporal prefetchers would re-issue the whole
window on every trigger).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.amc.prefetcher import PrefetchStream
from repro.core.registry import register_prefetcher


def _first_occurrence_index(stream: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """sorted unique blocks + index of their first occurrence in stream."""
    uniq, first = np.unique(stream, return_index=True)
    return uniq, first


def _issue_with_hwm(trig_idx: np.ndarray, degree: int, stream_len: int):
    """Per-trigger issue ranges [lo, hi] with a cummax high-water mark."""
    hi = np.minimum(trig_idx + degree, stream_len - 1)
    hwm = np.concatenate([[-1], np.maximum.accumulate(hi)[:-1]])
    lo = np.maximum(trig_idx + 1, hwm + 1)
    counts = np.maximum(hi - lo + 1, 0)
    return lo, counts


def _expand(lo: np.ndarray, counts: np.ndarray) -> np.ndarray:
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.zeros(len(counts), dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    return np.repeat(lo, counts) + (
        np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
    )


def _pc_groups(blk: np.ndarray, pos: np.ndarray, pcs: np.ndarray):
    """Per-PC substreams via one stable argsort group-by: yields
    ``(pc, stream, spos)`` in ascending-PC order, stream order preserved
    within each PC.  Replaces the O(PCs x N) per-PC boolean masks."""
    order = np.argsort(pcs, kind="stable")
    pc_s = pcs[order]
    starts = np.flatnonzero(np.diff(pc_s, prepend=pc_s[:1] - 1))
    bounds = np.append(starts, len(pc_s))
    blk_s, pos_s = blk[order], pos[order]
    for i, g0 in enumerate(starts):
        g1 = bounds[i + 1]
        yield int(pc_s[g0]), blk_s[g0:g1], pos_s[g0:g1]


def _temporal_stream(workload, degree: int, localize_pc: bool, train_once: bool):
    """Shared ISB/MISB machinery. Returns pf arrays + op counts.

    ``train_once=True`` models ISB/MISB's append-only structural address
    space: first-touch assignment in the initial epoch is never remapped
    (the paper: "inability to delete useless metadata"), so predictions in
    later epochs replay initial-epoch successor chains — the mechanism that
    breaks on evolving graphs.

    Every PC's structural space is assigned in the first epoch (a PC with
    no first-epoch misses gets an empty stream), so under ``train_once``
    only first-epoch streams are ever trained and ``prev`` stays frozen —
    exactly the dict-carrying semantics of the original per-(epoch, PC)
    mask implementation, now via one group-by sort per epoch.
    """
    pos, blocks, pcs, epochs = workload.l2_stream()
    miss = ~workload.nl_outcome.demand_l2_hit  # trigger & train on L2 misses
    mpos, mblk, mpc, mep = pos[miss], blocks[miss], pcs[miss], epochs[miss]

    out_b, out_p = [], []
    n_lookups = 0
    n_train = 0
    # The miss stream is position-sorted and epoch ids are nondecreasing
    # along the trace, so epochs are contiguous runs: slice by boundaries.
    uniq_eps = np.unique(mep)
    e_bounds = np.searchsorted(mep, uniq_eps)
    e_bounds = np.append(e_bounds, len(mep))
    # previous epoch's per-pc streams (frozen first-epoch ones if train_once)
    prev: Dict[int, tuple] = {}
    empty = (np.zeros(0, mblk.dtype), np.zeros(0, mpos.dtype))
    for ei in range(len(uniq_eps)):
        e0, e1 = e_bounds[ei], e_bounds[ei + 1]
        blk_e, pos_e = mblk[e0:e1], mpos[e0:e1]
        if localize_pc:
            groups = _pc_groups(blk_e, pos_e, mpc[e0:e1])
        else:
            groups = [(0, blk_e, pos_e)]
        first_epoch = ei == 0
        cur: Dict[int, tuple] = dict(prev) if train_once and not first_epoch else {}
        for pc, stream, spos in groups:
            if not (train_once and not first_epoch):
                cur[pc] = (stream, spos)
                n_train += len(stream)
            if first_epoch:
                continue
            tstream, _ = prev.get(pc, empty)
            if len(tstream) < 2 or len(stream) == 0:
                continue
            uniq, first = _first_occurrence_index(tstream)
            li = np.searchsorted(uniq, stream)
            ok = (li < len(uniq)) & (uniq[np.minimum(li, len(uniq) - 1)] == stream)
            n_lookups += len(stream)
            tidx = first[np.minimum(li, len(uniq) - 1)]
            tidx = tidx[ok]
            tpos = spos[ok]
            if len(tidx) == 0:
                continue
            lo, counts = _issue_with_hwm(tidx, degree, len(tstream))
            sidx = _expand(lo, counts)
            out_b.append(tstream[sidx])
            out_p.append(np.repeat(tpos, counts))
        prev = cur
    blocks_out = np.concatenate(out_b) if out_b else np.zeros(0, np.int64)
    pos_out = np.concatenate(out_p) if out_p else np.zeros(0, np.int64)
    return blocks_out, pos_out, n_train, n_lookups


@register_prefetcher(
    "isb",
    trains_on="l2_miss",
    storage="off-chip PS/SP maps, TLB-synced 64B transfers",
    family="temporal",
)
def isb(workload) -> PrefetchStream:
    """ISB [23]: PC-localized structural temporal streams, degree 32.

    Metadata: PS & SP mappings (8B each) touched on every training update
    and lookup; ISB's TLB-sync forces full-line (64B) off-chip metadata
    transfers per lookup — the paper measures ~5x demand traffic."""
    b, p, n_train, n_lookups = _temporal_stream(
        workload, degree=32, localize_pc=True, train_once=True
    )
    meta = n_train * 16 + n_lookups * 64 + len(b) * 8
    return PrefetchStream("isb", b, p, metadata_bytes=meta)


@register_prefetcher(
    "misb",
    trains_on="l2_miss",
    storage="off-chip 8B mappings + on-chip bloom filter",
    family="temporal",
)
def misb(workload) -> PrefetchStream:
    """MISB [67]: same correlations, metadata managed with 8B mappings +
    bloom filter (most useless lookups filtered on-chip)."""
    b, p, n_train, n_lookups = _temporal_stream(
        workload, degree=32, localize_pc=True, train_once=True
    )
    meta = n_train * 8 + int(n_lookups * 0.25) * 8 + len(b)
    return PrefetchStream("misb", b, p, metadata_bytes=meta)


@register_prefetcher(
    "domino",
    trains_on="l2_miss",
    storage="off-chip miss-pair history",
    family="temporal",
)
def domino(workload) -> PrefetchStream:
    """Domino [5]: global miss-pair -> next-miss stream, degree 4."""
    pos, blocks, _, epochs = workload.l2_stream()
    miss = ~workload.nl_outcome.demand_l2_hit
    mpos, mblk, mep = pos[miss], blocks[miss], epochs[miss]
    out_b, out_p = [], []
    n_train = 0
    prev = None
    for e in np.unique(mep):
        s = mep == e
        stream, spos = mblk[s], mpos[s]
        n_train += len(stream)
        if prev is not None and len(prev) > 2 and len(stream) > 1:
            tstream = prev
            # pair keys of the trained stream
            pair = (tstream[:-1].astype(np.int64) << np.int64(25)) ^ tstream[1:]
            order = np.argsort(pair, kind="stable")
            psort = pair[order]
            cur_pair = (stream[:-1].astype(np.int64) << np.int64(25)) ^ stream[1:]
            li = np.searchsorted(psort, cur_pair)
            ok = (li < len(psort)) & (psort[np.minimum(li, len(psort) - 1)] == cur_pair)
            tidx = order[np.minimum(li, len(psort) - 1)] + 1  # index of 2nd elem
            tidx, tpos = tidx[ok], spos[1:][ok]
            if len(tidx):
                lo, counts = _issue_with_hwm(tidx, 4, len(tstream))
                sidx = _expand(lo, counts)
                out_b.append(tstream[sidx])
                out_p.append(np.repeat(tpos, counts))
        prev = stream
    b = np.concatenate(out_b) if out_b else np.zeros(0, np.int64)
    p = np.concatenate(out_p) if out_p else np.zeros(0, np.int64)
    return PrefetchStream("domino", b, p, metadata_bytes=n_train * 12)
