"""The paper's primary contribution: the AMC prefetcher system.

Public API
----------
  Experiment / ExperimentResult -- declarative (kernel x dataset x
                  prefetcher) evaluation grid with workload caching
  WorkloadSpec / build_workload -- declarative workload construction
                  (Algorithm-1 AMC session wiring included)
  registry      -- ``@register_prefetcher`` + ``get_prefetcher``: every
                  evaluated prefetcher (AMC and the seven Table I
                  baselines) is resolvable by name

Subpackages:
  amc          -- Access-to-Miss Correlation prefetcher (recording, BaseΔ
                  compression, AMC Cache model, programming interface)
  prefetchers  -- the evaluated baselines (next-line, VLDP, ISB, MISB,
                  Bingo, RnR, Domino, DROPLET/Prodigy model)
  driver       -- the composite-run workload driver tying apps, traces,
                  memsim and prefetchers together
  experiment   -- the Experiment builder and per-stream scoring
  exec         -- parallel execution engine: process-pool grid scheduler,
                  content-addressed workload artifact cache, stage timers
                  (``Experiment(...).run(workers=N)`` opts in)
  obs          -- structured run telemetry: cross-process span tracing,
                  the metrics registry, and run manifests (see
                  docs/OBSERVABILITY.md)

The PR-1 deprecation shims (``run_prefetcher_suite``,
``repro.core.prefetchers.SUITE``) have been removed per their stated
policy; resolve prefetchers through the registry and score through
``Experiment`` / ``score_prefetcher``.
"""
from repro.core.driver import (
    WorkloadSpec,
    WorkloadTrace,
    build_workload,
)
from repro.core.exec.artifacts import ArtifactCache
from repro.core.obs import MetricsRegistry, RunTrace, Span, Tracer, trace
from repro.core.experiment import (
    CellResult,
    Experiment,
    ExperimentResult,
    WorkloadCache,
    score_prefetcher,
    score_prefetchers_batched,
)
from repro.core.registry import (
    Prefetcher,
    PrefetcherSpec,
    get_prefetcher,
    list_prefetchers,
    register_prefetcher,
)

__all__ = [
    "ArtifactCache",
    "MetricsRegistry",
    "RunTrace",
    "Span",
    "Tracer",
    "trace",
    "WorkloadSpec",
    "WorkloadTrace",
    "build_workload",
    "CellResult",
    "Experiment",
    "ExperimentResult",
    "WorkloadCache",
    "score_prefetcher",
    "score_prefetchers_batched",
    "Prefetcher",
    "PrefetcherSpec",
    "get_prefetcher",
    "list_prefetchers",
    "register_prefetcher",
]
