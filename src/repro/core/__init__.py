"""The paper's primary contribution: the AMC prefetcher system.

Subpackages:
  amc          -- Access-to-Miss Correlation prefetcher (recording, BaseΔ
                  compression, AMC Cache model, programming interface)
  prefetchers  -- the evaluated baselines (next-line, VLDP, ISB, MISB,
                  Bingo, RnR, Domino, DROPLET/Prodigy model)
  driver       -- the composite-run workload driver tying apps, traces,
                  memsim and prefetchers together
"""
from repro.core.driver import WorkloadTrace, build_workload, run_prefetcher_suite

__all__ = ["WorkloadTrace", "build_workload", "run_prefetcher_suite"]
