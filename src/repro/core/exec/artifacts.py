"""Content-addressed on-disk cache of built workload traces.

Building a :class:`~repro.core.driver.WorkloadTrace` (app run -> access
trace -> demand simulation -> next-line baseline outcome) dominates the
cost of an evaluation grid and is fully determined by the
:class:`~repro.core.driver.WorkloadSpec`.  This cache persists every built
component as one compressed ``.npz`` so repeat sweeps, ablations and CI
reruns skip the rebuild entirely, and so parallel workers can share one
build per workload.

Properties:

- **Content-addressed.**  The filename embeds a SHA-256 digest of the
  canonical spec JSON plus :data:`repro.core.driver.TRACE_CODE_VERSION`
  and the artifact schema version.  Changing any spec field, bumping the
  trace-code version, or changing the artifact layout all move the key —
  stale artifacts are never read, merely orphaned.
- **Bit-identical round trip.**  Arrays are stored losslessly; derived
  pieces (the L2 substream views, the AMC session) are reconstructed by
  the same code paths the builder uses, so metrics computed from a loaded
  trace equal those from a fresh build exactly (asserted in
  ``tests/test_exec.py``).
- **Concurrency-safe.**  Writes go to a temp file in the cache directory
  followed by an atomic ``os.replace``; unreadable or truncated artifacts
  read as cache misses and are rebuilt.

Location: ``$REPRO_WORKLOAD_CACHE`` if set, else
``~/.cache/repro-amc/workloads``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import zipfile
from pathlib import Path
from typing import Optional

import numpy as np

import repro.core.driver as _driver
from repro.apps.registry import kernel_traits
from repro.apps.trace import TraceConfig
from repro.core.driver import WorkloadSpec, WorkloadTrace, make_session
from repro.memsim.hierarchy import DemandProfile, PrefetchOutcome

ENV_VAR = "REPRO_WORKLOAD_CACHE"

# Layout version of the .npz payload itself (folded into the content hash
# alongside TRACE_CODE_VERSION, and double-checked on load).
ARTIFACT_SCHEMA = 1

# PrefetchOutcome array fields, stored under an ``o_`` prefix.
_OUTCOME_ARRAYS = (
    "pf_pos",
    "pf_issuer",
    "pf_redundant",
    "pf_no_future",
    "pf_llc_in_dram",
    "pf_llc_in_pos",
    "demand_l2_hit",
    "demand_useful",
    "demand_late",
    "demand_fill_issuer",
    "demand_llc_hit",
    "pf_early",
)


def default_cache_dir() -> Path:
    """Artifact root: ``$REPRO_WORKLOAD_CACHE`` or the user cache dir."""
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-amc" / "workloads"


class ArtifactCache:
    """Persist/load :class:`WorkloadTrace` artifacts under one root dir."""

    def __init__(self, root: Optional[os.PathLike] = None):
        self.root = Path(root) if root is not None else default_cache_dir()
        self.loads = 0
        self.saves = 0
        self.misses = 0

    def key(self, spec: WorkloadSpec) -> str:
        """Canonical identity document hashed into the artifact filename.

        Any frozen spec dataclass with the ``WorkloadSpec`` field surface
        works (the stream protocol's ``StreamEpochSpec`` ships extra
        fields — churn model, epoch index — which land in the hash).  For
        non-``WorkloadSpec`` types the class names are folded in too, so
        two spec types can never collide on identical field dicts, while
        plain ``WorkloadSpec`` keys stay byte-stable across this change.

        The kernel's traversal-direction mode (from its
        :class:`~repro.apps.registry.KernelSpec`) is folded in for
        non-push kernels: a registry change that re-points a kernel name
        at a different direction moves its artifacts instead of serving a
        stale traversal pattern.  Push kernels (every pre-registry
        kernel) keep byte-stable keys.

        **Content-keyed specs.**  A spec exposing a ``content_key()``
        method (the stream protocol's ``StreamEpochSpec``) is keyed on
        what its trace is *determined by* — the per-epoch graph content
        hash, root, and trace config — instead of on how it was declared.
        Epochs whose graph the churn model left unchanged, and identical
        epochs declared through different stream parameters, then share
        one artifact: delta-aware trace reuse falls out of the cache key.
        The schema and trace-code versions still wrap the content
        document, so code changes move these keys like any other.
        """
        content = getattr(spec, "content_key", None)
        if callable(content):
            doc = {
                "artifact_schema": ARTIFACT_SCHEMA,
                "trace_code_version": _driver.TRACE_CODE_VERSION,
                "content": content(),
            }
            return json.dumps(doc, sort_keys=True)
        doc = {
            "artifact_schema": ARTIFACT_SCHEMA,
            "trace_code_version": _driver.TRACE_CODE_VERSION,
            "spec": dataclasses.asdict(spec),
        }
        direction = kernel_traits(spec.kernel).direction
        if direction != "push":
            doc["direction"] = direction
        if type(spec) is not WorkloadSpec:
            doc["spec_type"] = type(spec).__name__
            churn = getattr(spec, "churn", None)
            if churn is not None:
                doc["churn_kind"] = type(churn).__name__
        return json.dumps(doc, sort_keys=True)

    def path_for(self, spec: WorkloadSpec) -> Path:
        if getattr(spec, "is_sharded", False):
            return self.manifest_path(spec)
        digest = hashlib.sha256(self.key(spec).encode()).hexdigest()[:20]
        if callable(getattr(spec, "content_key", None)):
            # Content-keyed: no epoch tag — epochs with identical graph
            # content must resolve to the *same* file (that sharing is
            # the reuse mechanism), and the digest alone distinguishes
            # the rest.  ``g`` marks the digest as a graph-content hash.
            name = f"{spec.kernel}_{spec.dataset}_s{spec.seed}_g{digest}.npz"
            return self.root / name
        epoch = getattr(spec, "epoch", None)
        tag = f"_e{epoch}" if epoch is not None else ""
        name = f"{spec.kernel}_{spec.dataset}_s{spec.seed}{tag}_{digest}.npz"
        return self.root / name

    def has(self, spec: WorkloadSpec) -> bool:
        """Cheap presence + integrity probe (no array decompression).

        Reads only the zip central directory, which lives at the end of
        the file — so the common corruption (a truncated write from a
        killed process) reads as absent.  Callers that plan work from
        ``has()`` (the grid scheduler splits only materialized workloads)
        therefore won't fan a doomed load out to several workers.

        Sharded specs check the manifest (written last — the commit
        point) plus the presence of every shard file it names.
        """
        if getattr(spec, "is_sharded", False):
            manifest = self.load_manifest(spec)
            if manifest is None:
                return False
            return all(
                self.shard_path(spec, i).exists()
                for i in range(len(manifest["shard_sizes"]))
            )
        try:
            with zipfile.ZipFile(self.path_for(spec)) as z:
                return "meta.npy" in z.namelist()  # np.savez appends .npy
        except (OSError, zipfile.BadZipFile):
            return False

    # ---------------------------------------------- measured-cost sidecar
    #
    # Workers and the serial runner record *measured* build/score seconds
    # next to each artifact; the scheduler's cost model prefers these over
    # its static per-access constants (see ``scheduler.estimate_cost``).
    # The sidecar shares the artifact's content digest, so anything that
    # moves the artifact key (spec change, TRACE_CODE_VERSION bump)
    # orphans the stale timings with it.

    def cost_path(self, spec) -> Path:
        return self.path_for(spec).with_suffix(".cost.json")

    def load_cost(self, spec) -> Optional[dict]:
        """Measured timings for ``spec``: ``{"build_s": float,
        "score_s_per_prefetcher": float}`` (either key may be absent), or
        None when nothing was recorded (unreadable == absent)."""
        try:
            with open(self.cost_path(spec)) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return None
        return doc if isinstance(doc, dict) else None

    def record_cost(self, spec, **seconds: float) -> None:
        """Merge measured timing fields into ``spec``'s cost sidecar.

        Latest measurement wins per field; writes are atomic and failures
        are swallowed — a missing sidecar only costs the scheduler its
        constant-based fallback estimate.
        """
        doc = self.load_cost(spec) or {}
        doc.update({k: float(v) for k, v in seconds.items()})
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(doc, f, sort_keys=True)
                os.replace(tmp, self.cost_path(spec))
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except OSError:
            pass

    # ---------------------------------------------- sharded trace store
    #
    # A paper-scale trace is stored as fixed-size shard files plus one
    # JSON manifest.  Shard ``i`` is keyed on sha256(key(spec) + "#shard"
    # + i) — the spec identity plus the shard index, so a shard-size or
    # spec change moves every file.  The manifest (keyed on the spec
    # alone) is written *after* all shards: its presence commits the
    # build, and a build killed mid-way reads as absent.

    def _shard_digest(self, spec, index: Optional[int] = None) -> str:
        doc = self.key(spec)
        if index is not None:
            doc = f"{doc}#shard{index}"
        return hashlib.sha256(doc.encode()).hexdigest()[:20]

    def manifest_path(self, spec) -> Path:
        name = (
            f"{spec.kernel}_{spec.dataset}_s{spec.seed}"
            f"_{self._shard_digest(spec)}.manifest.json"
        )
        return self.root / name

    def shard_path(self, spec, index: int) -> Path:
        name = (
            f"{spec.kernel}_{spec.dataset}_s{spec.seed}"
            f"_k{index}_{self._shard_digest(spec, index)}.npz"
        )
        return self.root / name

    def load_manifest(self, spec) -> Optional[dict]:
        try:
            with open(self.manifest_path(spec)) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            return None
        if manifest.get("schema") != ARTIFACT_SCHEMA:
            return None
        return manifest

    def save_manifest(self, spec, manifest: dict) -> Path:
        path = self.manifest_path(spec)
        self.root.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": ARTIFACT_SCHEMA, **manifest}, f, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    def save_shard(self, spec, index: int, arrays: dict) -> Path:
        path = self.shard_path(spec, index)
        self.root.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez_compressed(f, **arrays)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.saves += 1
        return path

    def load_shard(self, spec, index: int) -> dict:
        with np.load(self.shard_path(spec, index), allow_pickle=False) as z:
            return {k: z[k] for k in z.files}

    def load(self, spec: WorkloadSpec) -> Optional[WorkloadTrace]:
        """The cached trace for ``spec``, or None (unreadable == miss)."""
        from repro.core.obs import spans as obs

        path = self.path_for(spec)
        with obs.span("artifact_load", cache_key=path.name) as sp:
            try:
                with np.load(path, allow_pickle=False) as z:
                    trace = _unpack(spec, z)
            except Exception:
                self.misses += 1
                obs.inc("artifact_cache.misses")
                if sp:
                    sp.attrs["hit"] = False
                return None
            self.loads += 1
            obs.inc("artifact_cache.hits")
            if sp:
                sp.attrs["hit"] = True
            return trace

    def save(self, spec: WorkloadSpec, trace: WorkloadTrace) -> Path:
        """Persist ``trace`` atomically; returns the artifact path."""
        from repro.core.obs import spans as obs

        path = self.path_for(spec)
        self.root.mkdir(parents=True, exist_ok=True)
        with obs.span("artifact_save", cache_key=path.name):
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    np.savez_compressed(f, **_pack(trace))
                os.replace(tmp, path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self.saves += 1
            obs.inc("artifact_cache.saves")
            return path


def _pack(trace: WorkloadTrace) -> dict:
    o = trace.nl_outcome
    meta = {
        "schema": ARTIFACT_SCHEMA,
        "kernel": trace.kernel,
        "dataset": trace.dataset,
        "num_vertices": trace.cfg_trace.num_vertices,
        "num_edges": trace.cfg_trace.num_edges,
        "base": trace.cfg_trace.base,
        "eval_from_pos": trace.eval_from_pos,
        "nl_evicted_early_total": o.evicted_early_total,
        "nl_metadata_bytes": o.metadata_bytes,
    }
    arrays = dict(
        meta=json.dumps(meta, sort_keys=True),
        block=trace.block,
        array_id=trace.array_id,
        epoch_id=trace.epoch_id,
        iter_id=trace.iter_id,
        elem=trace.elem,
        iter_epochs=np.asarray(trace.iter_epochs, dtype=np.int64).reshape(-1, 2),
        l1_hit=trace.profile.l1_hit,
        l2_hit=trace.profile.l2_hit,
        llc_hit=trace.profile.llc_hit,
        nl_blocks=trace.nl_blocks,
        nl_pos=trace.nl_pos,
    )
    for field in _OUTCOME_ARRAYS:
        arrays[f"o_{field}"] = getattr(o, field)
    return arrays


def _unpack(spec: WorkloadSpec, z) -> WorkloadTrace:
    meta = json.loads(str(z["meta"][()]))
    if meta.get("schema") != ARTIFACT_SCHEMA:
        raise ValueError(f"artifact schema {meta.get('schema')!r}")

    block = z["block"]
    iter_id = z["iter_id"]
    l1_hit = z["l1_hit"]
    # The L2 substream is derived exactly as simulate_demand derives it.
    l2_pos = np.flatnonzero(~l1_hit).astype(np.int64)
    profile = DemandProfile(
        blocks=block,
        iter_id=iter_id,
        l1_hit=l1_hit,
        l2_pos=l2_pos,
        l2_blocks=block[l2_pos],
        l2_iter=iter_id[l2_pos],
        l2_hit=z["l2_hit"],
        llc_hit=z["llc_hit"],
        cfg=spec.hierarchy,
    )
    outcome = PrefetchOutcome(
        evicted_early_total=meta["nl_evicted_early_total"],
        metadata_bytes=meta["nl_metadata_bytes"],
        **{field: z[f"o_{field}"] for field in _OUTCOME_ARRAYS},
    )
    cfg_trace = TraceConfig(
        num_vertices=meta["num_vertices"],
        num_edges=meta["num_edges"],
        base=meta["base"],
    )
    return WorkloadTrace(
        spec=spec,
        kernel=meta["kernel"],
        dataset=meta["dataset"],
        cfg_trace=cfg_trace,
        block=block,
        array_id=z["array_id"],
        epoch_id=z["epoch_id"],
        iter_id=iter_id,
        elem=z["elem"],
        iter_epochs=[(int(a), int(b)) for a, b in z["iter_epochs"]],
        profile=profile,
        nl_blocks=z["nl_blocks"],
        nl_pos=z["nl_pos"],
        nl_outcome=outcome,
        eval_from_pos=meta["eval_from_pos"],
        session=make_session(spec, cfg_trace),
    )


__all__ = ["ARTIFACT_SCHEMA", "ArtifactCache", "ENV_VAR", "default_cache_dir"]
