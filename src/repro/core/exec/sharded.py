"""Paper-scale sharded traces with bounded-memory streaming scoring.

A :class:`ShardedSpec` wraps a plain :class:`~repro.core.driver.WorkloadSpec`
and replaces the monolithic :class:`~repro.core.driver.WorkloadTrace` with
fixed-size trace shards in the content-addressed artifact cache (shard ``i``
is keyed on ``sha256(key(spec) + "#shard" + i)``; a JSON manifest written
*last* commits the build).  Scoring then streams the shards through the
carried-state simulators so peak memory is O(shard) in the trace length:

- **Build** (:func:`ensure_shards`): the app runs as usual (the graph is
  resident during emission), but the trace is emitted iteration-group by
  iteration-group (:func:`repro.apps.trace.iter_run_trace_chunks`) and
  re-sliced into exact ``shard_accesses``-sized files — the whole-run
  access stream never exists in memory.
- **Phase 1** (per workload): one sweep over the shards with the carried
  :class:`~repro.memsim.hierarchy.DemandState`, spilling the L2 substream,
  the windowed miss-position streams (for MLP), the baseline-composite
  miss stream and the target-array accesses (for AMC's training views),
  while a :class:`~repro.memsim.streaming.CompositeRunScorer` scores the
  demand + next-line baseline run.
- **Phase 2** (per prefetcher): replay the spilled L2 substream chunk by
  chunk, generate/slice the prefetcher's stream per chunk, and score a
  second :class:`CompositeRunScorer`; the closed-form metrics arithmetic
  mirrors :func:`repro.memsim.metrics.evaluate` term for term.

Working state is proportional to the number of *distinct* blocks touched
(cache tags, the classify carry, the per-block last-miss table) — the
graph footprint — and to one shard, never to the trace length.  The one
documented exception is the generated prefetch stream of table-driven
prefetchers (AMC's issue stream is materialized once, then sliced).

Sharded scoring is bit-identical to the unsharded path — every metric
field, including AMC's ``info`` dict — asserted for all three cache
engines in ``tests/test_sharded.py``.

Streaming adapters exist for ``nextline2`` (O(1) carry) and the ``amc``
family (training views streamed from spills).  Other prefetchers consume
whole-trace substreams by contract and raise :class:`ShardedScoringError`.
"""
from __future__ import annotations

import dataclasses
import tempfile
from pathlib import Path
from typing import ClassVar, Iterator, List, Optional, Tuple

import numpy as np

from repro.apps.registry import kernel_traits
from repro.apps.trace import T_ID, TraceConfig, iter_run_trace_chunks
from repro.core.amc.prefetcher import IterationView
from repro.core.driver import WorkloadSpec, _run_app
from repro.core.exec.artifacts import ArtifactCache
from repro.core.exec.timers import stage
from repro.core.obs import spans as obs
from repro.memsim.config import BLOCK_BITS, HierarchyConfig
from repro.memsim.hierarchy import demand_init_state, simulate_demand
from repro.memsim.metrics import PrefetchMetrics
from repro.memsim.streaming import (
    BlockPosTable,
    CompositeRunScorer,
    SpillFile,
    iter_grouped,
    spilled_mlp,
)
from repro.memsim.timing import TimingModel, avg_miss_cost

DEFAULT_SHARD_ACCESSES = 1 << 22  # 4M accesses/shard (~100MB resident peak)


class ShardedScoringError(RuntimeError):
    """A prefetcher without a streaming adapter met a ShardedSpec."""


# A long run feeds hundreds of chunks whose padded shapes drift through
# many pow2 buckets; without periodic release, per-shape executables and
# freed-but-retained allocator pages creep ~30MB over a 496-shard run
# (measured on bfs/road-8m), breaking the flat-RSS contract this module
# exists to provide.  With the persistent compilation cache enabled,
# re-loading an evicted executable costs milliseconds, so the cadence
# below is not measurable in score time.
_RELEASE_EVERY = 16


def _release_memory() -> None:
    import jax

    jax.clear_caches()
    try:
        import ctypes

        ctypes.CDLL("libc.so.6").malloc_trim(0)
    except (OSError, AttributeError):  # non-glibc: caches alone are freed
        pass


@dataclasses.dataclass(frozen=True)
class ShardedSpec:
    """A workload cell scored through the sharded streaming path.

    Wraps the plain spec (which fully determines the trace) plus the shard
    size.  Duck-typed via ``is_sharded`` the same way stream/serve specs
    are — :class:`~repro.core.experiment.Experiment` and the grid
    scheduler branch on the flag, and the artifact cache keys shards on
    the full (base + shard_accesses) identity.
    """

    base: WorkloadSpec
    shard_accesses: int = DEFAULT_SHARD_ACCESSES

    is_sharded: ClassVar[bool] = True

    def __post_init__(self):
        if self.shard_accesses < 1:
            raise ValueError("shard_accesses must be >= 1")

    @property
    def kernel(self) -> str:
        return self.base.kernel

    @property
    def dataset(self) -> str:
        return self.base.dataset

    @property
    def seed(self) -> int:
        return self.base.seed

    @property
    def hierarchy(self) -> HierarchyConfig:
        return self.base.hierarchy

    def validate_names(self) -> None:
        self.base.validate_names()


class _ShardWriter:
    """Re-slices pushed trace chunks into exact fixed-size shard files."""

    def __init__(self, cache: ArtifactCache, spec: ShardedSpec):
        self.cache = cache
        self.spec = spec
        self.cap = spec.shard_accesses
        self.buf: List[Tuple[np.ndarray, ...]] = []
        self.buffered = 0
        self.total = 0
        self.sizes: List[int] = []

    def push(self, block, array_id, iter_id, elem) -> None:
        self.buf.append((block, array_id, iter_id, elem))
        self.buffered += len(block)
        self.total += len(block)
        while self.buffered >= self.cap:
            self._flush(self.cap)

    def _flush(self, n: int) -> None:
        cols = [np.concatenate([part[j] for part in self.buf]) for j in range(4)]
        self.cache.save_shard(
            self.spec,
            len(self.sizes),
            dict(
                block=cols[0][:n],
                array_id=cols[1][:n],
                iter_id=cols[2][:n],
                elem=cols[3][:n],
            ),
        )
        self.sizes.append(n)
        self.buf = [tuple(c[n:] for c in cols)]
        self.buffered -= n

    def finish(self) -> List[int]:
        if self.buffered:
            self._flush(self.buffered)
        return self.sizes


def ensure_shards(spec: ShardedSpec, cache: ArtifactCache) -> dict:
    """Build (or load) the shard store for ``spec``; returns the manifest.

    Mirrors ``_build_workload``'s protocol decisions exactly — epoch
    structure, shared address layout across runs, the two-run evaluation
    window — but the window start is computed from per-run access offsets
    (``searchsorted(iter_id, second_run_first_iter)`` equals run 1's total
    length because ``iter_id`` is nondecreasing), so no whole-trace array
    is ever needed.
    """
    if cache.has(spec):
        manifest = cache.load_manifest(spec)
        if manifest is not None:
            return manifest
    spec.validate_names()
    ks = kernel_traits(spec.kernel)
    with obs.span(
        "ensure_shards",
        kernel=spec.kernel,
        dataset=spec.dataset,
        shard_accesses=spec.shard_accesses,
    ), stage("trace_gen"):
        runs = _run_app(spec.kernel, spec.dataset, spec.seed)
        g = runs[0].graph
        cfg_trace = TraceConfig(
            num_vertices=g.num_vertices,
            num_edges=max(r.graph.num_edges for r in runs),
        )
        iter_epochs: List[Tuple[int, int]] = []
        run_start_iter: List[int] = []
        git = 0
        for run_idx, run in enumerate(runs):
            run_start_iter.append(git)
            for k in range(len(run.frontiers)):
                iter_epochs.append((run_idx, k) if ks.two_run else (git, 0))
                git += 1
        with stage("trace_emit"):
            writer = _ShardWriter(cache, spec)
            run_access_start: List[int] = []
            for s, run in zip(run_start_iter, runs):
                run_access_start.append(writer.total)
                for i0, rt in iter_run_trace_chunks(
                    run, cfg_trace, max_accesses=spec.shard_accesses
                ):
                    it_id = np.repeat(
                        np.arange(s + i0, s + i0 + rt.num_iters, dtype=np.int32),
                        rt.iter_sizes,
                    )
                    writer.push(rt.block, rt.array_id, it_id, rt.elem)
            shard_sizes = writer.finish()
    eval_from = 0
    if ks.two_run and len(runs) > 1:
        eval_from = int(run_access_start[1])
    manifest = {
        "kernel": spec.kernel,
        "dataset": spec.dataset,
        "seed": spec.seed,
        "num_accesses": int(writer.total),
        "shard_accesses": int(spec.shard_accesses),
        "shard_sizes": [int(x) for x in shard_sizes],
        "iter_epochs": [[int(a), int(b)] for a, b in iter_epochs],
        "eval_from_pos": eval_from,
        "num_vertices": int(cfg_trace.num_vertices),
        "num_edges": int(cfg_trace.num_edges),
        "base": int(cfg_trace.base),
    }
    cache.save_manifest(spec, manifest)
    return manifest


def _nextline_chunk(b: np.ndarray, p: np.ndarray, carry: Optional[int]):
    """Chunked ``_nextline_stream``: consecutive-duplicate filtering with
    the previous chunk's last L2 block carried across the seam."""
    if len(b) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64), carry
    keep = np.ones(len(b), dtype=bool)
    keep[1:] = b[1:] != b[:-1]
    if carry is not None:
        keep[0] = b[0] != carry
    return b[keep] + 1, p[keep], int(b[-1])


class _ShardedWorkloadView:
    """The two-attribute surface ``AMCPrefetcher.generate`` consumes
    (``input_bytes`` + ``amc_iteration_views()``), with the per-iteration
    training views streamed from phase-1 spills instead of whole-trace
    arrays.  View contents are bit-identical to
    ``WorkloadTrace.amc_iteration_views()`` (same dtypes, same
    target-range filter, empty iterations included)."""

    def __init__(
        self,
        cfg_trace: TraceConfig,
        iter_epochs: List[Tuple[int, int]],
        target_spill: SpillFile,  # (pos, vid, iter)
        miss_spill: SpillFile,  # (pos, block, iter) baseline-composite misses
    ):
        self.cfg_trace = cfg_trace
        self.input_bytes = cfg_trace.input_bytes
        self._iter_epochs = iter_epochs
        self._target = target_spill
        self._miss = miss_spill

    def amc_iteration_views(self):
        t_base, t_size = self.cfg_trace.target_range
        t_lo, t_hi = t_base >> BLOCK_BITS, (t_base + t_size) >> BLOCK_BITS
        n = len(self._iter_epochs)
        tgt_groups = iter_grouped(self._target, 2, n)
        miss_groups = iter_grouped(self._miss, 2, n)
        for (it, (tp, tv, _ti)), (_it, (mp, mb, _mi)) in zip(
            tgt_groups, miss_groups
        ):
            not_target = ~((mb >= t_lo) & (mb <= t_hi))
            epoch, within = self._iter_epochs[it]
            yield (
                IterationView(
                    iteration=it,
                    within_epoch=within,
                    target_pos=tp,
                    target_vid=tv,
                    miss_pos=mp[not_target],
                    miss_blocks=mb[not_target],
                ),
                epoch,
            )


def iter_shard_arrays(
    spec: ShardedSpec, cache: ArtifactCache, manifest: dict
) -> Iterator[dict]:
    for k in range(len(manifest["shard_sizes"])):
        yield cache.load_shard(spec, k)


def score_sharded(
    spec: ShardedSpec,
    prefetchers: List[Tuple[str, object]],
    cache: Optional[ArtifactCache] = None,
    tm: TimingModel = TimingModel(),
) -> List[Tuple[str, PrefetchMetrics]]:
    """Score ``prefetchers`` on ``spec`` with O(shard) peak memory.

    Returns ``(name, metrics)`` pairs in input order, bit-identical to the
    unsharded ``score_prefetcher`` results for the same base spec.
    """
    cache = cache if cache is not None else ArtifactCache()
    manifest = ensure_shards(spec, cache)
    cfg = spec.hierarchy
    t0 = int(manifest["eval_from_pos"])
    num_accesses = int(manifest["num_accesses"])
    iter_epochs = [(int(a), int(b)) for a, b in manifest["iter_epochs"]]
    bounds = np.zeros(len(manifest["shard_sizes"]) + 1, dtype=np.int64)
    np.cumsum(np.asarray(manifest["shard_sizes"], dtype=np.int64), out=bounds[1:])
    cfg_trace = TraceConfig(
        num_vertices=manifest["num_vertices"],
        num_edges=manifest["num_edges"],
        base=manifest["base"],
    )
    results: List[Tuple[str, PrefetchMetrics]] = []
    with stage("score"), tempfile.TemporaryDirectory(
        prefix="repro-sharded-"
    ) as tmp:
        td = Path(tmp)
        # ---- phase 1: one sweep building the baseline + all spills
        l2_spill = SpillFile(td / "l2sub.i64", cols=3)  # pos, block, iter
        l2_rows: List[int] = []
        mp_spill = SpillFile(td / "base.mp.i64", cols=1)  # windowed, demand-only
        dp_spill = SpillFile(td / "base.dp.i64", cols=1)
        bl_miss = SpillFile(td / "blmiss.i64", cols=3)  # pos, block, iter
        tgt_spill = SpillFile(td / "target.i64", cols=3)  # pos, vid, iter
        base_sc = CompositeRunScorer(
            cfg, t0, td, "base", sel_issuer=None, miss_sink=bl_miss
        )
        no_future = BlockPosTable()
        dstate = demand_init_state(cfg)
        nl_carry: Optional[int] = None
        l1w = l2w = dramw = 0
        for k, arrays in enumerate(iter_shard_arrays(spec, cache, manifest)):
            blocks = arrays["block"]
            iters = arrays["iter_id"]
            with obs.span("shard_demand", shard=k, accesses=len(blocks)):
                profile, dstate = simulate_demand(
                    blocks, iters, cfg, state=dstate, return_state=True
                )
            obs.inc("sharded.shards_swept")
            d_pos = profile.l2_pos  # global positions (carry offsets them)
            d_blocks = profile.l2_blocks
            d_iter = profile.l2_iter.astype(np.int64)
            l2_spill.append(d_pos, d_blocks, d_iter)
            l2_rows.append(len(d_pos))
            l1w += int((d_pos >= t0).sum())
            dmiss = ~profile.l2_hit
            mp = d_pos[dmiss]
            l2w += int((mp >= t0).sum())
            mp_spill.append(mp[mp >= t0])
            dp = mp[~profile.llc_hit]
            dramw += int((dp >= t0).sum())
            dp_spill.append(dp[dp >= t0])
            no_future.update(d_blocks[dmiss], mp)
            nl_b, nl_p, nl_carry = _nextline_chunk(d_blocks, d_pos, nl_carry)
            base_sc.feed(
                d_pos,
                d_blocks,
                nl_b,
                nl_p,
                np.zeros(len(nl_b), np.int8),
                d_iter=d_iter,
            )
            tmask = arrays["array_id"] == T_ID
            tgt_spill.append(
                np.flatnonzero(tmask).astype(np.int64) + bounds[k],
                arrays["elem"][tmask].astype(np.int64),
                iters[tmask].astype(np.int64),
            )
            if (k + 1) % _RELEASE_EVERY == 0:
                _release_memory()
        base = dict(
            accesses=num_accesses - t0,
            l1_miss=l1w,
            l2_miss=l2w,
            llc_miss=dramw,
            dram=dramw,
        )
        late_cost = avg_miss_cost(
            l2_misses=l2w,
            dram_misses=dramw,
            l2_miss_pos=np.zeros(0, np.int64),
            dram_pos=np.zeros(0, np.int64),
            cfg=cfg,
            tm=tm,
            mlp_llc=spilled_mlp(mp_spill, tm.mlp_window, tm.mlp_cap_llc),
            mlp_dram=spilled_mlp(dp_spill, tm.mlp_window, tm.mlp_cap_dram),
        )
        base_cycles, base_counts = base_sc.finalize(
            base, base["dram"], late_cost, 0, tm
        )
        mp_spill.close()
        dp_spill.close()

        # ---- phase 2: replay the L2 substream once per prefetcher
        for pf_idx, (name, gen) in enumerate(prefetchers):
            obs.inc("sharded.replays")
            x_pos = x_blocks = None
            meta_bytes = 0
            info: dict = {}
            if name == "nextline2":
                pass  # chunk stream derived from the next-line regen below
            elif name == "amc" or name.startswith("amc"):
                shim = _ShardedWorkloadView(
                    cfg_trace, iter_epochs, tgt_spill, bl_miss
                )
                stream = gen(shim)
                meta_bytes = stream.metadata_bytes
                info = stream.info
                # Global stable position sort once, so per-chunk slices
                # reproduce the whole-trace merge's equal-position order.
                xo = np.argsort(stream.pos, kind="stable")
                x_pos = stream.pos[xo].astype(np.int64)
                x_blocks = stream.blocks[xo].astype(np.int64)
            else:
                raise ShardedScoringError(
                    f"prefetcher {name!r} has no streaming adapter "
                    "(available: nextline2, amc*); score it through the "
                    "unsharded WorkloadSpec path"
                )
            sc = CompositeRunScorer(
                cfg, t0, td, f"run{pf_idx}", sel_issuer=1, no_future=no_future
            )
            nl_carry = None
            for k, (d_pos, d_blocks, _di) in enumerate(l2_spill.groups(l2_rows)):
                nl_b, nl_p, nl_carry = _nextline_chunk(d_blocks, d_pos, nl_carry)
                if x_pos is None:  # nextline2: same triggers, +2 lines
                    cx_b, cx_p = nl_b + 1, nl_p
                else:
                    lo, hi = np.searchsorted(x_pos, [bounds[k], bounds[k + 1]])
                    cx_b, cx_p = x_blocks[lo:hi], x_pos[lo:hi]
                sc.feed(
                    d_pos,
                    d_blocks,
                    np.concatenate([nl_b, cx_b]),
                    np.concatenate([nl_p, cx_p]),
                    np.concatenate(
                        [
                            np.zeros(len(nl_b), np.int8),
                            np.ones(len(cx_b), np.int8),
                        ]
                    ),
                )
                if (k + 1) % _RELEASE_EVERY == 0:
                    _release_memory()
            meta_dram = meta_bytes >> BLOCK_BITS
            run_cycles, run_counts = sc.finalize(
                base, base["dram"], late_cost, meta_dram, tm
            )
            results.append(
                (
                    name,
                    _metrics(
                        name,
                        base,
                        base_cycles,
                        base_counts,
                        run_cycles,
                        run_counts,
                        sc,
                        meta_dram,
                        info,
                    ),
                )
            )
        for sp in (l2_spill, bl_miss, tgt_spill):
            sp.close()
    return results


def _metrics(
    name: str,
    base: dict,
    base_cycles: float,
    base_counts: dict,
    run_cycles: float,
    run_counts: dict,
    sc: CompositeRunScorer,
    meta_dram: int,
    info: dict,
) -> PrefetchMetrics:
    """``metrics.evaluate``'s closing arithmetic, from streamed counts."""
    baseline_misses = base_counts["l2_misses"]
    dram_b = base_counts["dram_total"]
    dram_r = run_counts["dram_total"]
    extra = (dram_r - dram_b) / max(dram_b, 1)
    meta = meta_dram / max(dram_b, 1)
    issued_eff = sc.issued - sc.redundant
    return PrefetchMetrics(
        name=name,
        accuracy=sc.useful / max(issued_eff, 1),
        coverage=sc.useful / max(baseline_misses, 1),
        speedup=base_cycles / max(run_cycles, 1e-9),
        ipc_baseline=base["accesses"] / max(base_cycles, 1e-9),
        ipc_prefetch=base["accesses"] / max(run_cycles, 1e-9),
        issued=sc.issued,
        useful=sc.useful,
        late=sc.late_sel,
        evicted_early=sc.early,
        overpredicted=sc.overpred,
        redundant=sc.redundant,
        baseline_l2_misses=baseline_misses,
        extra_traffic=float(extra),
        metadata_traffic=float(meta),
        dram_demand=run_counts["dram_demand"],
        dram_total=dram_r,
        info=info,
    )


__all__ = [
    "DEFAULT_SHARD_ACCESSES",
    "ShardedScoringError",
    "ShardedSpec",
    "ensure_shards",
    "score_sharded",
]
