"""Parallel grid scheduler: shard (workload x prefetcher) cells across a
process pool.

The unit of work is one *task* = (WorkloadSpec, [prefetcher subset]).  Each
worker materializes its task's trace once — an artifact-cache load when
present, else a full build persisted for every later task and run — and
scores the task's prefetchers sequentially against it.  An unmaterialized
workload is always a single task, so its expensive build happens exactly
once, in the worker that scores it; a workload already in the artifact
store loads in seconds, so its prefetcher list is split across sibling
tasks (targeting ~2 tasks per worker, heaviest dispatched first) so one
heavy workload cannot serialize the tail of the run.

Determinism: workers return ``(task_index, [(name, metrics), ...])`` and
the parent reassembles cells in the exact workload-major, prefetcher-minor
order the serial path uses, so parallel output is bit-identical to serial
(asserted in ``tests/test_exec.py`` and gated in CI by ``bench --smoke``).

Workers are *spawned*, not forked: the simulator holds live JAX/XLA thread
pools, and forking a process with running thread pools can deadlock the
child.  Spawned workers re-import the package, so the parent exports the
``repro`` source root on ``PYTHONPATH`` for the pool's lifetime.
"""

from __future__ import annotations

import contextlib
import os
import pickle
from concurrent.futures import ProcessPoolExecutor, as_completed
from multiprocessing import get_context
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

import repro
from repro.core.driver import WorkloadSpec, WorkloadTrace
from repro.core.exec.artifacts import ArtifactCache
from repro.core.experiment import score_prefetcher
from repro.memsim import PrefetchMetrics


# Per-worker-process memo of the last materialized trace: pool processes
# run many tasks, and consecutive tasks for the same workload (a split
# prefetcher list) should not reload the artifact.  One entry bounds memory.
_LAST_TRACE: Optional[Tuple[Tuple[str, WorkloadSpec], WorkloadTrace]] = None


def _materialize(spec: WorkloadSpec, cache_root: str) -> Optional[WorkloadTrace]:
    global _LAST_TRACE
    if getattr(spec, "is_sharded", False):
        # Sharded workloads materialize as a shard store + manifest, not a
        # WorkloadTrace; nothing stays resident in the worker.
        from repro.core.exec import sharded

        sharded.ensure_shards(spec, ArtifactCache(cache_root))
        return None
    key = (cache_root, spec)
    if _LAST_TRACE is not None and _LAST_TRACE[0] == key:
        return _LAST_TRACE[1]
    cache = ArtifactCache(cache_root)
    trace = cache.load(spec)
    if trace is None:
        trace = spec.build()
        cache.save(spec, trace)
    _LAST_TRACE = (key, trace)
    return trace


def _run_task(task) -> Tuple[int, List[Tuple[str, PrefetchMetrics]]]:
    """Worker body: build-or-load one trace, score its prefetchers."""
    import time

    index, spec, prefetchers, cache_root = task
    debug = os.environ.get("REPRO_EXEC_DEBUG")
    if getattr(spec, "is_sharded", False):
        # Sharded tasks stream shards through the bounded-memory scorer;
        # the shard store (cached by content key) is built on first touch.
        from repro.core.exec import sharded

        t0 = time.perf_counter()
        scored = sharded.score_sharded(
            spec, list(prefetchers), ArtifactCache(cache_root)
        )
        if debug:
            print(
                f"[worker {os.getpid()}] {spec.kernel}/{spec.dataset} "
                f"sharded x{len(prefetchers)} {time.perf_counter() - t0:.1f}s",
                flush=True,
            )
        return index, scored
    t0 = time.perf_counter()
    trace = _materialize(spec, cache_root)
    if debug:
        print(
            f"[worker {os.getpid()}] {spec.kernel}/{spec.dataset} "
            f"materialize {time.perf_counter() - t0:.1f}s",
            flush=True,
        )
    scored = []
    for name, gen in prefetchers:
        t0 = time.perf_counter()
        scored.append((name, score_prefetcher(trace, name, gen)))
        if debug:
            print(
                f"[worker {os.getpid()}] {spec.kernel}/{spec.dataset} "
                f"score {name} {time.perf_counter() - t0:.1f}s",
                flush=True,
            )
    return index, scored


def _split(items: Sequence, n: int) -> List[list]:
    """Split into ``n`` (or fewer) contiguous near-equal chunks."""
    n = max(1, min(n, len(items)))
    size, rem = divmod(len(items), n)
    out, i = [], 0
    for j in range(n):
        step = size + (1 if j < rem else 0)
        out.append(list(items[i : i + step]))
        i += step
    return out


def _plan(
    specs: Sequence[WorkloadSpec],
    prefetchers: Sequence[tuple],
    workers: int,
    artifacts: ArtifactCache,
) -> Tuple[List[WorkloadSpec], List[tuple]]:
    """(unique specs, [(spec, prefetcher chunk), ...]) task list.

    An *unmaterialized* workload is one task — its (expensive) build must
    happen exactly once, in the worker that scores it.  A workload already
    in the artifact store loads in seconds, so its prefetcher list may be
    split across sibling tasks for load balance; we aim for ~2 tasks per
    worker so one heavy workload cannot serialize the tail of the run.
    """
    unique = list(dict.fromkeys(specs))
    target_tasks = max(2 * workers, len(unique))
    chunks_per_cached = max(1, -(-target_tasks // len(unique)))  # ceil
    tasks = []
    for spec in unique:
        n_chunks = chunks_per_cached if artifacts.has(spec) else 1
        for chunk in _split(prefetchers, n_chunks):
            tasks.append((spec, chunk))
    return unique, tasks


def _check_picklable(prefetchers: Sequence[tuple]) -> None:
    for name, gen in prefetchers:
        try:
            pickle.dumps(gen)
        except Exception as e:
            raise ValueError(
                f"prefetcher {name!r} is not picklable and cannot be shipped "
                "to worker processes — parallel execution needs module-level "
                "generators or registry factories (lambdas and closures are "
                "not); run serially or register the prefetcher"
            ) from e


def rows_equal(a: List[dict], b: List[dict]) -> bool:
    """Exact equality of two ``ExperimentResult.rows()`` lists.

    The ``info`` entry holds prefetcher-side stats (scalars and numpy
    arrays) and is compared element-wise; every other metric must match
    bit-for-bit.  This is the parallel-vs-serial parity predicate used by
    the engine tests and the CI bench smoke gate.
    """
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        if set(ra) != set(rb):
            return False
        for k in ra:
            va, vb = ra[k], rb[k]
            if k == "info":
                if set(va) != set(vb):
                    return False
                if not all(np.array_equal(va[ik], vb[ik]) for ik in va):
                    return False
            elif va != vb:
                return False
    return True


@contextlib.contextmanager
def _spawn_pool(
    artifacts: ArtifactCache, n_tasks: int, workers: int
) -> Iterator[ProcessPoolExecutor]:
    """A spawned process pool with the engine's worker environment.

    Spawned interpreters re-import the package from scratch, so the parent
    exports: the ``repro`` source root on ``PYTHONPATH``; a persistent JAX
    compilation cache next to the workload artifacts (re-JITting the
    lax.scan cache passes costs seconds per process otherwise — an
    externally-set cache dir wins so a parent that set one shares its
    compiles); and the current cache-engine selection, which may live in
    process-local state the children would never see.  The environment is
    restored when the pool closes.
    """
    # repro may be a namespace package (no __init__), so resolve its
    # directory via __path__ when __file__ is absent.
    if getattr(repro, "__file__", None):
        pkg_dir = os.path.dirname(os.path.abspath(repro.__file__))
    else:
        pkg_dir = os.path.abspath(next(iter(repro.__path__)))
    src_root = os.path.dirname(pkg_dir)
    old_pythonpath = os.environ.get("PYTHONPATH")
    pythonpath = [src_root] + ([old_pythonpath] if old_pythonpath else [])
    jax_cache = os.environ.get(
        "JAX_COMPILATION_CACHE_DIR", str(artifacts.root / "jax-cache")
    )
    from repro.apps.trace import EMITTER_ENV, current_emitter
    from repro.memsim.engine import ENGINE_ENV, current_engine

    # ``workers`` is the requested shard width; the actual pool never
    # exceeds the task count or the core count — extra spawned processes
    # on a saturated host only add import/contention overhead.
    pool_size = max(1, min(workers, n_tasks, os.cpu_count() or workers))
    # Pin each worker's intra-op threadpools to its share of the cores.
    # XLA (and OpenMP/BLAS) size their pools to the *machine*, so P
    # workers x C-thread pools oversubscribe a C-core host P-fold — the
    # BENCH_2026-08-01 regression where workers=4 lost to workers=1.
    threads = max(1, (os.cpu_count() or 1) // pool_size)
    xla_flags = " ".join(
        filter(
            None,
            [
                os.environ.get("XLA_FLAGS"),
                f"--xla_cpu_multi_thread_eigen={'true' if threads > 1 else 'false'}",
                f"intra_op_parallelism_threads={threads}",
            ],
        )
    )
    child_env = {
        "PYTHONPATH": os.pathsep.join(pythonpath),
        "JAX_COMPILATION_CACHE_DIR": jax_cache,
        # Cache even sub-second compiles (the default threshold is 1s).
        "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": os.environ.get(
            "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0"
        ),
        "XLA_FLAGS": xla_flags,
        "OMP_NUM_THREADS": str(threads),
        "OPENBLAS_NUM_THREADS": str(threads),
        "MKL_NUM_THREADS": str(threads),
        ENGINE_ENV: current_engine(),
        # Same story for the trace-emitter selection (set_emitter /
        # use_emitter overrides live in parent process-local state).
        EMITTER_ENV: current_emitter(),
    }
    saved_env = {k: os.environ.get(k) for k in child_env}
    os.environ.update(child_env)
    try:
        ctx = get_context("spawn")
        with ProcessPoolExecutor(max_workers=pool_size, mp_context=ctx) as pool:
            yield pool
    finally:
        for key, value in saved_env.items():
            if value is None:
                os.environ.pop(key, None)
            else:
                os.environ[key] = value


def run_grid(
    specs: Sequence[WorkloadSpec],
    prefetchers: Sequence[Tuple[str, object]],
    *,
    workers: int,
    artifacts: Optional[ArtifactCache] = None,
    verbose: bool = False,
) -> Tuple[Dict[tuple, PrefetchMetrics], Dict[WorkloadSpec, WorkloadTrace]]:
    """Evaluate the (specs x prefetchers) grid across ``workers`` processes.

    Returns ``({(spec, name): metrics}, {spec: trace})``, where the trace
    dict holds parent-side builds (none in the common path — every task's
    trace lands in the artifact store for on-demand loading).  The caller
    owns cell ordering (the metrics mapping is order-free, deterministic).
    """
    artifacts = artifacts if artifacts is not None else ArtifactCache()
    _check_picklable(prefetchers)
    unique, tasks = _plan(specs, prefetchers, workers, artifacts)

    # Longest-task-first dispatch: a heavy task submitted last would
    # serialize the tail of the run.  Artifact size x chunk length is the
    # cost proxy; a cold (unbuilt) workload is the most expensive unit of
    # all, so unknown costs rank first and the build overlaps the warm
    # work.  Execution order never affects results — cells are
    # reassembled by key.
    def _cost(task):
        spec = task[0]
        if getattr(spec, "is_sharded", False):
            # The manifest is tiny; rank by the trace length it describes
            # (8 bytes/access as the size proxy).  Unbuilt stores rank first.
            manifest = artifacts.load_manifest(spec)
            if manifest is None:
                return float("inf")
            return 8.0 * manifest["num_accesses"] * len(task[1])
        try:
            return artifacts.path_for(spec).stat().st_size * len(task[1])
        except OSError:
            return float("inf")

    tasks.sort(key=_cost, reverse=True)

    traces: Dict[WorkloadSpec, WorkloadTrace] = {}
    metrics: Dict[tuple, PrefetchMetrics] = {}
    with _spawn_pool(artifacts, len(tasks), workers) as pool:
        futures = {
            pool.submit(_run_task, (i, spec, chunk, str(artifacts.root))): i
            for i, (spec, chunk) in enumerate(tasks)
        }
        for fut in as_completed(futures):
            index, scored = fut.result()
            spec = tasks[index][0]
            for name, m in scored:
                metrics[(spec, name)] = m
                if verbose:
                    print(
                        f"[{spec.kernel}/{spec.dataset}] {name}: "
                        f"speedup {m.speedup:.2f} coverage {m.coverage:.2f} "
                        f"accuracy {m.accuracy:.2f}"
                    )

    # Workers persisted their traces in the artifact store; the caller
    # loads them from there on demand (``traces`` stays empty unless a
    # future planner gives the parent build work again).
    return metrics, traces


def _materialize_task(task) -> int:
    """Worker body: build-or-load one trace into the artifact store."""
    index, spec, cache_root = task
    _materialize(spec, cache_root)
    return index


def materialize_specs(
    specs: Sequence[WorkloadSpec],
    *,
    workers: int,
    artifacts: Optional[ArtifactCache] = None,
) -> int:
    """Fan workload builds (no scoring) across a spawned pool.

    The build-only counterpart of :func:`run_grid`, used by the streaming
    protocol: epochs of one stream are independent *builds* (each is its
    own task here, so E epochs spread across the pool) but must be
    *scored* sequentially in the parent, where the cross-epoch table
    lifecycle lives.  Already-materialized specs are skipped.  Returns the
    number of traces built.
    """
    artifacts = artifacts if artifacts is not None else ArtifactCache()
    todo = [s for s in dict.fromkeys(specs) if not artifacts.has(s)]
    if not todo:
        return 0
    with _spawn_pool(artifacts, len(todo), workers) as pool:
        futures = [
            pool.submit(_materialize_task, (i, spec, str(artifacts.root)))
            for i, spec in enumerate(todo)
        ]
        for fut in as_completed(futures):
            fut.result()
    return len(todo)


__all__ = ["materialize_specs", "rows_equal", "run_grid"]
