"""Parallel grid scheduler: shard (workload x prefetcher) cells across a
process pool.

The unit of work is one *task* = (WorkloadSpec, [prefetcher subset]).  Each
worker materializes its task's trace once — an artifact-cache load when
present, else a full build persisted for every later task and run — and
scores the task's prefetchers sequentially against it.  An unmaterialized
workload is always a single task, so its expensive build happens exactly
once, in the worker that scores it; a workload already in the artifact
store loads in seconds, so its prefetcher list is split across sibling
tasks (targeting ~2 tasks per worker, heaviest dispatched first) so one
heavy workload cannot serialize the tail of the run.

Determinism: workers return ``(task_index, [(name, metrics), ...])`` and
the parent reassembles cells in the exact workload-major, prefetcher-minor
order the serial path uses, so parallel output is bit-identical to serial
(asserted in ``tests/test_exec.py`` and gated in CI by ``bench --smoke``).

Workers are *spawned*, not forked: the simulator holds live JAX/XLA thread
pools, and forking a process with running thread pools can deadlock the
child.  Spawned workers re-import the package, so the parent exports the
``repro`` source root on ``PYTHONPATH`` for the pool's lifetime.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import pickle
import time
from concurrent.futures import (
    FIRST_COMPLETED,
    ProcessPoolExecutor,
    as_completed,
    wait,
)
from multiprocessing import get_context
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

import repro
from repro.core.driver import WorkloadSpec, WorkloadTrace
from repro.core.exec.artifacts import ArtifactCache
from repro.core.exec.timers import record
from repro.core.experiment import score_prefetcher
from repro.core.obs import spans as obs
from repro.memsim import PrefetchMetrics


# Per-worker-process memo of the last materialized trace: pool processes
# run many tasks, and consecutive tasks for the same workload (a split
# prefetcher list) should not reload the artifact.  One entry bounds memory.
_LAST_TRACE: Optional[Tuple[Tuple[str, WorkloadSpec], WorkloadTrace]] = None


def _materialize(spec: WorkloadSpec, cache_root: str) -> Optional[WorkloadTrace]:
    global _LAST_TRACE
    if getattr(spec, "is_sharded", False):
        # Sharded workloads materialize as a shard store + manifest, not a
        # WorkloadTrace; nothing stays resident in the worker.
        from repro.core.exec import sharded

        sharded.ensure_shards(spec, ArtifactCache(cache_root))
        return None
    key = (cache_root, spec)
    with obs.span(
        "materialize", kernel=spec.kernel, dataset=spec.dataset
    ) as sp:
        if _LAST_TRACE is not None and _LAST_TRACE[0] == key:
            if sp:
                sp.attrs["cache"] = "memo"
            obs.inc("artifact.memo_hits")
            return _LAST_TRACE[1]
        cache = ArtifactCache(cache_root)
        if sp:
            sp.attrs["cache_key"] = cache.path_for(spec).name
        trace = cache.load(spec)
        if trace is None:
            t0 = time.perf_counter()
            trace = spec.build()
            cache.save(spec, trace)
            cache.record_cost(spec, build_s=time.perf_counter() - t0)
            if sp:
                sp.attrs["cache"] = "build"
            obs.inc("artifact.builds")
        else:
            if sp:
                sp.attrs["cache"] = "load"
            obs.inc("artifact.loads")
        _LAST_TRACE = (key, trace)
        return trace


def _run_task(task) -> Tuple[int, List[Tuple[str, PrefetchMetrics]]]:
    """Worker body: build-or-load one trace, score its prefetchers."""
    import time

    index, spec, prefetchers, cache_root = task
    debug = os.environ.get("REPRO_EXEC_DEBUG")
    try:
        with obs.span(
            "run_task",
            task=index,
            kernel=spec.kernel,
            dataset=spec.dataset,
            prefetchers=[name for name, _ in prefetchers],
            sharded=bool(getattr(spec, "is_sharded", False)),
        ):
            if getattr(spec, "is_sharded", False):
                # Sharded tasks stream shards through the bounded-memory
                # scorer; the shard store (cached by content key) is built
                # on first touch.
                from repro.core.exec import sharded

                t0 = time.perf_counter()
                scored = sharded.score_sharded(
                    spec, list(prefetchers), ArtifactCache(cache_root)
                )
                if debug:
                    print(
                        f"[worker {os.getpid()}] {spec.kernel}/{spec.dataset} "
                        f"sharded x{len(prefetchers)} "
                        f"{time.perf_counter() - t0:.1f}s",
                        flush=True,
                    )
                return index, scored
            t0 = time.perf_counter()
            trace = _materialize(spec, cache_root)
            if debug:
                print(
                    f"[worker {os.getpid()}] {spec.kernel}/{spec.dataset} "
                    f"materialize {time.perf_counter() - t0:.1f}s",
                    flush=True,
                )
            scored = []
            score_t0 = time.perf_counter()
            for name, gen in prefetchers:
                t0 = time.perf_counter()
                scored.append((name, score_prefetcher(trace, name, gen)))
                if debug:
                    print(
                        f"[worker {os.getpid()}] {spec.kernel}/{spec.dataset} "
                        f"score {name} {time.perf_counter() - t0:.1f}s",
                        flush=True,
                    )
            if prefetchers:
                ArtifactCache(cache_root).record_cost(
                    spec,
                    score_s_per_prefetcher=(
                        (time.perf_counter() - score_t0) / len(prefetchers)
                    ),
                )
            return index, scored
    finally:
        # Task boundary: land this process's cumulative counters so the
        # parent's merge sees worker-side cache hit/build splits.
        obs.flush_worker_metrics()


def _split(items: Sequence, n: int) -> List[list]:
    """Split into ``n`` (or fewer) contiguous near-equal chunks."""
    n = max(1, min(n, len(items)))
    size, rem = divmod(len(items), n)
    out, i = [], 0
    for j in range(n):
        step = size + (1 if j < rem else 0)
        out.append(list(items[i : i + step]))
        i += step
    return out


def _plan(
    specs: Sequence[WorkloadSpec],
    prefetchers: Sequence[tuple],
    workers: int,
    artifacts: ArtifactCache,
) -> Tuple[List[WorkloadSpec], List[tuple]]:
    """(unique specs, [(spec, prefetcher chunk), ...]) task list.

    An *unmaterialized* workload is one task — its (expensive) build must
    happen exactly once, in the worker that scores it.  A workload already
    in the artifact store loads in seconds, so its prefetcher list may be
    split across sibling tasks for load balance; we aim for ~2 tasks per
    worker so one heavy workload cannot serialize the tail of the run.
    """
    unique = list(dict.fromkeys(specs))
    target_tasks = max(2 * workers, len(unique))
    chunks_per_cached = max(1, -(-target_tasks // len(unique)))  # ceil
    tasks = []
    for spec in unique:
        n_chunks = chunks_per_cached if artifacts.has(spec) else 1
        for chunk in _split(prefetchers, n_chunks):
            tasks.append((spec, chunk))
    return unique, tasks


def _check_picklable(prefetchers: Sequence[tuple]) -> None:
    for name, gen in prefetchers:
        try:
            pickle.dumps(gen)
        except Exception as e:
            raise ValueError(
                f"prefetcher {name!r} is not picklable and cannot be shipped "
                "to worker processes — parallel execution needs module-level "
                "generators or registry factories (lambdas and closures are "
                "not); run serially or register the prefetcher"
            ) from e


# ------------------------------------------------------------ cost model
#
# The scheduler sizes its pool from *predicted* task cost instead of a
# blind min(cores, builds): on hosts where spawn + import + contention
# overhead exceeds the parallel gain (the BENCH_2026-08-07 inversion where
# workers=2 took 15.5s against 9.9s serial on a 1-CPU box), the model
# degrades to serial in-process execution and no pool is spawned at all.
#
# Costs come from metadata the artifact cache already records, preferred
# in this order: *measured* build/score seconds persisted in each
# artifact's cost sidecar by earlier runs (``ArtifactCache.record_cost``);
# a materialized trace's compressed size as a direct access-count proxy
# (``measured``); and, cold, a dataset-size estimate from the DATASETS
# registry.  The per-access constants below are therefore first-run
# fallbacks only, calibrated against the committed BENCH stage breakdown
# under the fused hierarchy engine (pgd/comdblp: ~2.6M accesses, one
# fused demand launch instead of three per-level passes at build, one
# batched score launch per prefetcher family) — they only need
# order-of-magnitude fidelity, because the decision margins they guard
# (spawn overhead vs multi-core speedup) are themselves
# order-of-magnitude.

BUILD_S_PER_ACCESS = 1.1e-6  # trace_gen + fused demand_sim + artifact save
SCORE_S_PER_ACCESS = 3.0e-7  # one prefetcher's composite scoring pass
LOAD_S_PER_ACCESS = 5.0e-8  # artifact load + session rebuild
ARTIFACT_BYTES_PER_ACCESS = 12.0  # compressed .npz size -> access count
TRACE_BYTES_PER_ACCESS = 80.0  # resident trace working set per access
SPAWN_BASE_S = 2.5  # pool startup: spawn + re-import + JAX re-init
SPAWN_PER_WORKER_S = 0.4  # marginal startup cost of each extra worker
SPARSE_TRAVERSAL_DISCOUNT = 0.4  # frontier kernels touch a graph fraction


@dataclasses.dataclass(frozen=True)
class TaskCost:
    """Predicted cost of one workload spec's build + scoring."""

    spec: object
    build_s: float  # 0.0 when the artifact store already holds the trace
    score_s: float  # all prefetchers against this spec
    resident_bytes: float
    measured: bool  # True when sized from a real artifact, not a guess

    @property
    def total_s(self) -> float:
        return self.build_s + self.score_s


@dataclasses.dataclass(frozen=True)
class SchedDecision:
    """The scheduler's resolved execution mode for one run.

    Surfaced as ``ExperimentResult.sched`` and recorded by the bench, so
    every committed BENCH_*.json documents *why* a run went serial or
    parallel on its host.
    """

    mode: str  # "serial" | "pipeline"
    workers: int  # 1 for serial, else the chosen pool width
    est_serial_s: float
    est_pool_s: Optional[float]  # best pool estimate (None: pool impossible)
    reason: str
    cores: int
    n_tasks: int
    measured_frac: float  # fraction of estimates backed by real artifacts

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _dataset_shape(name: str) -> Tuple[int, int]:
    """(vertices, edges) from the DATASETS registry, with a generic
    fallback so unknown names still get a nonzero estimate."""
    from repro.graphs.generators import DATASETS

    ds = DATASETS.get(name)
    if ds is None:
        return 50_000, 200_000
    n = int(ds.get("n", 50_000))
    m = int(ds.get("m", 4 * n))  # road graphs omit m: ~4 edges/vertex
    return n, m


def _estimate_accesses(spec) -> float:
    """Spec-derived access-count estimate for a cold (unbuilt) workload."""
    from repro.apps.registry import kernel_traits

    n, m = _dataset_shape(spec.dataset)
    traits = kernel_traits(spec.kernel)
    per_pass = n + 3.0 * m  # vertex props + offsets/neighbors/frontier
    if traits.two_run:
        # Traversals: two runs, each visiting a sparse-frontier fraction.
        accesses = 2.0 * per_pass * SPARSE_TRAVERSAL_DISCOUNT
    else:
        accesses = 12.0 * per_pass  # iterative kernels: ~a dozen sweeps
    if getattr(spec, "epochs", None) is not None and hasattr(spec, "epoch"):
        # A stream epoch is a single run in the shared address layout.
        accesses /= 2.0 if traits.two_run else 12.0
    return accesses


def estimate_cost(spec, n_prefetchers: int, artifacts: ArtifactCache) -> TaskCost:
    """Predict build/score cost for one spec from cache metadata.

    Measured per-task seconds from the artifact's cost sidecar
    (:meth:`~repro.core.exec.artifacts.ArtifactCache.record_cost`) beat
    every constant: a recorded ``score_s_per_prefetcher`` prices scoring
    exactly, and a recorded ``build_s`` prices a rebuild of a spec whose
    artifact is gone but whose sidecar survived.  Otherwise materialized
    specs are sized from their artifact's compressed size (sharded specs
    from the manifest's exact access count) and pay only a load, not a
    build; cold specs fall back to the DATASETS-derived estimate.
    Deterministic given the artifact store's state.
    """
    accesses: Optional[float] = None
    measured = False
    if getattr(spec, "is_sharded", False):
        manifest = artifacts.load_manifest(spec)
        if manifest is not None:
            accesses, measured = float(manifest["num_accesses"]), True
    else:
        try:
            size = artifacts.path_for(spec).stat().st_size
            accesses, measured = size / ARTIFACT_BYTES_PER_ACCESS, True
        except OSError:
            pass
    if accesses is None:
        accesses = _estimate_accesses(spec)
    recorded = artifacts.load_cost(spec) or {}
    if measured:
        build_s = accesses * LOAD_S_PER_ACCESS
    elif "build_s" in recorded:
        build_s, measured = float(recorded["build_s"]), True
    else:
        build_s = accesses * BUILD_S_PER_ACCESS
    if "score_s_per_prefetcher" in recorded:
        score_s = float(recorded["score_s_per_prefetcher"]) * n_prefetchers
    else:
        score_s = accesses * SCORE_S_PER_ACCESS * n_prefetchers
    return TaskCost(
        spec=spec,
        build_s=build_s,
        score_s=score_s,
        resident_bytes=accesses * TRACE_BYTES_PER_ACCESS,
        measured=measured,
    )


def _lpt_makespan(costs_s: Sequence[float], bins: int) -> float:
    """Longest-processing-time-first makespan of ``costs_s`` over ``bins``
    equal workers — the same greedy order the dispatcher uses."""
    loads = [0.0] * max(1, bins)
    for c in sorted(costs_s, reverse=True):
        loads[loads.index(min(loads))] += c
    return max(loads)


def decide(
    costs: Sequence[TaskCost],
    *,
    cores: int,
    mem_bytes: Optional[int] = None,
) -> SchedDecision:
    """Pure decision function: serial vs pipelined pool, and pool width.

    Deterministic for fixed inputs (tested).  Serial wins whenever the
    best pool estimate — spawn overhead plus the LPT makespan across P
    workers — is no better than just running the work in-process, which
    is always the case on a single core, and whenever available memory
    cannot hold two resident traces at once.
    """
    serial_s = sum(c.total_s for c in costs)
    n = len(costs)
    base = dict(
        est_serial_s=serial_s,
        cores=cores,
        n_tasks=n,
        measured_frac=(sum(c.measured for c in costs) / n) if n else 1.0,
    )
    if n <= 1:
        return SchedDecision(
            mode="serial", workers=1, est_pool_s=None,
            reason="at most one independent task — nothing to overlap",
            **base,
        )
    if cores <= 1:
        return SchedDecision(
            mode="serial", workers=1, est_pool_s=None,
            reason="single core — a pool only adds spawn and contention cost",
            **base,
        )
    cap = min(cores, n)
    if mem_bytes is not None:
        peak = max(c.resident_bytes for c in costs)
        cap = min(cap, max(1, int(mem_bytes // max(peak, 1.0))))
        if cap <= 1:
            return SchedDecision(
                mode="serial", workers=1, est_pool_s=None,
                reason="available memory holds at most one resident trace",
                **base,
            )
    totals = [c.total_s for c in costs]
    best_p, best_s = 1, float("inf")
    for p in range(2, cap + 1):
        pool_s = (
            SPAWN_BASE_S + SPAWN_PER_WORKER_S * p + _lpt_makespan(totals, p)
        )
        if pool_s < best_s:
            best_p, best_s = p, pool_s
    if best_s >= serial_s:
        return SchedDecision(
            mode="serial", workers=1, est_pool_s=best_s,
            reason=(
                f"predicted pool time {best_s:.1f}s >= serial "
                f"{serial_s:.1f}s — spawn overhead exceeds parallel gain"
            ),
            **base,
        )
    return SchedDecision(
        mode="pipeline", workers=best_p, est_pool_s=best_s,
        reason=(
            f"predicted pool time {best_s:.1f}s at {best_p} workers beats "
            f"serial {serial_s:.1f}s"
        ),
        **base,
    )


def _available_mem_bytes() -> Optional[int]:
    """MemAvailable from /proc/meminfo, or None off-Linux."""
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    return None


def plan_execution(
    specs: Sequence,
    n_prefetchers: int,
    artifacts: Optional[ArtifactCache] = None,
    *,
    cores: Optional[int] = None,
    mem_bytes: Optional[int] = None,
) -> SchedDecision:
    """Cost out ``specs`` against the artifact store and pick a mode.

    ``cores``/``mem_bytes`` default to the live host (injectable for
    deterministic tests).  This is what ``Experiment.run(workers=None)``
    consults instead of the old blind ``min(cores, builds)``.
    """
    artifacts = artifacts if artifacts is not None else ArtifactCache()
    if cores is None:
        cores = os.cpu_count() or 1
    if mem_bytes is None:
        mem_bytes = _available_mem_bytes()
    unique = list(dict.fromkeys(specs))
    costs = [estimate_cost(s, n_prefetchers, artifacts) for s in unique]
    return decide(costs, cores=cores, mem_bytes=mem_bytes)


def rows_equal(a: List[dict], b: List[dict]) -> bool:
    """Exact equality of two ``ExperimentResult.rows()`` lists.

    The ``info`` entry holds prefetcher-side stats (scalars and numpy
    arrays) and is compared element-wise; every other metric must match
    bit-for-bit.  This is the parallel-vs-serial parity predicate used by
    the engine tests and the CI bench smoke gate.
    """
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        if set(ra) != set(rb):
            return False
        for k in ra:
            va, vb = ra[k], rb[k]
            if k == "info":
                if set(va) != set(vb):
                    return False
                if not all(np.array_equal(va[ik], vb[ik]) for ik in va):
                    return False
            elif va != vb:
                return False
    return True


@contextlib.contextmanager
def _spawn_pool(
    artifacts: ArtifactCache, n_tasks: int, workers: int
) -> Iterator[ProcessPoolExecutor]:
    """A spawned process pool with the engine's worker environment.

    Spawned interpreters re-import the package from scratch, so the parent
    exports: the ``repro`` source root on ``PYTHONPATH``; a persistent JAX
    compilation cache next to the workload artifacts (re-JITting the
    lax.scan cache passes costs seconds per process otherwise — an
    externally-set cache dir wins so a parent that set one shares its
    compiles); and the current cache-engine selection, which may live in
    process-local state the children would never see.  The environment is
    restored when the pool closes.
    """
    # repro may be a namespace package (no __init__), so resolve its
    # directory via __path__ when __file__ is absent.
    if getattr(repro, "__file__", None):
        pkg_dir = os.path.dirname(os.path.abspath(repro.__file__))
    else:
        pkg_dir = os.path.abspath(next(iter(repro.__path__)))
    src_root = os.path.dirname(pkg_dir)
    old_pythonpath = os.environ.get("PYTHONPATH")
    pythonpath = [src_root] + ([old_pythonpath] if old_pythonpath else [])
    jax_cache = os.environ.get(
        "JAX_COMPILATION_CACHE_DIR", str(artifacts.root / "jax-cache")
    )
    from repro.apps.trace import EMITTER_ENV, current_emitter
    from repro.memsim.engine import ENGINE_ENV, current_engine

    # ``workers`` is the requested shard width; the actual pool never
    # exceeds the task count or the core count — extra spawned processes
    # on a saturated host only add import/contention overhead.
    pool_size = max(1, min(workers, n_tasks, os.cpu_count() or workers))
    # Pin each worker's intra-op threadpools to its share of the cores.
    # XLA (and OpenMP/BLAS) size their pools to the *machine*, so P
    # workers x C-thread pools oversubscribe a C-core host P-fold — the
    # BENCH_2026-08-01 regression where workers=4 lost to workers=1.
    threads = max(1, (os.cpu_count() or 1) // pool_size)
    xla_flags = " ".join(
        filter(
            None,
            [
                os.environ.get("XLA_FLAGS"),
                f"--xla_cpu_multi_thread_eigen={'true' if threads > 1 else 'false'}",
                f"intra_op_parallelism_threads={threads}",
            ],
        )
    )
    child_env = {
        "PYTHONPATH": os.pathsep.join(pythonpath),
        "JAX_COMPILATION_CACHE_DIR": jax_cache,
        # Cache even sub-second compiles (the default threshold is 1s).
        "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": os.environ.get(
            "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0"
        ),
        "XLA_FLAGS": xla_flags,
        "OMP_NUM_THREADS": str(threads),
        "OPENBLAS_NUM_THREADS": str(threads),
        "MKL_NUM_THREADS": str(threads),
        ENGINE_ENV: current_engine(),
        # Same story for the trace-emitter selection (set_emitter /
        # use_emitter overrides live in parent process-local state).
        EMITTER_ENV: current_emitter(),
    }
    # When a dir-backed tracer is active, children join the trace: they
    # append spans to their own spans-worker-<pid>.jsonl under the trace
    # dir, and the parent's Tracer.finish() merges every file.
    tracer = obs.current_tracer()
    if tracer is not None and tracer.dir is not None:
        child_env[obs.SPAN_DIR_ENV] = str(tracer.dir)
        child_env[obs.TRACE_ID_ENV] = tracer.trace_id
    saved_env = {k: os.environ.get(k) for k in child_env}
    os.environ.update(child_env)
    try:
        ctx = get_context("spawn")
        with ProcessPoolExecutor(max_workers=pool_size, mp_context=ctx) as pool:
            yield pool
    finally:
        for key, value in saved_env.items():
            if value is None:
                os.environ.pop(key, None)
            else:
                os.environ[key] = value


def run_grid(
    specs: Sequence[WorkloadSpec],
    prefetchers: Sequence[Tuple[str, object]],
    *,
    workers: int,
    artifacts: Optional[ArtifactCache] = None,
    verbose: bool = False,
    pipeline: bool = True,
) -> Tuple[Dict[tuple, PrefetchMetrics], Dict[WorkloadSpec, WorkloadTrace]]:
    """Evaluate the (specs x prefetchers) grid across ``workers`` processes.

    Returns ``({(spec, name): metrics}, {spec: trace})``, where the trace
    dict holds parent-side builds (none in the common path — every task's
    trace lands in the artifact store for on-demand loading).  The caller
    owns cell ordering (the metrics mapping is order-free, deterministic).

    ``pipeline=True`` (the default) overlaps materialization with scoring:
    a cold workload is submitted as a build-only task, and its prefetcher
    chunks are dispatched *the moment the build completes* — so warm
    workloads score while cold builds are still running, instead of the
    phased materialize-all-then-score-all schedule (``pipeline=False``,
    kept as the comparison baseline for the bench).  Both schedules
    produce bit-identical metrics; only the dispatch order differs.
    """
    artifacts = artifacts if artifacts is not None else ArtifactCache()
    _check_picklable(prefetchers)
    if pipeline:
        return _run_grid_pipelined(specs, prefetchers, workers, artifacts, verbose)

    unique, tasks = _plan(specs, prefetchers, workers, artifacts)

    # Longest-task-first dispatch: a heavy task submitted last would
    # serialize the tail of the run.  Artifact size x chunk length is the
    # cost proxy; a cold (unbuilt) workload is the most expensive unit of
    # all, so unknown costs rank first and the build overlaps the warm
    # work.  Execution order never affects results — cells are
    # reassembled by key.
    def _cost(task):
        spec = task[0]
        if getattr(spec, "is_sharded", False):
            # The manifest is tiny; rank by the trace length it describes
            # (8 bytes/access as the size proxy).  Unbuilt stores rank first.
            manifest = artifacts.load_manifest(spec)
            if manifest is None:
                return float("inf")
            return 8.0 * manifest["num_accesses"] * len(task[1])
        try:
            return artifacts.path_for(spec).stat().st_size * len(task[1])
        except OSError:
            return float("inf")

    tasks.sort(key=_cost, reverse=True)

    traces: Dict[WorkloadSpec, WorkloadTrace] = {}
    metrics: Dict[tuple, PrefetchMetrics] = {}
    with _spawn_pool(artifacts, len(tasks), workers) as pool:
        futures = {
            pool.submit(_run_task, (i, spec, chunk, str(artifacts.root))): i
            for i, (spec, chunk) in enumerate(tasks)
        }
        for fut in as_completed(futures):
            index, scored = fut.result()
            spec = tasks[index][0]
            for name, m in scored:
                metrics[(spec, name)] = m
                if verbose:
                    _print_cell(spec, name, m)

    # Workers persisted their traces in the artifact store; the caller
    # loads them from there on demand (``traces`` stays empty unless a
    # future planner gives the parent build work again).
    return metrics, traces


def _print_cell(spec, name, m) -> None:
    print(
        f"[{spec.kernel}/{spec.dataset}] {name}: "
        f"speedup {m.speedup:.2f} coverage {m.coverage:.2f} "
        f"accuracy {m.accuracy:.2f}"
    )


def _run_grid_pipelined(
    specs: Sequence[WorkloadSpec],
    prefetchers: Sequence[Tuple[str, object]],
    workers: int,
    artifacts: ArtifactCache,
    verbose: bool,
) -> Tuple[Dict[tuple, PrefetchMetrics], Dict[WorkloadSpec, WorkloadTrace]]:
    """Overlap-pipelined grid execution (see :func:`run_grid`).

    Three task kinds flow through one pool: score chunks for warm
    workloads (dispatched immediately), build-only tasks for cold
    workloads (heaviest first), and the cold workloads' score chunks,
    dispatched as each build future resolves.  Sharded specs stay single
    build+score tasks — their bounded-memory scorer streams shards and
    never materializes a whole trace to hand off.  ``pipeline_overlap``
    accumulates the wall-time during which a build and a score task were
    in flight simultaneously — the saving over the phased schedule.
    """
    unique = list(dict.fromkeys(specs))
    target_tasks = max(2 * workers, len(unique))
    chunks_per = max(1, -(-target_tasks // len(unique)))  # ceil
    n_pf = len(prefetchers)

    warm, cold, whole = [], [], []
    for spec in unique:
        if getattr(spec, "is_sharded", False):
            whole.append(spec)
        elif artifacts.has(spec):
            warm.append(spec)
        else:
            cold.append(spec)
    cold.sort(
        key=lambda s: estimate_cost(s, n_pf, artifacts).total_s, reverse=True
    )

    tasks: List[tuple] = []  # (spec, chunk) per score task, by index
    metrics: Dict[tuple, PrefetchMetrics] = {}
    n_tasks_est = (
        len(whole) + (len(warm) + len(cold)) * chunks_per + len(cold)
    )
    overlap = 0.0
    with _spawn_pool(artifacts, n_tasks_est, workers) as pool:
        score_futs: set = set()
        build_futs: Dict[object, WorkloadSpec] = {}

        def submit_score(spec, n_chunks):
            for chunk in _split(prefetchers, n_chunks):
                index = len(tasks)
                tasks.append((spec, chunk))
                score_futs.add(
                    pool.submit(
                        _run_task, (index, spec, chunk, str(artifacts.root))
                    )
                )

        for spec in whole:
            submit_score(spec, 1)
        for spec in warm:
            submit_score(spec, chunks_per)
        for i, spec in enumerate(cold):
            fut = pool.submit(_materialize_task, (i, spec, str(artifacts.root)))
            build_futs[fut] = spec

        while score_futs or build_futs:
            both_in_flight = bool(score_futs) and bool(build_futs)
            t0 = time.perf_counter()
            done, _ = wait(
                score_futs | set(build_futs), return_when=FIRST_COMPLETED
            )
            if both_in_flight:
                overlap += time.perf_counter() - t0
            for fut in done:
                if fut in build_futs:
                    fut.result()  # surface worker exceptions
                    spec = build_futs.pop(fut)
                    # The artifact just landed; its scoring can now split
                    # across the pool like any warm workload.
                    submit_score(spec, chunks_per)
                else:
                    score_futs.discard(fut)
                    index, scored = fut.result()
                    spec = tasks[index][0]
                    for name, m in scored:
                        metrics[(spec, name)] = m
                        if verbose:
                            _print_cell(spec, name, m)
    record("pipeline_overlap", overlap)
    return metrics, {}


def _materialize_task(task) -> int:
    """Worker body: build-or-load one trace into the artifact store."""
    index, spec, cache_root = task
    try:
        _materialize(spec, cache_root)
    finally:
        obs.flush_worker_metrics()
    return index


class MaterializePipeline:
    """Background builds with as-ready handoff to an in-parent scorer.

    The streaming and serving protocols must *score* sequentially in the
    parent (the cross-epoch table lifecycle and the shared-LLC interleave
    live there) but their traces are independent *builds*.  This object
    fans the builds across a spawned pool and lets the scorer block on
    exactly the trace it needs next (:meth:`wait`), so epoch 0 scores
    while epochs 1..E are still building — replacing the old
    materialize-all-then-score-all barrier.

    Builds are deduplicated by artifact path, which under content-keyed
    specs (``StreamEpochSpec``) collapses epochs whose graph the churn
    model left unchanged — and identical epochs across several streams in
    one run — into a single in-flight build.  ``n_built``/``n_reused``
    report that split.  Specs already in the artifact store spawn no pool
    work at all; a fully-warm pipeline never starts a pool.

    The wall-time the parent spends scoring while builds are still in
    flight accumulates under the ``pipeline_overlap`` stage key.
    """

    def __init__(
        self,
        specs: Sequence,
        *,
        workers: int,
        artifacts: ArtifactCache,
    ):
        self.artifacts = artifacts
        unique = list(dict.fromkeys(specs))
        by_path: Dict[str, object] = {}
        for s in unique:
            by_path.setdefault(str(artifacts.path_for(s)), s)
        todo = [
            (path, s) for path, s in by_path.items() if not artifacts.has(s)
        ]
        self.n_specs = len(unique)
        self.n_built = len(todo)
        self.n_reused = self.n_specs - self.n_built
        self._futures: Dict[str, object] = {}
        self._stack: Optional[contextlib.ExitStack] = None
        self._last_handoff: Optional[float] = None
        if todo:
            self._stack = contextlib.ExitStack()
            pool = self._stack.enter_context(
                _spawn_pool(artifacts, len(todo), workers)
            )
            # FIFO submission: the scorer consumes epochs in sequence
            # order, so the build it will wait on first starts first.
            for i, (path, spec) in enumerate(todo):
                self._futures[path] = pool.submit(
                    _materialize_task, (i, spec, str(self.artifacts.root))
                )

    def wait(self, spec) -> None:
        """Block until ``spec``'s trace is in the artifact store."""
        now = time.perf_counter()
        if self._last_handoff is not None and any(
            not f.done() for f in self._futures.values()
        ):
            # Parent-side work since the last handoff ran concurrently
            # with at least one build — the pipeline's saving.
            record("pipeline_overlap", now - self._last_handoff)
        path = self.artifacts.path_for(spec)
        fut = self._futures.get(str(path))
        with obs.span(
            "pipeline_handoff",
            cache_key=path.name,
            built=fut is not None,
        ):
            if fut is not None:
                fut.result()
        self._last_handoff = time.perf_counter()

    def close(self) -> None:
        """Drain remaining builds and shut the pool down."""
        try:
            for fut in self._futures.values():
                fut.result()
        finally:
            if self._stack is not None:
                self._stack.close()
                self._stack = None


def materialize_specs(
    specs: Sequence[WorkloadSpec],
    *,
    workers: int,
    artifacts: Optional[ArtifactCache] = None,
) -> int:
    """Fan workload builds (no scoring) across a spawned pool.

    The barrier form of :class:`MaterializePipeline` — build everything,
    then return.  Kept for callers that genuinely need all traces before
    any scoring (the serving interleave sizes its schedule from every
    tenant's length).  Already-materialized specs — including epochs that
    content-hash to an existing artifact — are skipped.  Returns the
    number of traces built.
    """
    artifacts = artifacts if artifacts is not None else ArtifactCache()
    pipe = MaterializePipeline(specs, workers=workers, artifacts=artifacts)
    pipe.close()
    return pipe.n_built


__all__ = [
    "MaterializePipeline",
    "SchedDecision",
    "TaskCost",
    "decide",
    "estimate_cost",
    "materialize_specs",
    "plan_execution",
    "rows_equal",
    "run_grid",
]
