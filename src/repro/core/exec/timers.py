"""Shared wall-clock timing for the execution engine and benchmarks.

All timing in this repo goes through ``time.perf_counter`` — it is
monotonic and has the highest available resolution, whereas ``time.time()``
has coarse granularity on some platforms and jumps under clock adjustment,
which makes microsecond-scale measurements meaningless.

Two layers:

- :func:`time_s` / :func:`time_us` time one callable (used by the
  ``benchmarks/run.py`` micro-benches and ``benchmarks/bench.py``).
- Pipeline stage instrumentation: the workload driver and the experiment
  scorer wrap their phases in ``with stage("trace_gen"): ...``; a caller
  wanting the breakdown activates collection with ``with collect_stages()
  as times: ...``.  With no collector active ``stage`` is a no-op, so the
  hot path pays nothing.  :func:`record` feeds the same collector with
  durations (or counts) measured out-of-band — overlap windows and
  scheduler decisions, which have no single ``with`` block to wrap.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Dict, Iterator, Optional

_ACTIVE: Optional[Dict[str, float]] = None


def time_s(fn: Callable[[], object], repeats: int = 1, warmup: int = 0) -> float:
    """Mean wall-clock seconds per call of ``fn`` over ``repeats`` calls."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def time_us(fn: Callable[[], object], repeats: int = 3) -> float:
    """Mean microseconds per call, after one warmup (compile) call."""
    return time_s(fn, repeats=repeats, warmup=1) * 1e6


@contextlib.contextmanager
def collect_stages(
    into: Optional[Dict[str, float]] = None,
) -> Iterator[Dict[str, float]]:
    """Collect ``stage()`` durations from the enclosed block into a dict.

    Durations accumulate per stage name, so a block that builds several
    workloads reports total seconds spent in each pipeline stage.  Nested
    collectors shadow outer ones for their extent.
    """
    global _ACTIVE
    times = into if into is not None else {}
    prev, _ACTIVE = _ACTIVE, times
    try:
        yield times
    finally:
        _ACTIVE = prev


def record(name: str, value: float = 1.0) -> None:
    """Accumulate ``value`` under ``name`` in the active collector.

    The out-of-band counterpart of :func:`stage`: pipeline overlap is the
    wall-time two futures spend concurrently in flight, and a scheduler
    decision is a count — neither is a contiguous block a context manager
    could wrap.  No-op without an active :func:`collect_stages`.
    """
    if _ACTIVE is not None:
        _ACTIVE[name] = _ACTIVE.get(name, 0.0) + value


@contextlib.contextmanager
def stage(name: str) -> Iterator[None]:
    """Accumulate this block's duration under ``name`` (no-op when no
    :func:`collect_stages` collector is active)."""
    if _ACTIVE is None:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        if _ACTIVE is not None:
            _ACTIVE[name] = _ACTIVE.get(name, 0.0) + (time.perf_counter() - t0)


__all__ = ["collect_stages", "record", "stage", "time_s", "time_us"]
