"""Shared wall-clock timing for the execution engine and benchmarks.

All timing in this repo goes through ``time.perf_counter`` — it is
monotonic and has the highest available resolution, whereas ``time.time()``
has coarse granularity on some platforms and jumps under clock adjustment,
which makes microsecond-scale measurements meaningless.

Two layers:

- :func:`time_s` / :func:`time_us` time one callable (used by the
  ``benchmarks/run.py`` micro-benches and ``benchmarks/bench.py``).
- Pipeline stage instrumentation: the workload driver and the experiment
  scorer wrap their phases in ``with stage("trace_gen"): ...``; a caller
  wanting the breakdown activates collection with ``with collect_stages()
  as times: ...``.  With no collector active ``stage`` is a no-op, so the
  hot path pays nothing.  :func:`record` feeds the same collector with
  durations (or counts) measured out-of-band — overlap windows and
  scheduler decisions, which have no single ``with`` block to wrap.

``stage``/``collect_stages``/``record`` are now thin re-exports of
:mod:`repro.core.obs.spans`: the same stage names double as structured
spans (and per-stage latency histograms) when a tracer or metrics
registry is active, with the flat stage-dict semantics — including the
no-op fast path — unchanged.  See docs/OBSERVABILITY.md.
"""

from __future__ import annotations

import time
from typing import Callable

from repro.core.obs.spans import collect_stages, record, stage

__all__ = ["collect_stages", "record", "stage", "time_s", "time_us"]


def time_s(fn: Callable[[], object], repeats: int = 1, warmup: int = 0) -> float:
    """Mean wall-clock seconds per call of ``fn`` over ``repeats`` calls."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def time_us(fn: Callable[[], object], repeats: int = 3) -> float:
    """Mean microseconds per call, after one warmup (compile) call."""
    return time_s(fn, repeats=repeats, warmup=1) * 1e6
