"""Parallel, cache-backed experiment execution engine.

Three pieces, layered under :class:`repro.core.Experiment`:

- :mod:`~repro.core.exec.timers` — ``perf_counter`` timing helpers and the
  zero-overhead pipeline stage instrumentation.
- :mod:`~repro.core.exec.artifacts` — content-addressed on-disk cache of
  built workload traces (compressed ``.npz`` keyed by spec + trace-code
  version), so repeat sweeps and CI reruns skip the dominant rebuild cost.
- :mod:`~repro.core.exec.scheduler` — process-pool grid scheduler that
  shards evaluation cells by workload, builds each trace once per grid,
  and reassembles results in deterministic (bit-identical-to-serial) order.

``Experiment(...).run()`` stays the serial reference path;
``Experiment(...).run(workers=N)`` opts into the engine.

Only :mod:`timers` is imported eagerly — the workload driver uses its stage
hooks, so the heavier modules (which import the driver back) resolve lazily
through ``__getattr__`` to keep the import graph acyclic.
"""

from repro.core.exec.timers import collect_stages, record, stage, time_s, time_us

__all__ = [
    "ArtifactCache",
    "MaterializePipeline",
    "SchedDecision",
    "collect_stages",
    "default_cache_dir",
    "materialize_specs",
    "plan_execution",
    "record",
    "rows_equal",
    "run_grid",
    "stage",
    "time_s",
    "time_us",
]


def __getattr__(name):
    if name in ("ArtifactCache", "default_cache_dir"):
        from repro.core.exec import artifacts

        return getattr(artifacts, name)
    if name in (
        "MaterializePipeline",
        "SchedDecision",
        "materialize_specs",
        "plan_execution",
        "rows_equal",
        "run_grid",
    ):
        from repro.core.exec import scheduler

        return getattr(scheduler, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
