"""Workload driver: app -> trace -> composite simulation -> metrics.

One :class:`WorkloadTrace` per (kernel, dataset) bundles the full access
trace, the shared demand profile, and the composite *baseline run* (demand +
next-line, per the paper's Table VI L2). Prefetchers consume it through
``amc_iteration_views()`` (AMC) or the raw substream accessors (baselines),
and ``run_prefetcher_suite`` scores each against the baseline run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.apps import KERNELS, trace_app_run
from repro.apps.ligra import AppRun
from repro.apps.trace import F_ID, T_ID, TraceConfig, concat_traces
from repro.core.amc.api import AMCSession
from repro.core.amc.prefetcher import IterationView, PrefetchStream
from repro.graphs import make_dataset, make_evolving_pair
from repro.memsim import (
    SCALED,
    DemandProfile,
    HierarchyConfig,
    PrefetchMetrics,
    evaluate,
    simulate_demand,
    simulate_with_prefetch,
)
from repro.memsim.config import BLOCK_BITS
from repro.memsim.hierarchy import PrefetchOutcome

# Kernels evaluated on the two-run evolving protocol (§VI).
TWO_RUN_KERNELS = ("bfs", "bellmanford")


@dataclasses.dataclass
class WorkloadTrace:
    kernel: str
    dataset: str
    cfg_trace: TraceConfig
    block: np.ndarray
    array_id: np.ndarray
    epoch_id: np.ndarray  # AMC epoch per access
    iter_id: np.ndarray  # global iteration per access
    elem: np.ndarray
    iter_epochs: List[Tuple[int, int]]  # per global iteration: (epoch, within)
    profile: DemandProfile
    nl_blocks: np.ndarray
    nl_pos: np.ndarray
    nl_outcome: PrefetchOutcome  # the baseline run (demand + next-line)
    eval_from_pos: int
    session: AMCSession

    @property
    def input_bytes(self) -> int:
        return self.cfg_trace.input_bytes

    @property
    def num_accesses(self) -> int:
        return len(self.block)

    # ---- composite-baseline L2 miss stream (recording ground truth) ----

    def baseline_miss_stream(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        sel = ~self.nl_outcome.demand_l2_hit
        pos = self.profile.l2_pos[sel]
        blocks = self.profile.l2_blocks[sel]
        iters = self.iter_id[pos]
        return pos, blocks, iters

    def amc_iteration_views(self):
        """Yield (IterationView, epoch) in iteration order for AMC."""
        t_base, t_size = self.cfg_trace.target_range
        t_lo, t_hi = t_base >> BLOCK_BITS, (t_base + t_size) >> BLOCK_BITS
        mpos, mblocks, miters = self.baseline_miss_stream()
        not_target = ~((mblocks >= t_lo) & (mblocks <= t_hi))
        mpos, mblocks, miters = (
            mpos[not_target],
            mblocks[not_target],
            miters[not_target],
        )
        tmask = self.array_id == T_ID
        tpos_all = np.flatnonzero(tmask)
        titer = self.iter_id[tpos_all]
        tvid = self.elem[tpos_all]
        views = []
        for it, (epoch, within) in enumerate(self.iter_epochs):
            ts = titer == it
            ms = miters == it
            views.append(
                (
                    IterationView(
                        iteration=it,
                        within_epoch=within,
                        target_pos=tpos_all[ts],
                        target_vid=tvid[ts],
                        miss_pos=mpos[ms],
                        miss_blocks=mblocks[ms],
                    ),
                    epoch,
                )
            )
        return views

    # ---- L2 access substream view for the baseline prefetchers ----

    def l2_stream(self):
        """(pos, blocks, array_id, epoch) of L2 accesses (= L1 misses)."""
        p = self.profile
        return p.l2_pos, p.l2_blocks, self.array_id[p.l2_pos], self.epoch_id[p.l2_pos]


def _nextline_stream(profile: DemandProfile):
    """Degree-1 next-line at L2, trained on L2 accesses; consecutive
    same-line triggers filtered (standard)."""
    b = profile.l2_blocks
    p = profile.l2_pos
    if len(b) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    keep = np.ones(len(b), dtype=bool)
    keep[1:] = b[1:] != b[:-1]
    return b[keep] + 1, p[keep]


def _run_app(kernel: str, dataset: str, seed: int = 0):
    """Run the kernel per the paper's protocol; returns (runs, epoch_of_iter)."""
    fn = KERNELS[kernel]
    weighted = kernel == "bellmanford"
    g = make_dataset(dataset, weighted=weighted)
    if kernel in TWO_RUN_KERNELS:
        from repro.apps.bfs import pick_root

        pair = make_evolving_pair(g, seed=seed)
        # Same root for both runs so the traversals correlate (the paper's
        # BFS caveat: "if the parent node gets changed, the whole graph
        # traversal changes").
        root = pick_root(pair.run1, pair.mask1 & pair.mask2)
        r1 = fn(pair.run1, present_mask=pair.mask1, root=root)
        r2 = fn(pair.run2, present_mask=pair.mask2, root=root)
        return [r1, r2]
    return [fn(g)]


def build_workload(
    kernel: str,
    dataset: str,
    hierarchy: HierarchyConfig = SCALED,
    seed: int = 0,
    runs: Optional[List[AppRun]] = None,
) -> WorkloadTrace:
    runs = runs if runs is not None else _run_app(kernel, dataset, seed)
    # Shared address layout across runs (same id space - evolve.py keeps it).
    g = runs[0].graph
    cfg_trace = TraceConfig(
        num_vertices=g.num_vertices,
        num_edges=max(r.graph.num_edges for r in runs),
    )

    all_traces = []
    iter_epochs: List[Tuple[int, int]] = []
    git = 0
    run_start_iter = []
    for run_idx, run in enumerate(runs):
        traces = trace_app_run(run, cfg_trace)
        run_start_iter.append(git)
        for k, t in enumerate(traces):
            t.iteration = git  # globalize
            if kernel in TWO_RUN_KERNELS:
                iter_epochs.append((run_idx, k))
            else:
                iter_epochs.append((git, 0))
            git += 1
        all_traces.extend(traces)

    block, array_id, iter_id, elem = concat_traces(all_traces)
    epoch_id = np.asarray([iter_epochs[i][0] for i in range(git)], dtype=np.int32)[
        iter_id
    ]

    profile = simulate_demand(block, iter_id, hierarchy)
    nl_blocks, nl_pos = _nextline_stream(profile)
    nl_outcome = simulate_with_prefetch(
        profile, nl_blocks, nl_pos, pf_issuer=np.zeros(len(nl_blocks), np.int8)
    )

    eval_from = 0
    if kernel in TWO_RUN_KERNELS and len(runs) > 1:
        # Evaluate on the second (post-change) run only.
        second_first_iter = run_start_iter[1]
        eval_from = int(np.searchsorted(iter_id, second_first_iter))

    # Programming-model session, configured exactly as Algorithm 1 does.
    sess = AMCSession()
    sess.init(asid=0)
    t_base, t_size = cfg_trace.target_range
    f_base, f_size = cfg_trace.frontier_range
    sess.addr_t_base(t_base, t_size, elem_size=8)
    sess.addr_f_base(f_base, f_size, elem_size=1)

    return WorkloadTrace(
        kernel=kernel,
        dataset=dataset,
        cfg_trace=cfg_trace,
        block=block,
        array_id=array_id,
        epoch_id=epoch_id,
        iter_id=iter_id,
        elem=elem,
        iter_epochs=iter_epochs,
        profile=profile,
        nl_blocks=nl_blocks,
        nl_pos=nl_pos,
        nl_outcome=nl_outcome,
        eval_from_pos=eval_from,
        session=sess,
    )


def run_prefetcher_suite(
    workload: WorkloadTrace,
    prefetchers: Dict[str, Callable[[WorkloadTrace], PrefetchStream]],
) -> Dict[str, PrefetchMetrics]:
    """Run each prefetcher in the composite (next-line + X) configuration."""
    results: Dict[str, PrefetchMetrics] = {}
    for name, gen in prefetchers.items():
        stream = gen(workload)
        blocks = np.concatenate([workload.nl_blocks, stream.blocks])
        pos = np.concatenate([workload.nl_pos, stream.pos])
        issuer = np.concatenate(
            [
                np.zeros(len(workload.nl_blocks), np.int8),
                np.ones(len(stream.blocks), np.int8),
            ]
        )
        outcome = simulate_with_prefetch(
            workload.profile,
            blocks,
            pos,
            pf_issuer=issuer,
            metadata_bytes=stream.metadata_bytes,
        )
        m = evaluate(
            name,
            workload.profile,
            outcome,
            baseline_outcome=workload.nl_outcome,
            eval_from_pos=workload.eval_from_pos,
            issuer=1,
        )
        m.info = stream.info  # attach prefetcher-side stats
        results[name] = m
    return results
