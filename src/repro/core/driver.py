"""Workload driver: app -> trace -> composite simulation -> metrics.

One :class:`WorkloadTrace` per (kernel, dataset) bundles the full access
trace, the shared demand profile, and the composite *baseline run* (demand +
next-line, per the paper's Table VI L2). Prefetchers consume it through
``amc_iteration_views()`` (AMC) or the raw substream accessors (baselines).

Construction is declared by a :class:`WorkloadSpec` — kernel, dataset,
hierarchy, seed, and the AMC programming-model parameters (Table V element
sizes) in one frozen value, validated up front.  ``WorkloadSpec.build()``
(or the ``build_workload`` convenience wrapper) produces the trace with the
:class:`AMCSession` wired exactly as Algorithm 1 does.

Every kernel-protocol decision — weighted input, the §VI two-run evolving
protocol, the shared traversal root, the AMC epoch structure, traversal
direction — dispatches on the kernel's declarative
:class:`~repro.apps.registry.KernelSpec`; there are no kernel-name string
special-cases here.  Trace emission is the whole-run batched emitter
(:func:`repro.apps.trace.trace_run`), bit-identical to the per-iteration
reference oracle.

Scoring lives in :mod:`repro.core.experiment`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.apps import get_kernel, has_kernel, kernel_traits, list_kernels
from repro.apps.ligra import AppRun
from repro.apps.trace import T_ID, TraceConfig, trace_run
from repro.core.amc.api import AMCSession
from repro.core.amc.prefetcher import IterationView
from repro.core.exec.timers import stage
from repro.core.obs import spans as obs
from repro.graphs import DATASETS, make_dataset, make_evolving_pair
from repro.memsim import (
    SCALED,
    DemandProfile,
    HierarchyConfig,
    simulate_demand,
    simulate_with_prefetch,
)
from repro.memsim.config import BLOCK_BITS
from repro.memsim.hierarchy import PrefetchOutcome

# Version of the trace-construction pipeline below (app protocol, address
# layout, demand/next-line simulation).  The workload artifact cache
# (repro.core.exec.artifacts) folds this into its content hash, so bump it
# whenever a change to this module (or to apps/graphs/memsim code it calls)
# alters the built WorkloadTrace — every persisted artifact then reads as a
# miss and is rebuilt instead of silently serving stale data.
# v2: run_iterations stops at the kernel's done flag (converged-stop), which
# can shorten runs whose convergence test is independent of the frontier
# emptying — identical on the tested configs, but not provably for every
# dataset, so old artifacts must not be served.
TRACE_CODE_VERSION = 2


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Declarative description of one (kernel, dataset) workload cell.

    Folds the AMC programming-model configuration (paper Table V: the
    target/frontier element sizes behind ``AddrTBase``/``AddrFBase``) into
    the workload declaration, so Algorithm-1 wiring is validated here once
    instead of being hand-sequenced at every call site.  Hashable — used as
    the workload-cache key by :class:`repro.core.experiment.Experiment`.
    """

    kernel: str
    dataset: str
    hierarchy: HierarchyConfig = SCALED
    seed: int = 0
    target_elem_size: int = 8  # vertex property width (AddrTBase)
    frontier_elem_size: int = 1  # frontier flag width (AddrFBase)

    def __post_init__(self):
        if self.target_elem_size < 1 or self.frontier_elem_size < 1:
            raise ValueError("element sizes must be >= 1 byte")
        if self.target_elem_size % self.frontier_elem_size:
            raise ValueError(
                f"target_elem_size ({self.target_elem_size}) must be an "
                f"integer multiple of frontier_elem_size "
                f"({self.frontier_elem_size}): the §V-C2 address calculation "
                "scales by their integer ratio and would silently truncate"
            )

    def validate_names(self) -> None:
        """Check kernel/dataset against the registries. Called before the
        app is run from names; skipped when caller-supplied ``runs`` make
        the names purely descriptive."""
        if not has_kernel(self.kernel):
            raise ValueError(
                f"unknown kernel {self.kernel!r}; available: {sorted(list_kernels())}"
            )
        if self.dataset not in DATASETS:
            raise ValueError(
                f"unknown dataset {self.dataset!r}; available: {sorted(DATASETS)}"
            )

    def build(self, runs: Optional[List[AppRun]] = None) -> "WorkloadTrace":
        if runs is None:
            self.validate_names()
        with obs.span(
            "build_workload",
            kernel=self.kernel,
            dataset=self.dataset,
            seed=self.seed,
        ):
            return _build_workload(self, runs)


@dataclasses.dataclass
class WorkloadTrace:
    spec: WorkloadSpec
    kernel: str
    dataset: str
    cfg_trace: TraceConfig
    block: np.ndarray
    array_id: np.ndarray
    epoch_id: np.ndarray  # AMC epoch per access
    iter_id: np.ndarray  # global iteration per access
    elem: np.ndarray
    iter_epochs: List[Tuple[int, int]]  # per global iteration: (epoch, within)
    profile: DemandProfile
    nl_blocks: np.ndarray
    nl_pos: np.ndarray
    nl_outcome: PrefetchOutcome  # the baseline run (demand + next-line)
    eval_from_pos: int
    session: AMCSession

    @property
    def input_bytes(self) -> int:
        return self.cfg_trace.input_bytes

    @property
    def num_accesses(self) -> int:
        return len(self.block)

    # ---- composite-baseline L2 miss stream (recording ground truth) ----

    def baseline_miss_stream(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        sel = ~self.nl_outcome.demand_l2_hit
        pos = self.profile.l2_pos[sel]
        blocks = self.profile.l2_blocks[sel]
        iters = self.iter_id[pos]
        return pos, blocks, iters

    def amc_iteration_views(self):
        """Yield (IterationView, epoch) in iteration order for AMC."""
        t_base, t_size = self.cfg_trace.target_range
        t_lo, t_hi = t_base >> BLOCK_BITS, (t_base + t_size) >> BLOCK_BITS
        mpos, mblocks, miters = self.baseline_miss_stream()
        not_target = ~((mblocks >= t_lo) & (mblocks <= t_hi))
        mpos, mblocks, miters = (
            mpos[not_target],
            mblocks[not_target],
            miters[not_target],
        )
        tmask = self.array_id == T_ID
        tpos_all = np.flatnonzero(tmask)
        titer = self.iter_id[tpos_all]
        tvid = self.elem[tpos_all]
        # Both streams are iteration-sorted (positions ascend and iter_id is
        # nondecreasing along the trace), so the per-iteration views are
        # contiguous slices: two searchsorted calls replace the
        # O(iterations x N) per-iteration boolean masks.
        edges = np.arange(len(self.iter_epochs) + 1)
        t_bounds = np.searchsorted(titer, edges)
        m_bounds = np.searchsorted(miters, edges)
        views = []
        for it, (epoch, within) in enumerate(self.iter_epochs):
            t0, t1 = t_bounds[it], t_bounds[it + 1]
            m0, m1 = m_bounds[it], m_bounds[it + 1]
            views.append(
                (
                    IterationView(
                        iteration=it,
                        within_epoch=within,
                        target_pos=tpos_all[t0:t1],
                        target_vid=tvid[t0:t1],
                        miss_pos=mpos[m0:m1],
                        miss_blocks=mblocks[m0:m1],
                    ),
                    epoch,
                )
            )
        return views

    # ---- L2 access substream view for the baseline prefetchers ----

    def l2_stream(self):
        """(pos, blocks, array_id, epoch) of L2 accesses (= L1 misses)."""
        p = self.profile
        return p.l2_pos, p.l2_blocks, self.array_id[p.l2_pos], self.epoch_id[p.l2_pos]


def _nextline_stream(profile: DemandProfile):
    """Degree-1 next-line at L2, trained on L2 accesses; consecutive
    same-line triggers filtered (standard)."""
    b = profile.l2_blocks
    p = profile.l2_pos
    if len(b) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    keep = np.ones(len(b), dtype=bool)
    keep[1:] = b[1:] != b[:-1]
    return b[keep] + 1, p[keep]


def _run_app(kernel: str, dataset: str, seed: int = 0) -> List[AppRun]:
    """Run the kernel per its spec's protocol; returns the run list."""
    ks = get_kernel(kernel)
    g = make_dataset(dataset, weighted=ks.weighted)
    if ks.two_run:
        from repro.apps.bfs import pick_root

        pair = make_evolving_pair(g, seed=seed)
        # Same root for both runs so the traversals correlate (the paper's
        # BFS caveat: "if the parent node gets changed, the whole graph
        # traversal changes").
        root = (
            pick_root(pair.run1, pair.mask1 & pair.mask2)
            if ks.needs_root
            else None
        )
        r1 = ks.run(pair.run1, present_mask=pair.mask1, root=root)
        r2 = ks.run(pair.run2, present_mask=pair.mask2, root=root)
        return [r1, r2]
    return [ks.run(g)]


def build_workload(
    kernel,
    dataset: Optional[str] = None,
    hierarchy: HierarchyConfig = SCALED,
    seed: int = 0,
    runs: Optional[List[AppRun]] = None,
    *,
    target_elem_size: int = 8,
    frontier_elem_size: int = 1,
) -> WorkloadTrace:
    """Build a workload trace. Accepts a :class:`WorkloadSpec` or the legacy
    positional ``(kernel, dataset, ...)`` form."""
    if isinstance(kernel, WorkloadSpec):
        if (
            dataset is not None
            or hierarchy is not SCALED
            or seed != 0
            or target_elem_size != 8
            or frontier_elem_size != 1
        ):
            raise ValueError(
                "build_workload(spec) takes all configuration from the "
                "WorkloadSpec; don't pass dataset/hierarchy/seed/elem-size "
                "arguments alongside it"
            )
        return kernel.build(runs=runs)
    spec = WorkloadSpec(
        kernel=kernel,
        dataset=dataset,
        hierarchy=hierarchy,
        seed=seed,
        target_elem_size=target_elem_size,
        frontier_elem_size=frontier_elem_size,
    )
    return spec.build(runs=runs)


def make_session(spec: WorkloadSpec, cfg_trace: TraceConfig) -> AMCSession:
    """Programming-model session, configured exactly as Algorithm 1 does —
    element sizes come from the declarative spec (Table V wiring).  Also
    used by the workload artifact cache to reconstruct loaded traces."""
    sess = AMCSession()
    sess.init(asid=0)
    t_base, t_size = cfg_trace.target_range
    f_base, f_size = cfg_trace.frontier_range
    sess.addr_t_base(t_base, t_size, elem_size=spec.target_elem_size)
    sess.addr_f_base(f_base, f_size, elem_size=spec.frontier_elem_size)
    return sess


def _build_workload(
    spec: WorkloadSpec,
    runs: Optional[List[AppRun]],
    cfg_trace: Optional[TraceConfig] = None,
    epoch_mode: Optional[str] = None,
) -> WorkloadTrace:
    """Build the trace for ``spec``.

    ``cfg_trace`` overrides the address layout — the streaming protocol
    (``repro.stream.protocol``) lays every epoch of a stream out in one
    shared space so cross-epoch correlations stay valid.  ``epoch_mode``
    selects the AMC-epoch structure: ``None`` keeps the kernel spec's
    declared ``epoch_protocol`` (per-iteration epochs, or one epoch per
    run for the two-run kernels); ``"single"`` puts the whole trace in one
    epoch with the iteration index as the within-epoch key — one *stream
    epoch*, replayed against the previous epoch's recordings by the table
    lifecycle.
    """
    kernel, dataset, hierarchy = spec.kernel, spec.dataset, spec.hierarchy
    # Ad-hoc kernel names with caller-supplied runs get the default
    # per-iteration traits; registered kernels dispatch on their spec.
    ks = kernel_traits(kernel)
    with stage("trace_gen"):
        runs = runs if runs is not None else _run_app(kernel, dataset, spec.seed)
        if cfg_trace is None:
            # Shared layout across runs (same id space - evolve.py keeps it).
            g = runs[0].graph
            cfg_trace = TraceConfig(
                num_vertices=g.num_vertices,
                num_edges=max(r.graph.num_edges for r in runs),
            )

        with stage("trace_emit"):
            run_traces = []
            iter_epochs: List[Tuple[int, int]] = []
            git = 0
            run_start_iter = []
            for run_idx, run in enumerate(runs):
                rt = trace_run(run, cfg_trace)
                run_start_iter.append(git)
                for k in range(rt.num_iters):
                    if epoch_mode == "single":
                        iter_epochs.append((0, git))
                    elif ks.two_run:
                        iter_epochs.append((run_idx, k))
                    else:
                        iter_epochs.append((git, 0))
                    git += 1
                run_traces.append(rt)

            if len(run_traces) == 1:  # single-run kernels: no concat copy
                rt = run_traces[0]
                block, array_id, elem = rt.block, rt.array_id, rt.elem
            else:
                block = np.concatenate([rt.block for rt in run_traces])
                array_id = np.concatenate([rt.array_id for rt in run_traces])
                elem = np.concatenate([rt.elem for rt in run_traces])
            iter_id = np.concatenate(
                [
                    np.repeat(
                        np.arange(s, s + rt.num_iters, dtype=np.int32),
                        rt.iter_sizes,
                    )
                    for s, rt in zip(run_start_iter, run_traces)
                ]
            )
        epoch_id = np.asarray(
            [iter_epochs[i][0] for i in range(git)], dtype=np.int32
        )[iter_id]

    with stage("demand_sim"):
        profile = simulate_demand(block, iter_id, hierarchy)
        nl_blocks, nl_pos = _nextline_stream(profile)
        nl_outcome = simulate_with_prefetch(
            profile, nl_blocks, nl_pos, pf_issuer=np.zeros(len(nl_blocks), np.int8)
        )

    eval_from = 0
    if ks.two_run and len(runs) > 1:
        # Evaluate on the second (post-change) run only.
        second_first_iter = run_start_iter[1]
        eval_from = int(np.searchsorted(iter_id, second_first_iter))

    sess = make_session(spec, cfg_trace)

    return WorkloadTrace(
        spec=spec,
        kernel=kernel,
        dataset=dataset,
        cfg_trace=cfg_trace,
        block=block,
        array_id=array_id,
        epoch_id=epoch_id,
        iter_id=iter_id,
        elem=elem,
        iter_epochs=iter_epochs,
        profile=profile,
        nl_blocks=nl_blocks,
        nl_pos=nl_pos,
        nl_outcome=nl_outcome,
        eval_from_pos=eval_from,
        session=sess,
    )
