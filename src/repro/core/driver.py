"""Workload driver: app -> trace -> composite simulation -> metrics.

One :class:`WorkloadTrace` per (kernel, dataset) bundles the full access
trace, the shared demand profile, and the composite *baseline run* (demand +
next-line, per the paper's Table VI L2). Prefetchers consume it through
``amc_iteration_views()`` (AMC) or the raw substream accessors (baselines).

Construction is declared by a :class:`WorkloadSpec` — kernel, dataset,
hierarchy, seed, and the AMC programming-model parameters (Table V element
sizes) in one frozen value, validated up front.  ``WorkloadSpec.build()``
(or the ``build_workload`` convenience wrapper) produces the trace with the
:class:`AMCSession` wired exactly as Algorithm 1 does.

Scoring lives in :mod:`repro.core.experiment`; the ``run_prefetcher_suite``
function kept here is a thin deprecation shim over it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.apps import KERNELS, trace_app_run
from repro.apps.ligra import AppRun
from repro.apps.trace import T_ID, TraceConfig, concat_traces
from repro.core.amc.api import AMCSession
from repro.core.amc.prefetcher import IterationView, PrefetchStream
from repro.core.exec.timers import stage
from repro.graphs import DATASETS, make_dataset, make_evolving_pair
from repro.memsim import (
    SCALED,
    DemandProfile,
    HierarchyConfig,
    PrefetchMetrics,
    simulate_demand,
    simulate_with_prefetch,
)
from repro.memsim.config import BLOCK_BITS
from repro.memsim.hierarchy import PrefetchOutcome

# Kernels evaluated on the two-run evolving protocol (§VI).
TWO_RUN_KERNELS = ("bfs", "bellmanford")

# Version of the trace-construction pipeline below (app protocol, address
# layout, demand/next-line simulation).  The workload artifact cache
# (repro.core.exec.artifacts) folds this into its content hash, so bump it
# whenever a change to this module (or to apps/graphs/memsim code it calls)
# alters the built WorkloadTrace — every persisted artifact then reads as a
# miss and is rebuilt instead of silently serving stale data.
TRACE_CODE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Declarative description of one (kernel, dataset) workload cell.

    Folds the AMC programming-model configuration (paper Table V: the
    target/frontier element sizes behind ``AddrTBase``/``AddrFBase``) into
    the workload declaration, so Algorithm-1 wiring is validated here once
    instead of being hand-sequenced at every call site.  Hashable — used as
    the workload-cache key by :class:`repro.core.experiment.Experiment`.
    """

    kernel: str
    dataset: str
    hierarchy: HierarchyConfig = SCALED
    seed: int = 0
    target_elem_size: int = 8  # vertex property width (AddrTBase)
    frontier_elem_size: int = 1  # frontier flag width (AddrFBase)

    def __post_init__(self):
        if self.target_elem_size < 1 or self.frontier_elem_size < 1:
            raise ValueError("element sizes must be >= 1 byte")
        if self.target_elem_size % self.frontier_elem_size:
            raise ValueError(
                f"target_elem_size ({self.target_elem_size}) must be an "
                f"integer multiple of frontier_elem_size "
                f"({self.frontier_elem_size}): the §V-C2 address calculation "
                "scales by their integer ratio and would silently truncate"
            )

    def validate_names(self) -> None:
        """Check kernel/dataset against the registries. Called before the
        app is run from names; skipped when caller-supplied ``runs`` make
        the names purely descriptive."""
        if self.kernel not in KERNELS:
            raise ValueError(
                f"unknown kernel {self.kernel!r}; available: {sorted(KERNELS)}"
            )
        if self.dataset not in DATASETS:
            raise ValueError(
                f"unknown dataset {self.dataset!r}; available: {sorted(DATASETS)}"
            )

    def build(self, runs: Optional[List[AppRun]] = None) -> "WorkloadTrace":
        if runs is None:
            self.validate_names()
        return _build_workload(self, runs)


@dataclasses.dataclass
class WorkloadTrace:
    spec: WorkloadSpec
    kernel: str
    dataset: str
    cfg_trace: TraceConfig
    block: np.ndarray
    array_id: np.ndarray
    epoch_id: np.ndarray  # AMC epoch per access
    iter_id: np.ndarray  # global iteration per access
    elem: np.ndarray
    iter_epochs: List[Tuple[int, int]]  # per global iteration: (epoch, within)
    profile: DemandProfile
    nl_blocks: np.ndarray
    nl_pos: np.ndarray
    nl_outcome: PrefetchOutcome  # the baseline run (demand + next-line)
    eval_from_pos: int
    session: AMCSession

    @property
    def input_bytes(self) -> int:
        return self.cfg_trace.input_bytes

    @property
    def num_accesses(self) -> int:
        return len(self.block)

    # ---- composite-baseline L2 miss stream (recording ground truth) ----

    def baseline_miss_stream(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        sel = ~self.nl_outcome.demand_l2_hit
        pos = self.profile.l2_pos[sel]
        blocks = self.profile.l2_blocks[sel]
        iters = self.iter_id[pos]
        return pos, blocks, iters

    def amc_iteration_views(self):
        """Yield (IterationView, epoch) in iteration order for AMC."""
        t_base, t_size = self.cfg_trace.target_range
        t_lo, t_hi = t_base >> BLOCK_BITS, (t_base + t_size) >> BLOCK_BITS
        mpos, mblocks, miters = self.baseline_miss_stream()
        not_target = ~((mblocks >= t_lo) & (mblocks <= t_hi))
        mpos, mblocks, miters = (
            mpos[not_target],
            mblocks[not_target],
            miters[not_target],
        )
        tmask = self.array_id == T_ID
        tpos_all = np.flatnonzero(tmask)
        titer = self.iter_id[tpos_all]
        tvid = self.elem[tpos_all]
        # Both streams are iteration-sorted (positions ascend and iter_id is
        # nondecreasing along the trace), so the per-iteration views are
        # contiguous slices: two searchsorted calls replace the
        # O(iterations x N) per-iteration boolean masks.
        edges = np.arange(len(self.iter_epochs) + 1)
        t_bounds = np.searchsorted(titer, edges)
        m_bounds = np.searchsorted(miters, edges)
        views = []
        for it, (epoch, within) in enumerate(self.iter_epochs):
            t0, t1 = t_bounds[it], t_bounds[it + 1]
            m0, m1 = m_bounds[it], m_bounds[it + 1]
            views.append(
                (
                    IterationView(
                        iteration=it,
                        within_epoch=within,
                        target_pos=tpos_all[t0:t1],
                        target_vid=tvid[t0:t1],
                        miss_pos=mpos[m0:m1],
                        miss_blocks=mblocks[m0:m1],
                    ),
                    epoch,
                )
            )
        return views

    # ---- L2 access substream view for the baseline prefetchers ----

    def l2_stream(self):
        """(pos, blocks, array_id, epoch) of L2 accesses (= L1 misses)."""
        p = self.profile
        return p.l2_pos, p.l2_blocks, self.array_id[p.l2_pos], self.epoch_id[p.l2_pos]


def _nextline_stream(profile: DemandProfile):
    """Degree-1 next-line at L2, trained on L2 accesses; consecutive
    same-line triggers filtered (standard)."""
    b = profile.l2_blocks
    p = profile.l2_pos
    if len(b) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    keep = np.ones(len(b), dtype=bool)
    keep[1:] = b[1:] != b[:-1]
    return b[keep] + 1, p[keep]


def _run_app(kernel: str, dataset: str, seed: int = 0):
    """Run the kernel per the paper's protocol; returns (runs, epoch_of_iter)."""
    fn = KERNELS[kernel]
    weighted = kernel == "bellmanford"
    g = make_dataset(dataset, weighted=weighted)
    if kernel in TWO_RUN_KERNELS:
        from repro.apps.bfs import pick_root

        pair = make_evolving_pair(g, seed=seed)
        # Same root for both runs so the traversals correlate (the paper's
        # BFS caveat: "if the parent node gets changed, the whole graph
        # traversal changes").
        root = pick_root(pair.run1, pair.mask1 & pair.mask2)
        r1 = fn(pair.run1, present_mask=pair.mask1, root=root)
        r2 = fn(pair.run2, present_mask=pair.mask2, root=root)
        return [r1, r2]
    return [fn(g)]


def build_workload(
    kernel,
    dataset: Optional[str] = None,
    hierarchy: HierarchyConfig = SCALED,
    seed: int = 0,
    runs: Optional[List[AppRun]] = None,
    *,
    target_elem_size: int = 8,
    frontier_elem_size: int = 1,
) -> WorkloadTrace:
    """Build a workload trace. Accepts a :class:`WorkloadSpec` or the legacy
    positional ``(kernel, dataset, ...)`` form."""
    if isinstance(kernel, WorkloadSpec):
        if (
            dataset is not None
            or hierarchy is not SCALED
            or seed != 0
            or target_elem_size != 8
            or frontier_elem_size != 1
        ):
            raise ValueError(
                "build_workload(spec) takes all configuration from the "
                "WorkloadSpec; don't pass dataset/hierarchy/seed/elem-size "
                "arguments alongside it"
            )
        return kernel.build(runs=runs)
    spec = WorkloadSpec(
        kernel=kernel,
        dataset=dataset,
        hierarchy=hierarchy,
        seed=seed,
        target_elem_size=target_elem_size,
        frontier_elem_size=frontier_elem_size,
    )
    return spec.build(runs=runs)


def make_session(spec: WorkloadSpec, cfg_trace: TraceConfig) -> AMCSession:
    """Programming-model session, configured exactly as Algorithm 1 does —
    element sizes come from the declarative spec (Table V wiring).  Also
    used by the workload artifact cache to reconstruct loaded traces."""
    sess = AMCSession()
    sess.init(asid=0)
    t_base, t_size = cfg_trace.target_range
    f_base, f_size = cfg_trace.frontier_range
    sess.addr_t_base(t_base, t_size, elem_size=spec.target_elem_size)
    sess.addr_f_base(f_base, f_size, elem_size=spec.frontier_elem_size)
    return sess


def _build_workload(
    spec: WorkloadSpec,
    runs: Optional[List[AppRun]],
    cfg_trace: Optional[TraceConfig] = None,
    epoch_mode: Optional[str] = None,
) -> WorkloadTrace:
    """Build the trace for ``spec``.

    ``cfg_trace`` overrides the address layout — the streaming protocol
    (``repro.stream.protocol``) lays every epoch of a stream out in one
    shared space so cross-epoch correlations stay valid.  ``epoch_mode``
    selects the AMC-epoch structure: ``None`` keeps the per-kernel paper
    protocol (PGD/CC: one epoch per iteration; BFS/BF: one per run);
    ``"single"`` puts the whole trace in one epoch with the iteration index
    as the within-epoch key — one *stream epoch*, replayed against the
    previous epoch's recordings by the table lifecycle.
    """
    kernel, dataset, hierarchy = spec.kernel, spec.dataset, spec.hierarchy
    with stage("trace_gen"):
        runs = runs if runs is not None else _run_app(kernel, dataset, spec.seed)
        if cfg_trace is None:
            # Shared layout across runs (same id space - evolve.py keeps it).
            g = runs[0].graph
            cfg_trace = TraceConfig(
                num_vertices=g.num_vertices,
                num_edges=max(r.graph.num_edges for r in runs),
            )

        all_traces = []
        iter_epochs: List[Tuple[int, int]] = []
        git = 0
        run_start_iter = []
        for run_idx, run in enumerate(runs):
            traces = trace_app_run(run, cfg_trace)
            run_start_iter.append(git)
            for k, t in enumerate(traces):
                t.iteration = git  # globalize
                if epoch_mode == "single":
                    iter_epochs.append((0, git))
                elif kernel in TWO_RUN_KERNELS:
                    iter_epochs.append((run_idx, k))
                else:
                    iter_epochs.append((git, 0))
                git += 1
            all_traces.extend(traces)

        block, array_id, iter_id, elem = concat_traces(all_traces)
        epoch_id = np.asarray(
            [iter_epochs[i][0] for i in range(git)], dtype=np.int32
        )[iter_id]

    with stage("demand_sim"):
        profile = simulate_demand(block, iter_id, hierarchy)
        nl_blocks, nl_pos = _nextline_stream(profile)
        nl_outcome = simulate_with_prefetch(
            profile, nl_blocks, nl_pos, pf_issuer=np.zeros(len(nl_blocks), np.int8)
        )

    eval_from = 0
    if kernel in TWO_RUN_KERNELS and len(runs) > 1:
        # Evaluate on the second (post-change) run only.
        second_first_iter = run_start_iter[1]
        eval_from = int(np.searchsorted(iter_id, second_first_iter))

    sess = make_session(spec, cfg_trace)

    return WorkloadTrace(
        spec=spec,
        kernel=kernel,
        dataset=dataset,
        cfg_trace=cfg_trace,
        block=block,
        array_id=array_id,
        epoch_id=epoch_id,
        iter_id=iter_id,
        elem=elem,
        iter_epochs=iter_epochs,
        profile=profile,
        nl_blocks=nl_blocks,
        nl_pos=nl_pos,
        nl_outcome=nl_outcome,
        eval_from_pos=eval_from,
        session=sess,
    )


def run_prefetcher_suite(
    workload: WorkloadTrace,
    prefetchers: Dict[str, Callable[[WorkloadTrace], PrefetchStream]],
) -> Dict[str, PrefetchMetrics]:
    """Deprecated shim: score each prefetcher against the baseline run.

    Use :class:`repro.core.experiment.Experiment` instead — it owns workload
    construction, caches traces across prefetchers, and returns a structured
    result over the full evaluation grid.
    """
    warnings.warn(
        "run_prefetcher_suite is deprecated; use repro.core.Experiment "
        "(or repro.core.experiment.score_prefetcher for a single stream)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.experiment import score_prefetcher

    return {
        name: score_prefetcher(workload, name, gen)
        for name, gen in prefetchers.items()
    }
