"""Multi-tenant serving subsystem: K concurrent query traces over a
shared LLC with per-tenant vs shared AMC correlation tables.

Public API:

- :class:`~repro.serve.protocol.TenantSpec` /
  :class:`~repro.serve.protocol.ServeSpec` — declare a scenario; pass the
  ServeSpec in ``Experiment(workloads=[...])`` or to
  :func:`~repro.serve.protocol.run_serve`.
- :func:`~repro.serve.interleave.interleave` — the deterministic
  K-way trace merge.
- :func:`~repro.serve.protocol.contention_payload` — the
  ``serve-contention`` JSON schema for figures/CI.
"""
from repro.serve.interleave import (
    INTERLEAVE_POLICIES,
    Interleave,
    deinterleave,
    interleave,
)
from repro.serve.protocol import (
    TABLE_MODES,
    ServeCell,
    ServeResult,
    ServeSpec,
    TenantSpec,
    contention_payload,
    run_serve,
    score_serve,
)
from repro.serve.tables import shared_table_streams

__all__ = [
    "INTERLEAVE_POLICIES",
    "Interleave",
    "ServeCell",
    "ServeResult",
    "ServeSpec",
    "TABLE_MODES",
    "TenantSpec",
    "contention_payload",
    "deinterleave",
    "interleave",
    "run_serve",
    "score_serve",
    "shared_table_streams",
]
