"""Shared-AMC-table walk for multi-tenant serving.

``TableMode`` axis, shared side: K tenants' iteration views are merged
into the global interleaved order and driven through ONE
:class:`~repro.core.amc.storage.AMCStorage` pair.  The walk is the body of
:meth:`AMCPrefetcher.generate` with three multi-tenant extensions:

- **Per-tenant epoch tracking.**  Each tenant's ``AMC.update()`` (epoch
  boundary in its own trace) triggers :meth:`AMCStorage.swap` on the
  *shared* spaces.  That is the naive-sharing semantic: one tenant's
  update invalidates everyone's freshly recorded tables — the paper's
  role-reversal applied to a resource it was never designed to share.

- **Ownership accounting.**  Recording tables are tagged with the tenant
  that wrote them.  A ``store()`` landing on a same-key table recorded by
  another tenant is a *cross-tenant overwrite* (its entries are counted as
  thrashed); a ``lookup()`` hit on a table recorded by another tenant is
  an *aliased hit* — the prefetcher replays a different query's miss
  stream, the serving-scale version of the paper's correlation-aliasing
  failure mode.  (PGD/CC put every iteration in its own epoch with
  ``within_epoch == 0``, so K such tenants contend for a single table
  key — aliasing is maximal by construction.)

- **Per-tenant traffic deltas.**  Metadata read/write/dropped counters are
  snapshotted around each view so every tenant's ``PrefetchStream.info``
  carries its own share, exactly as ``generate()`` reports per-call deltas.

With K=1 no extension fires (no foreign owner, deltas sum to the
call-total) and the walk is statement-for-statement ``generate()`` —
the byte-identity anchor asserted in ``tests/test_serve.py``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.amc.compression import CompressionStats
from repro.core.amc.prefetcher import AMCPrefetcher, PrefetchStream
from repro.core.amc.storage import AMCStorage
from repro.serve.interleave import Interleave


def _view_global_starts(trace, il_gmap: np.ndarray) -> np.ndarray:
    """Global slot of each iteration's first access in one tenant's trace."""
    n_iters = len(trace.iter_epochs)
    starts = np.searchsorted(trace.iter_id, np.arange(n_iters))
    # An empty trailing iteration would index one past the end; clamp —
    # its slot only orders the (no-op) view relative to other tenants.
    starts = np.minimum(starts, max(len(trace.iter_id) - 1, 0))
    return il_gmap[starts]


def shared_table_streams(
    prefetcher: AMCPrefetcher, traces: Sequence, il: Interleave
) -> Tuple[List[PrefetchStream], dict]:
    """Run the AMC lifecycle for K tenants over one shared table store.

    Returns one :class:`PrefetchStream` per tenant (blocks/pos in that
    tenant's private positions, info mirroring ``generate()``) plus a
    contention-counter dict with global totals and a ``per_tenant`` list.
    """
    cfg = prefetcher.config
    storage = AMCStorage(
        int(cfg.storage_fraction * sum(t.input_bytes for t in traces))
    )
    k_tenants = len(traces)

    # Merge all tenants' views into the interleaved global order.
    entries = []  # (gstart, tenant, view, epoch)
    for k, t in enumerate(traces):
        views = t.amc_iteration_views()
        if not views:
            continue
        gstarts = _view_global_starts(t, il.gmaps[k])
        for (view, epoch), g in zip(views, gstarts):
            entries.append((int(g), k, view, epoch))
    # Global slots are unique across tenants; stable sort keeps each
    # tenant's view order on (possible) within-tenant ties.
    order = np.argsort(
        np.asarray([e[0] for e in entries], dtype=np.int64), kind="stable"
    )

    cur_epoch: Dict[int, object] = {k: None for k in range(k_tenants)}
    rec_owner: Dict[int, int] = {}  # iteration key -> recording tenant
    pf_owner: Dict[int, int] = {}  # same, for the prefetch space
    stats = [CompressionStats() for _ in range(k_tenants)]
    out_blocks: List[List[np.ndarray]] = [[] for _ in range(k_tenants)]
    out_pos: List[List[np.ndarray]] = [[] for _ in range(k_tenants)]
    read_d = np.zeros(k_tenants, dtype=np.int64)
    write_d = np.zeros(k_tenants, dtype=np.int64)
    dropped_d = np.zeros(k_tenants, dtype=np.int64)
    lookups = np.zeros(k_tenants, dtype=np.int64)
    hits = np.zeros(k_tenants, dtype=np.int64)
    aliased = np.zeros(k_tenants, dtype=np.int64)
    evicted = np.zeros(k_tenants, dtype=np.int64)  # recordings clobbered
    swaps = 0
    cross_overwrites = 0
    thrashed_entries = 0

    for idx in order:
        _, k, view, epoch = entries[idx]
        if epoch != cur_epoch[k]:
            if cur_epoch[k] is not None:
                storage.swap()  # this tenant's AMC.update() — shared spaces
                pf_owner = rec_owner
                rec_owner = {}
                swaps += 1
            cur_epoch[k] = epoch
        key = view.within_epoch
        read0, write0 = storage.read_bytes, storage.write_bytes
        dropped0 = storage.dropped_entries

        rec = storage.lookup(key)
        lookups[k] += 1
        if rec is not None:
            hits[k] += 1
            if pf_owner.get(key, k) != k:
                aliased[k] += 1
        issued = prefetcher._prefetch(view, rec, storage)
        if issued is not None:
            out_blocks[k].append(issued[0])
            out_pos[k].append(issued[1])

        prev_tbl = storage.recording.get(key)
        prefetcher._record(view, storage, stats[k])
        new_tbl = storage.recording.get(key)
        if new_tbl is not None and new_tbl is not prev_tbl:
            owner = rec_owner.get(key)
            if prev_tbl is not None and owner is not None and owner != k:
                cross_overwrites += 1
                thrashed_entries += prev_tbl.num_entries
                evicted[owner] += 1
            rec_owner[key] = k

        read_d[k] += storage.read_bytes - read0
        write_d[k] += storage.write_bytes - write0
        dropped_d[k] += storage.dropped_entries - dropped0

    streams = []
    for k in range(k_tenants):
        blocks = (
            np.concatenate(out_blocks[k])
            if out_blocks[k]
            else np.zeros(0, np.int64)
        )
        pos = (
            np.concatenate(out_pos[k]) if out_pos[k] else np.zeros(0, np.int64)
        )
        streams.append(
            PrefetchStream(
                name=cfg.name,
                blocks=blocks,
                pos=pos,
                metadata_bytes=int(read_d[k] + write_d[k]),
                info=dict(
                    compression_ratio=stats[k].ratio,
                    mode_counts=stats[k].mode_counts,
                    entries=stats[k].entries,
                    storage_peak_bytes=storage.peak_bytes,
                    storage_cap_bytes=storage.capacity_bytes,
                    dropped_entries=int(dropped_d[k]),
                    metadata_read_bytes=int(read_d[k]),
                    metadata_write_bytes=int(write_d[k]),
                ),
            )
        )
    counters = dict(
        table_swaps=swaps,
        cross_tenant_overwrites=cross_overwrites,
        thrashed_entries=thrashed_entries,
        aliased_hits=int(aliased.sum()),
        shared_capacity_bytes=storage.capacity_bytes,
        per_tenant=[
            dict(
                lookups=int(lookups[k]),
                lookup_hits=int(hits[k]),
                aliased_hits=int(aliased[k]),
                recordings_evicted=int(evicted[k]),
            )
            for k in range(k_tenants)
        ],
    )
    return streams, counters


__all__ = ["shared_table_streams"]
