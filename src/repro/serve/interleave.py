"""Deterministic multi-tenant trace interleaver.

K independent query traces merge into one global access stream under a
virtual-time discipline: tenant ``k``'s ``i``-th access is stamped with
finish time ``(i + 1) / rate_k`` and the global stream is the stable sort
of all stamps (ties broken by tenant id, so equal-rate tenants alternate
in strict round-robin).  The merge is a pure function of
``(lengths, rates, policy)`` — no RNG, no host state — which is what makes
serving results reproducible and the serial/parallel parity gate possible.

Two properties the serving subsystem builds on (asserted in
``tests/test_serve.py``):

- **Order preservation.**  Within a tenant, global slots are strictly
  increasing in private position (``gmaps[k]`` is sorted), so per-tenant
  simulation order survives interleaving and deinterleaving is a bit-exact
  roundtrip.
- **Coverage.**  Every global slot belongs to exactly one tenant
  (``tenant_of`` partitions ``arange(total)`` via ``gmaps``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

INTERLEAVE_POLICIES = ("round_robin", "rate")


@dataclasses.dataclass
class Interleave:
    """The merged order of K tenant streams.

    ``tenant_of[g]`` is the tenant owning global slot ``g``;
    ``gmaps[k][i]`` is the global slot of tenant ``k``'s ``i``-th access.
    """

    policy: str
    rates: np.ndarray  # (K,) effective rates (all ones under round_robin)
    tenant_of: np.ndarray  # (total,) int32
    gmaps: List[np.ndarray]  # per tenant: private pos -> global slot

    @property
    def num_tenants(self) -> int:
        return len(self.gmaps)

    @property
    def total(self) -> int:
        return len(self.tenant_of)


def interleave(
    lengths: Sequence[int],
    rates: Optional[Sequence[float]] = None,
    policy: str = "round_robin",
) -> Interleave:
    """Merge K per-tenant streams of the given lengths into one order."""
    if policy not in INTERLEAVE_POLICIES:
        raise ValueError(
            f"unknown interleave policy {policy!r}; "
            f"available: {list(INTERLEAVE_POLICIES)}"
        )
    k_tenants = len(lengths)
    if k_tenants == 0:
        raise ValueError("interleave needs at least one tenant")
    if policy == "round_robin" or rates is None:
        r = np.ones(k_tenants, dtype=np.float64)
    else:
        r = np.asarray(list(rates), dtype=np.float64)
        if len(r) != k_tenants:
            raise ValueError(
                f"{len(r)} rates for {k_tenants} tenants — must match"
            )
        if not np.all(np.isfinite(r)) or np.any(r <= 0):
            raise ValueError(f"rates must be positive and finite, got {r}")
    total = int(sum(lengths))
    vtime = np.concatenate(
        [
            (np.arange(n, dtype=np.float64) + 1.0) / r[k]
            for k, n in enumerate(lengths)
        ]
    ) if total else np.zeros(0, dtype=np.float64)
    tenant = np.concatenate(
        [np.full(n, k, dtype=np.int32) for k, n in enumerate(lengths)]
    ) if total else np.zeros(0, dtype=np.int32)
    # lexsort: last key is primary -> sort by virtual time, ties by tenant.
    order = np.lexsort((tenant, vtime))
    tenant_of = tenant[order]
    gpos = np.empty(total, dtype=np.int64)
    gpos[order] = np.arange(total, dtype=np.int64)
    gmaps, start = [], 0
    for n in lengths:
        gmaps.append(gpos[start : start + n])
        start += n
    return Interleave(policy=policy, rates=r, tenant_of=tenant_of, gmaps=gmaps)


def deinterleave(il: Interleave) -> List[np.ndarray]:
    """Per-tenant global-slot index arrays, in private stream order.

    ``global_stream[deinterleave(il)[k]]`` recovers tenant ``k``'s private
    stream bit-exactly (the roundtrip property).  Equal to ``il.gmaps``
    but recomputed from ``tenant_of`` alone, so the roundtrip test
    exercises both representations against each other.
    """
    return [
        np.flatnonzero(il.tenant_of == k).astype(np.int64)
        for k in range(il.num_tenants)
    ]


__all__ = ["INTERLEAVE_POLICIES", "Interleave", "deinterleave", "interleave"]
