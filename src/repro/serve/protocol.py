"""Serving protocol: K concurrent query traces on the Experiment engine.

A :class:`ServeSpec` declares one multi-tenant serving scenario — K
:class:`TenantSpec` query workloads (mixed kernels x roots x datasets), an
interleave policy, and the AMC ``TableMode`` axis — and plugs into the
existing machinery like a :class:`~repro.core.driver.WorkloadSpec`:

- **Per-tenant traces, built once, cached.**  Each tenant is an ordinary
  :class:`WorkloadSpec` (content-addressable), so the
  :class:`~repro.core.exec.artifacts.ArtifactCache` persists tenant traces
  and the parallel scheduler materializes them across the pool.  Scoring
  happens in the parent — serial and ``workers=N`` results are
  byte-identical, same contract as the stream protocol.
- **Interleaved shared LLC.**  The deterministic interleaver
  (:mod:`repro.serve.interleave`) merges the K traces into one global
  order; private L1/L2 run per tenant on their own substreams and the LLC
  is re-simulated once on the interleaved miss stream
  (:mod:`repro.memsim.shared_llc`).  The *baseline* composite runs share
  the LLC too, so speedups compare contended runs against contended
  baselines.
- **TableMode axis.**  AMC-family prefetchers score under ``per_tenant``
  (one private table store each — the provisioned-isolation upper bound)
  and ``shared`` (one store for everyone —
  :func:`repro.serve.tables.shared_table_streams`, the paper's
  correlation-aliasing failure mode at serving scale).  Stateless
  baselines score once with ``table_mode=None``.
- **Contention report.**  Every cell's ``metrics.info["serve"]`` carries
  per-tenant contention counters (LLC hits lost to other tenants,
  shared-table thrash/aliasing); :func:`contention_payload` aggregates
  them into the ``serve-contention`` JSON schema consumed by
  ``benchmarks/figures.py::fig_contention`` and the CI smoke artifact.

K=1 is the anchor: one tenant, identity interleave, zero-offset LLC
namespace, no foreign table owner — every row is byte-identical to the
single-tenant :func:`~repro.core.experiment.score_prefetcher` path
(asserted in ``tests/test_serve.py``).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.driver import WorkloadSpec, WorkloadTrace
from repro.core.exec.timers import stage
from repro.core.obs import spans as obs
from repro.memsim import (
    SCALED,
    HierarchyConfig,
    PrefetchMetrics,
    evaluate,
    simulate_with_prefetch,
)
from repro.memsim.shared_llc import shared_llc_pass
from repro.serve.interleave import INTERLEAVE_POLICIES, Interleave, interleave
from repro.serve.tables import shared_table_streams

TABLE_MODES = ("per_tenant", "shared")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's query workload within a serving scenario."""

    kernel: str
    dataset: str
    seed: int = 0
    rate: float = 1.0  # relative request rate (the "rate" policy weight)
    target_elem_size: int = 8
    frontier_elem_size: int = 1

    def __post_init__(self):
        if not (np.isfinite(self.rate) and self.rate > 0):
            raise ValueError(f"tenant rate must be positive, got {self.rate}")

    def workload(self, hierarchy: HierarchyConfig) -> WorkloadSpec:
        return WorkloadSpec(
            kernel=self.kernel,
            dataset=self.dataset,
            hierarchy=hierarchy,
            seed=self.seed,
            target_elem_size=self.target_elem_size,
            frontier_elem_size=self.frontier_elem_size,
        )


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Declarative multi-tenant serving scenario.

    The hierarchy is shared (one LLC for everyone); per-tenant traces are
    ordinary cached workloads, so serving scenarios differing only in
    policy or table modes rebuild nothing.
    """

    tenants: Tuple[TenantSpec, ...]
    policy: str = "round_robin"
    table_modes: Tuple[str, ...] = TABLE_MODES
    hierarchy: HierarchyConfig = SCALED
    seed: int = 0  # scenario seed (rows inherit each tenant's own seed)

    # Duck-typing marker: Experiment routes these through the serving
    # protocol without importing it at declaration time.
    is_serve: ClassVar[bool] = True

    def __post_init__(self):
        object.__setattr__(self, "tenants", tuple(self.tenants))
        object.__setattr__(self, "table_modes", tuple(self.table_modes))
        if not self.tenants:
            raise ValueError("a serving scenario needs >= 1 tenant")
        for t in self.tenants:
            if not isinstance(t, TenantSpec):
                raise TypeError(f"tenants must be TenantSpec, got {t!r}")
        if self.policy not in INTERLEAVE_POLICIES:
            raise ValueError(
                f"unknown interleave policy {self.policy!r}; "
                f"available: {list(INTERLEAVE_POLICIES)}"
            )
        if not self.table_modes:
            raise ValueError("table_modes must be non-empty")
        for m in self.table_modes:
            if m not in TABLE_MODES:
                raise ValueError(
                    f"unknown table mode {m!r}; available: {list(TABLE_MODES)}"
                )

    @property
    def num_tenants(self) -> int:
        return len(self.tenants)

    def validate_names(self) -> None:
        for w in self.tenant_workloads():
            w.validate_names()

    def tenant_workloads(self) -> List[WorkloadSpec]:
        return [t.workload(self.hierarchy) for t in self.tenants]

    def rates(self) -> List[float]:
        return [t.rate for t in self.tenants]


@dataclasses.dataclass(frozen=True)
class ServeCell:
    """One (tenant, prefetcher, table-mode) score within a scenario."""

    tenant: int
    prefetcher: str
    table_mode: Optional[str]  # None for stateless (non-AMC) baselines
    metrics: PrefetchMetrics
    spec: WorkloadSpec


def _is_amc_generator(gen) -> bool:
    from repro.core.amc.prefetcher import AMCPrefetcher

    return isinstance(getattr(gen, "__self__", None), AMCPrefetcher)


def _share_llc(
    outs: Sequence, il: Interleave, hierarchy: HierarchyConfig
) -> Tuple[List, List[dict]]:
    """Re-simulate K private LLC-input streams through one shared LLC.

    Returns the outcomes with ``demand_llc_hit``/``pf_llc_in_dram`` patched
    to the contended hit masks, plus per-tenant counters of hits lost to
    contention (solo hit, shared miss — cross-tenant evictions)."""
    streams = []
    for k, o in enumerate(outs):
        # Private LLC events carry doubled positions (2p demand, 2p+1
        # prefetch); mapping p through the tenant's global-slot map yields
        # globally unique, order-preserving merge keys.
        pos2 = o.llc_in_pos2
        gkey = 2 * il.gmaps[k][pos2 // 2] + (pos2 & 1)
        streams.append((o.llc_in_blocks, gkey))
    hits = shared_llc_pass(streams, hierarchy.llc.sets, hierarchy.llc.ways)
    patched, lost = [], []
    for o, h in zip(outs, hits):
        is_pf = o.llc_in_is_pf
        d_hit, p_dram = h[~is_pf], (~h)[is_pf]
        lost.append(
            dict(
                llc_demand_hits_lost=int((o.demand_llc_hit & ~d_hit).sum()),
                llc_pf_hits_lost=int((~o.pf_llc_in_dram & p_dram).sum()),
            )
        )
        patched.append(
            dataclasses.replace(o, demand_llc_hit=d_hit, pf_llc_in_dram=p_dram)
        )
    return patched, lost


def _composite_outcome(trace: WorkloadTrace, pf_stream):
    """The composite (next-line + X) simulation of ``score_prefetcher``,
    keeping the LLC-input stream for the shared pass."""
    blocks = np.concatenate([trace.nl_blocks, pf_stream.blocks])
    pos = np.concatenate([trace.nl_pos, pf_stream.pos])
    issuer = np.concatenate(
        [
            np.zeros(len(trace.nl_blocks), np.int8),
            np.ones(len(pf_stream.blocks), np.int8),
        ]
    )
    return simulate_with_prefetch(
        trace.profile,
        blocks,
        pos,
        pf_issuer=issuer,
        metadata_bytes=pf_stream.metadata_bytes,
        keep_llc_stream=True,
    )


def score_serve(
    spec: ServeSpec,
    prefetchers: Sequence[Tuple[str, object]],
    traces: Sequence[WorkloadTrace],
) -> List[ServeCell]:
    """Score every prefetcher per tenant under the shared LLC.

    AMC-family generators run once per table mode; stateless baselines run
    once with ``table_mode=None``.  Deterministic given the traces — the
    serial/parallel parity of the serving protocol rests here.
    """
    wspecs = spec.tenant_workloads()
    with stage("serve_interleave"):
        il = interleave(
            [t.num_accesses for t in traces],
            rates=spec.rates(),
            policy=spec.policy,
        )
    with stage("serve_llc"):
        # Contended baselines: the composite (demand + next-line) runs of
        # all K tenants share the LLC too.  Re-simulated (bit-identical to
        # the cached nl_outcome) to capture the private LLC-input stream.
        base_outs = [
            simulate_with_prefetch(
                t.profile,
                t.nl_blocks,
                t.nl_pos,
                pf_issuer=np.zeros(len(t.nl_blocks), np.int8),
                keep_llc_stream=True,
            )
            for t in traces
        ]
        base_shared, base_lost = _share_llc(base_outs, il, spec.hierarchy)

    cells: List[ServeCell] = []
    for name, gen in prefetchers:
        modes: Tuple[Optional[str], ...] = (
            spec.table_modes if _is_amc_generator(gen) else (None,)
        )
        for mode in modes:
            with obs.span(
                "serve_cell",
                prefetcher=name,
                table_mode=mode,
                tenants=len(traces),
            ), stage("serve_score"):
                table_counters = None
                if mode == "shared":
                    streams, table_counters = shared_table_streams(
                        gen.__self__, traces, il
                    )
                else:  # per_tenant AMC tables, or a stateless baseline
                    streams = [gen(t) for t in traces]
                outs = [
                    _composite_outcome(t, s) for t, s in zip(traces, streams)
                ]
                shared_outs, lost = _share_llc(outs, il, spec.hierarchy)
                for k, t in enumerate(traces):
                    m = evaluate(
                        name,
                        t.profile,
                        shared_outs[k],
                        baseline_outcome=base_shared[k],
                        eval_from_pos=t.eval_from_pos,
                        issuer=1,
                    )
                    m.info = dict(streams[k].info)
                    serve_info = dict(
                        tenant=k,
                        rate=spec.tenants[k].rate,
                        policy=spec.policy,
                        **lost[k],
                        baseline_llc_demand_hits_lost=base_lost[k][
                            "llc_demand_hits_lost"
                        ],
                    )
                    for key, v in lost[k].items():
                        obs.inc(f"serve.{key}", float(v))
                    if table_counters is not None:
                        serve_info["shared_table"] = dict(
                            {
                                key: v
                                for key, v in table_counters.items()
                                if key != "per_tenant"
                            },
                            **table_counters["per_tenant"][k],
                        )
                    m.info["serve"] = serve_info
                    cells.append(
                        ServeCell(
                            tenant=k,
                            prefetcher=name,
                            table_mode=mode,
                            metrics=m,
                            spec=wspecs[k],
                        )
                    )
    return cells


def run_serve(
    spec: ServeSpec,
    prefetchers,
    cache=None,
    workers: Optional[int] = None,
    verbose: bool = False,
) -> "ServeResult":
    """Convenience wrapper: one serving scenario through Experiment."""
    from repro.core.experiment import Experiment

    exp = Experiment(workloads=[spec], prefetchers=prefetchers, cache=cache)
    result = exp.run(workers=workers, verbose=verbose)
    wspecs = spec.tenant_workloads()
    return ServeResult(
        spec=spec,
        cells=[
            ServeCell(
                tenant=c.tenant,
                prefetcher=c.prefetcher,
                table_mode=c.table_mode,
                metrics=c.metrics,
                spec=wspecs[c.tenant],
            )
            for c in result.cells
        ],
    )


@dataclasses.dataclass
class ServeResult:
    """Per-(tenant, prefetcher, mode) cells for one serving scenario."""

    spec: ServeSpec
    cells: List[ServeCell]

    def tenant_metrics(
        self, prefetcher: str, table_mode: Optional[str] = None
    ) -> List[PrefetchMetrics]:
        out = [
            c.metrics
            for c in sorted(self.cells, key=lambda c: c.tenant)
            if c.prefetcher == prefetcher and c.table_mode == table_mode
        ]
        if not out:
            have = sorted(
                {(c.prefetcher, c.table_mode) for c in self.cells},
                key=repr,
            )
            raise KeyError(
                f"({prefetcher!r}, {table_mode!r}) not in serve result; "
                f"have {have}"
            )
        return out

    def contention(self) -> dict:
        return contention_payload(self.spec, self.cells)


def contention_payload(spec: ServeSpec, cells: Sequence[ServeCell]) -> dict:
    """The ``serve-contention`` JSON document: per-tenant metric rows per
    (prefetcher, table mode) with the scenario's contention counters."""
    by_pf: Dict[str, Dict[str, List[ServeCell]]] = {}
    for c in cells:
        mode = c.table_mode if c.table_mode is not None else "stateless"
        by_pf.setdefault(c.prefetcher, {}).setdefault(mode, []).append(c)
    prefetchers = {}
    for name, by_mode in by_pf.items():
        modes = {}
        for mode, mode_cells in by_mode.items():
            mode_cells = sorted(mode_cells, key=lambda c: c.tenant)
            rows = [
                {
                    "tenant": c.tenant,
                    "kernel": c.spec.kernel,
                    "dataset": c.spec.dataset,
                    "seed": c.spec.seed,
                    "speedup": c.metrics.speedup,
                    "coverage": c.metrics.coverage,
                    "accuracy": c.metrics.accuracy,
                    "useful": c.metrics.useful,
                    "issued": c.metrics.issued,
                    "serve": c.metrics.info.get("serve"),
                }
                for c in mode_cells
            ]
            ms = [c.metrics for c in mode_cells]
            modes[mode] = {
                "per_tenant_rows": rows,
                "mean_coverage": float(np.mean([m.coverage for m in ms])),
                "mean_accuracy": float(np.mean([m.accuracy for m in ms])),
                "mean_speedup": float(np.mean([m.speedup for m in ms])),
            }
        prefetchers[name] = modes
    return {
        "schema": "serve-contention",
        "policy": spec.policy,
        "num_tenants": spec.num_tenants,
        "table_modes": list(spec.table_modes),
        "tenants": [
            {
                "kernel": t.kernel,
                "dataset": t.dataset,
                "seed": t.seed,
                "rate": t.rate,
            }
            for t in spec.tenants
        ],
        "prefetchers": prefetchers,
    }


__all__ = [
    "ServeCell",
    "ServeResult",
    "ServeSpec",
    "TABLE_MODES",
    "TenantSpec",
    "contention_payload",
    "run_serve",
    "score_serve",
]
