"""AdamW with per-config optimizer-state dtype (bf16 m/v for llama3-405b).

Functional: state is a pytree mirroring params; update returns new
(params, state). Written against plain pytrees so the whole train state
shards under pjit with the rules in :mod:`repro.models.sharding` (optimizer
state inherits its parameter's sharding — ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    dt = jnp.dtype(cfg.state_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def adamw_update(
    params: Any, grads: Any, state: Any, cfg: AdamWConfig, lr_scale=1.0
) -> Tuple[Any, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    step = state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m_new.astype(dt),
            v_new.astype(dt),
        )

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
