"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 1000+-node scale the inter-pod links are the gradient bottleneck
(DESIGN.md §5). Gradients are quantized to int8 with a per-tensor scale
before crossing the pod axis; the quantization residual is carried in an
error-feedback buffer so the compression is unbiased over time (EF-SGD
style — provably converges at the uncompressed rate).

Used by ``train.py --grad-compress``: psum(int8-dequantized grads) over the
"pod" axis only; the intra-pod reduction stays full precision.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_update(grads: Any, error_buf: Any) -> Tuple[Any, Any]:
    """Compress (grads + carried error); return (dequantized, new error)."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress_int8(target)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), (target - deq)

    out = jax.tree.map(one, grads, error_buf)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def init_error_buf(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
