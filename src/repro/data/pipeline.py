"""Deterministic sharded data pipeline.

Synthetic LM data (Zipf-distributed tokens over n-gram templates so the
loss actually decreases), generated *per host shard*: each data-parallel
host materializes only its slice, keyed by (seed, step, shard) — which also
makes restart-exactness trivial (the iterator is a pure function of the
step counter restored from the checkpoint, no iterator state to persist)
and keeps elastic rescale correct (reshard = re-slice by new shard count).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The (host-local) batch for one step — pure function of step."""
        per_shard = self.global_batch // self.num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard])
        )
        # Markov-ish structure: tokens = base zipf + learnable bigram echo.
        v = self.vocab_size
        base = rng.zipf(1.3, size=(per_shard, self.seq_len + 1)).astype(np.int64)
        base = np.minimum(base, v - 1)
        echo = np.roll(base, 1, axis=1)
        mix = rng.random((per_shard, self.seq_len + 1)) < 0.35
        toks = np.where(mix, (echo * 7 + 11) % v, base).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_iterator(data: SyntheticLMData, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield step, data.batch_at(step)
        step += 1
