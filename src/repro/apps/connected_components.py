"""Connected Components via label propagation (Ligra CC).

Every vertex starts in its own component; active vertices push their label,
destinations keep the min, and changed vertices stay active. On directed
input the graph is symmetrized (CC is an undirected notion), matching
Ligra's behavior.  Pull traversal reduces the same min over in-edges of the
symmetrized graph — labels are bit-identical (min is order-free).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ligra import AppRun, edge_endpoints, run_iterations, step_directions
from repro.apps.registry import register_kernel
from repro.graphs.csr import CSRGraph, symmetrize


@register_kernel(
    "cc",
    epoch_protocol="per_iteration",
    directions=("push", "pull", "auto"),
    description="Connected Components (label propagation; Ligra)",
)
def connected_components(
    graph: CSRGraph,
    max_iters: int = 100,
    present_mask: np.ndarray | None = None,
    direction: str = "push",
) -> AppRun:
    und = symmetrize(graph)
    n = und.num_vertices

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.asarray(und.degrees > 0)
    )
    big = jnp.float32(n + 1)

    def make_step(src_e, dst_e, _w):
        @partial(jax.jit, donate_argnums=())
        def step(state, frontier_mask):
            (labels,) = state
            msg = jnp.where(frontier_mask[src_e], labels[src_e], big)
            incoming = jax.ops.segment_min(msg, dst_e, num_segments=n)
            new_labels = jnp.minimum(labels, incoming)
            changed = (new_labels < labels) & present
            return (new_labels,), changed, ~jnp.any(changed)

        return step

    steps = {
        d: make_step(*edge_endpoints(und, d)) for d in step_directions(direction)
    }

    labels0 = jnp.where(
        present, jnp.arange(n, dtype=jnp.float32), big
    )
    init_mask = np.asarray(present)

    run = run_iterations(
        name="cc",
        graph=und,
        init_state=(labels0,),
        init_frontier_mask=init_mask,
        max_iters=max_iters,
        extract_values=lambda s: s[0],
        steps=steps,
        direction=direction,
    )
    return run
