"""Connected Components via label propagation (Ligra CC).

Every vertex starts in its own component; active vertices push their label,
destinations keep the min, and changed vertices stay active. On directed
input the graph is symmetrized (CC is an undirected notion), matching
Ligra's behavior.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ligra import AppRun, run_iterations
from repro.graphs.csr import CSRGraph, symmetrize


def connected_components(
    graph: CSRGraph,
    max_iters: int = 100,
    present_mask: np.ndarray | None = None,
) -> AppRun:
    und = symmetrize(graph)
    n = und.num_vertices
    offsets, neighbors, _, edge_src = und.device()

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.asarray(und.degrees > 0)
    )
    big = jnp.float32(n + 1)

    @partial(jax.jit, donate_argnums=())
    def step(state, frontier_mask):
        (labels,) = state
        msg = jnp.where(frontier_mask[edge_src], labels[edge_src], big)
        incoming = jax.ops.segment_min(msg, neighbors, num_segments=n)
        new_labels = jnp.minimum(labels, incoming)
        changed = (new_labels < labels) & present
        return (new_labels,), changed, ~jnp.any(changed)

    labels0 = jnp.where(
        present, jnp.arange(n, dtype=jnp.float32), big
    )
    init_mask = np.asarray(present)

    run = run_iterations(
        name="cc",
        graph=und,
        init_state=(labels0,),
        init_frontier_mask=init_mask,
        step_fn=step,
        max_iters=max_iters,
        extract_values=lambda s: s[0],
    )
    return run
