"""BellmanFord SSSP (Ligra) — push-based relaxation with change frontier."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.bfs import pick_root
from repro.apps.ligra import AppRun, run_iterations
from repro.graphs.csr import CSRGraph


def bellman_ford(
    graph: CSRGraph,
    root: int | None = None,
    max_iters: int = 200,
    present_mask: np.ndarray | None = None,
) -> AppRun:
    n = graph.num_vertices
    offsets, neighbors, weights, edge_src = graph.device()
    if root is None:
        root = pick_root(graph, present_mask)

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.ones(n, dtype=bool)
    )
    inf = jnp.float32(3.0e38)

    @partial(jax.jit, donate_argnums=())
    def step(state, frontier_mask):
        (dist,) = state
        cand = jnp.where(frontier_mask[edge_src], dist[edge_src] + weights, inf)
        best = jax.ops.segment_min(cand, neighbors, num_segments=n)
        improved = (best < dist) & present
        new_dist = jnp.where(improved, best, dist)
        return (new_dist,), improved, ~jnp.any(improved)

    dist0 = jnp.full(n, inf, dtype=jnp.float32)
    dist0 = dist0.at[root].set(0.0)
    init_mask = np.zeros(n, dtype=bool)
    init_mask[root] = True

    return run_iterations(
        name="bellmanford",
        graph=graph,
        init_state=(dist0,),
        init_frontier_mask=init_mask,
        step_fn=step,
        max_iters=max_iters,
        extract_values=lambda s: s[0],
    )
