"""BellmanFord SSSP (Ligra) — edge relaxation with a change frontier.

Push relaxes out-edges of changed vertices; pull scans in-edges per
destination (weights ride the CSC transpose).  Distances are identical in
either direction (min is order-free).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.bfs import pick_root
from repro.apps.ligra import AppRun, edge_endpoints, run_iterations, step_directions
from repro.apps.registry import register_kernel
from repro.graphs.csr import CSRGraph


@register_kernel(
    "bellmanford",
    weighted=True,
    epoch_protocol="per_run",
    needs_root=True,
    directions=("push", "pull", "auto"),
    description="BellmanFord SSSP (run twice on evolving inputs)",
)
def bellman_ford(
    graph: CSRGraph,
    root: int | None = None,
    max_iters: int = 200,
    present_mask: np.ndarray | None = None,
    direction: str = "push",
) -> AppRun:
    n = graph.num_vertices
    if root is None:
        root = pick_root(graph, present_mask)

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.ones(n, dtype=bool)
    )
    inf = jnp.float32(3.0e38)

    def make_step(src_e, dst_e, w_e):
        @partial(jax.jit, donate_argnums=())
        def step(state, frontier_mask):
            (dist,) = state
            cand = jnp.where(frontier_mask[src_e], dist[src_e] + w_e, inf)
            best = jax.ops.segment_min(cand, dst_e, num_segments=n)
            improved = (best < dist) & present
            new_dist = jnp.where(improved, best, dist)
            return (new_dist,), improved, ~jnp.any(improved)

        return step

    steps = {
        d: make_step(*edge_endpoints(graph, d)) for d in step_directions(direction)
    }

    dist0 = jnp.full(n, inf, dtype=jnp.float32)
    dist0 = dist0.at[root].set(0.0)
    init_mask = np.zeros(n, dtype=bool)
    init_mask[root] = True

    return run_iterations(
        name="bellmanford",
        graph=graph,
        init_state=(dist0,),
        init_frontier_mask=init_mask,
        max_iters=max_iters,
        extract_values=lambda s: s[0],
        steps=steps,
        direction=direction,
    )
