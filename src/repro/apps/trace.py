"""Memory-trace generation: app run -> access streams, both directions.

**Push (sparse EDGEMAP, Fig 3).**  For every active source vertex v
(processed in frontier order, as Ligra's sparse vertexSubset does):

    F[v]          frontier check                   (frontier array)
    T[v]          target read (delta/label/dist)   (TARGET data structure)
    V[v], V[v+1]  CSR row bounds (same line or adjacent)
    for e in row(v):  N[e]   edge read
                      P[dst] neighbor property update   <- the misses

**Pull (dense EDGEMAP).**  A dense iteration first scans the frontier
bitmap sequentially (Ligra materializes the dense vertexSubset), then
every destination vertex d walks its in-edge row:

    F[0..n-1]     dense frontier scan              (sequential)
    per d: T[d]   own target read                  (sequential)
           V[d]   CSC row bound                    (sequential)
           for e in in_row(d):  NI[e]    in-edge read   (sequential)
                                P[src]   source-property gather  <- the misses

Direction changes the miss *structure* AMC sees: push scatters property
writes to destinations behind sequential out-edge reads; pull scatters
property reads from sources behind sequential in-edge reads, with the
frontier/target/offset streams turning fully sequential.  A
direction-optimizing run (``bfs_do``) alternates the two modalities.

The paper's AMC registers mark T's range (AddrTBase) and F's range
(AddrFBase); everything is emitted as *addresses* so range filtering happens
exactly as in hardware. Element sizes: F 1B (ligra bool frontier), T 8B,
V 8B, N 4B, P 8B, NI 4B; arrays live in disjoint page-aligned regions.

**Emitters.**  :func:`trace_run` emits a whole run as one
:class:`RunTrace` — boundary-offset arrays over one concatenated stream.
Two emitters (pick with ``REPRO_TRACE_EMITTER``, :func:`set_emitter`, or
:func:`use_emitter`), mirroring the cache-engine pattern in
:mod:`repro.memsim.engine`:

- ``batched`` (default): one vectorized pass over all iterations — the
  concatenated-frontier cumsum layout per run, no per-iteration Python
  loop.  Bit-identical to the reference (test- and bench-gated).
- ``reference``: the original per-iteration path
  (:func:`trace_app_run` + concatenation), kept as the correctness oracle.

Traces are numpy struct-of-arrays; the cache simulator consumes the 64-bit
block ids.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.apps.ligra import AppRun
from repro.graphs.csr import CSRGraph

BLOCK_BITS = 6  # 64B lines
PAGE_BITS = 12  # 4KB pages

# array id -> (symbol, element size in bytes)
ARRAYS: Dict[int, tuple] = {
    0: ("F", 1),  # frontier bitmap
    1: ("T", 8),  # target (delta / label / dist) -- AddrTBase range
    2: ("V", 8),  # CSR/CSC offsets (of the traversal direction in use)
    3: ("N", 4),  # out-edge/neighbor array (push traversal)
    4: ("P", 8),  # vertex property (push destination / pull source)
    5: ("NI", 4),  # in-edge array (pull traversal; the CSC neighbor list)
}
F_ID, T_ID, V_ID, N_ID, P_ID, NI_ID = 0, 1, 2, 3, 4, 5

# The paper's application input footprint (V+N+P+F+T) — the storage-overhead
# denominator.  The in-edge array is a runtime-derived view (Ligra builds it
# from the input), so it is addressable but not counted as input.
_INPUT_ARRAY_IDS = (F_ID, T_ID, V_ID, N_ID, P_ID)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Address-space layout for one app instance."""

    num_vertices: int
    num_edges: int
    base: int = 0x1000_0000

    def _sizes(self) -> Dict[int, int]:
        return {
            F_ID: self.num_vertices * 1,
            T_ID: self.num_vertices * 8,
            V_ID: (self.num_vertices + 1) * 8,
            N_ID: self.num_edges * 4,
            P_ID: self.num_vertices * 8,
            NI_ID: self.num_edges * 4,
        }

    def region(self, array_id: int) -> tuple:
        """(base_addr, size_bytes) for an array, page aligned regions.

        Regions are laid out in array-id order, so appending NI after P
        left every pre-existing (push) address unchanged.
        """
        sizes = self._sizes()
        addr = self.base
        for aid in range(array_id):
            size = sizes[aid]
            pages = -(-size // (1 << PAGE_BITS)) + 1  # +1 guard page
            addr += pages << PAGE_BITS
        return addr, sizes[array_id]

    @functools.cached_property
    def _addr_lut(self) -> tuple:
        """(bases, elem_sizes) int64 lookup tables indexed by array id."""
        ids = sorted(ARRAYS)
        bases = np.array([self.region(a)[0] for a in ids], dtype=np.int64)
        esize = np.array([ARRAYS[a][1] for a in ids], dtype=np.int64)
        return bases, esize

    def addr(self, array_id: np.ndarray, elem: np.ndarray) -> np.ndarray:
        """Byte address per access — one lookup-table-indexed expression
        (base[id] + elem * elem_size[id]) instead of a per-array Python
        loop; bit-identical to the loop it replaced."""
        bases, esize = self._addr_lut
        aid = np.asarray(array_id, dtype=np.int64)
        return bases[aid] + np.asarray(elem, dtype=np.int64) * esize[aid]

    @property
    def target_range(self) -> tuple:
        return self.region(T_ID)

    @property
    def frontier_range(self) -> tuple:
        return self.region(F_ID)

    @property
    def input_bytes(self) -> int:
        """Application input footprint (V+N+P+F+T) for storage-overhead %."""
        return sum(self.region(a)[1] for a in _INPUT_ARRAY_IDS)


@dataclasses.dataclass
class IterationTrace:
    """One iteration's access stream (struct of arrays)."""

    array_id: np.ndarray  # int8
    elem: np.ndarray  # int64 element index
    addr: np.ndarray  # int64 byte address
    block: np.ndarray  # int64 cache-line id (addr >> 6)
    src_vertex: np.ndarray  # int64: vertex whose processing owns this access
    iteration: int

    def __len__(self) -> int:
        return len(self.addr)

    @property
    def is_target(self) -> np.ndarray:
        return self.array_id == T_ID

    @property
    def is_frontier(self) -> np.ndarray:
        return self.array_id == F_ID


@dataclasses.dataclass
class RunTrace:
    """A whole app run's access stream: one concatenated struct-of-arrays
    with per-iteration boundary offsets (``iter_bounds[i] : iter_bounds[i+1]``
    is iteration ``i``'s slice)."""

    array_id: np.ndarray  # int8
    elem: np.ndarray  # int64
    addr: np.ndarray  # int64
    block: np.ndarray  # int64
    src_vertex: np.ndarray  # int64
    iter_bounds: np.ndarray  # int64, (num_iters + 1,)
    directions: List[str]  # per-iteration traversal direction

    def __len__(self) -> int:
        return len(self.addr)

    @property
    def num_iters(self) -> int:
        return len(self.iter_bounds) - 1

    @property
    def iter_sizes(self) -> np.ndarray:
        return np.diff(self.iter_bounds)

    def iteration(self, i: int) -> IterationTrace:
        """Zero-copy view of one iteration's slice."""
        lo, hi = self.iter_bounds[i], self.iter_bounds[i + 1]
        return IterationTrace(
            array_id=self.array_id[lo:hi],
            elem=self.elem[lo:hi],
            addr=self.addr[lo:hi],
            block=self.block[lo:hi],
            src_vertex=self.src_vertex[lo:hi],
            iteration=i,
        )


# ------------------------------------------------------ emitter selection

EMITTERS = ("batched", "reference")
EMITTER_ENV = "REPRO_TRACE_EMITTER"
DEFAULT_EMITTER = "batched"

_emitter_override: Optional[str] = None


def _check_emitter(name: str) -> str:
    if name not in EMITTERS:
        raise ValueError(f"unknown trace emitter {name!r}; choose from {EMITTERS}")
    return name


def current_emitter() -> str:
    """The active emitter: ``set_emitter`` override > env var > default."""
    if _emitter_override is not None:
        return _emitter_override
    return _check_emitter(os.environ.get(EMITTER_ENV, DEFAULT_EMITTER))


def set_emitter(name: Optional[str]) -> None:
    """Select the trace emitter process-wide (``None`` restores env/default)."""
    global _emitter_override
    _emitter_override = _check_emitter(name) if name is not None else None


@contextlib.contextmanager
def use_emitter(name: str) -> Iterator[None]:
    """Run the enclosed block under a specific trace emitter."""
    global _emitter_override
    prev, _emitter_override = _emitter_override, _check_emitter(name)
    try:
        yield
    finally:
        _emitter_override = prev


# -------------------------------------------------- per-iteration (reference)


def _iteration_trace(
    graph: CSRGraph, active: np.ndarray, cfg: TraceConfig, iteration: int
) -> IterationTrace:
    """Sparse (push) iteration: frontier-ordered per-source blocks."""
    offsets = graph.offsets
    neighbors = graph.neighbors
    k = len(active)
    deg = (offsets[active + 1] - offsets[active]).astype(np.int64)
    e_total = int(deg.sum())
    lengths = 3 + 2 * deg  # F,T,V headers + interleaved N,P
    starts = np.zeros(k, dtype=np.int64)
    np.cumsum(lengths[:-1], out=starts[1:])
    total = int(lengths.sum())

    array_id = np.empty(total, dtype=np.int8)
    elem = np.empty(total, dtype=np.int64)
    src_vertex = np.empty(total, dtype=np.int64)

    # Headers.
    array_id[starts] = F_ID
    array_id[starts + 1] = T_ID
    array_id[starts + 2] = V_ID
    for off in range(3):
        elem[starts + off] = active
        src_vertex[starts + off] = active

    if e_total:
        owner = np.repeat(np.arange(k, dtype=np.int64), deg)
        e_rank = np.arange(e_total, dtype=np.int64)
        deg_cum = np.zeros(k, dtype=np.int64)
        np.cumsum(deg[:-1], out=deg_cum[1:])
        j = e_rank - deg_cum[owner]  # edge index within the vertex row
        edge_global = offsets[active[owner]] + j  # position in N array
        dsts = neighbors[edge_global]
        pos_n = starts[owner] + 3 + 2 * j
        pos_p = pos_n + 1
        array_id[pos_n] = N_ID
        elem[pos_n] = edge_global
        src_vertex[pos_n] = active[owner]
        array_id[pos_p] = P_ID
        elem[pos_p] = dsts
        src_vertex[pos_p] = active[owner]

    addr = cfg.addr(array_id, elem)
    return IterationTrace(
        array_id=array_id,
        elem=elem,
        addr=addr,
        block=addr >> BLOCK_BITS,
        src_vertex=src_vertex,
        iteration=iteration,
    )


def _pull_body(graph: CSRGraph) -> tuple:
    """The (array_id, elem, src_vertex) arrays of one dense iteration.

    A dense (pull) iteration's access stream is frontier-independent — the
    full bitmap scan plus every destination's complete in-edge walk — so
    the body is built once per graph and cached on the instance; only the
    address mapping (and the owning iteration id) varies per use.
    """
    cached = graph.__dict__.get("_pull_trace_body")
    if cached is not None:
        return cached
    t = graph.transpose()
    n = graph.num_vertices
    m = t.num_edges
    indeg = t.degrees.astype(np.int64)
    vid = np.arange(n, dtype=np.int64)
    lengths = 2 + 2 * indeg  # T,V headers + interleaved NI,P per destination
    starts = np.empty(n, dtype=np.int64)
    starts[0] = n  # per-destination blocks follow the n-long frontier scan
    np.cumsum(lengths[:-1], out=starts[1:])
    starts[1:] += n
    total = n + int(lengths.sum())

    array_id = np.empty(total, dtype=np.int8)
    elem = np.empty(total, dtype=np.int64)
    src_vertex = np.empty(total, dtype=np.int64)

    # Dense frontier scan: F[0..n-1], sequential.
    array_id[:n] = F_ID
    elem[:n] = vid
    src_vertex[:n] = vid

    # Per-destination headers: own target read + CSC row bound.
    array_id[starts] = T_ID
    array_id[starts + 1] = V_ID
    for off in range(2):
        elem[starts + off] = vid
        src_vertex[starts + off] = vid

    if m:
        owner = np.repeat(vid, indeg)  # destination d per in-edge
        e_rank = np.arange(m, dtype=np.int64)  # CSC in-edge positions
        j = e_rank - np.repeat(t.offsets[:-1].astype(np.int64), indeg)
        pos_ni = starts[owner] + 2 + 2 * j
        pos_p = pos_ni + 1
        array_id[pos_ni] = NI_ID
        elem[pos_ni] = e_rank
        src_vertex[pos_ni] = owner
        array_id[pos_p] = P_ID
        elem[pos_p] = t.neighbors[e_rank].astype(np.int64)  # source gather
        src_vertex[pos_p] = owner

    body = (array_id, elem, src_vertex)
    object.__setattr__(graph, "_pull_trace_body", body)
    return body


def _iteration_trace_pull(
    graph: CSRGraph, active: np.ndarray, cfg: TraceConfig, iteration: int
) -> IterationTrace:
    """Dense (pull) iteration — ``active`` does not shape the stream (the
    dense EDGEMAP scans everything); it is accepted for signature symmetry."""
    array_id, elem, src_vertex = _pull_body(graph)
    addr = cfg.addr(array_id, elem)
    return IterationTrace(
        array_id=array_id,
        elem=elem,
        addr=addr,
        block=addr >> BLOCK_BITS,
        src_vertex=src_vertex,
        iteration=iteration,
    )


def trace_app_run(run: AppRun, cfg: TraceConfig | None = None) -> List[IterationTrace]:
    """Per-iteration traces for an app run (the reference emitter's path)."""
    g = run.graph
    cfg = cfg or TraceConfig(num_vertices=g.num_vertices, num_edges=g.num_edges)
    dirs = run.iteration_directions()
    return [
        (_iteration_trace_pull if d == "pull" else _iteration_trace)(g, f, cfg, i)
        for i, (f, d) in enumerate(zip(run.frontiers, dirs))
    ]


# ------------------------------------------------------- whole-run emission


def trace_run(run: AppRun, cfg: TraceConfig | None = None) -> RunTrace:
    """Emit the whole run's access stream under the active emitter."""
    g = run.graph
    cfg = cfg or TraceConfig(num_vertices=g.num_vertices, num_edges=g.num_edges)
    if current_emitter() == "reference":
        return _trace_run_reference(run, cfg)
    return _trace_run_batched(run, cfg)


def _trace_run_reference(run: AppRun, cfg: TraceConfig) -> RunTrace:
    """Reference oracle: per-iteration traces, concatenated."""
    traces = trace_app_run(run, cfg)
    sizes = np.array([len(t) for t in traces], dtype=np.int64)
    bounds = np.zeros(len(traces) + 1, dtype=np.int64)
    np.cumsum(sizes, out=bounds[1:])

    def cat(field: str, dtype) -> np.ndarray:
        if not traces:
            return np.zeros(0, dtype=dtype)
        return np.concatenate([getattr(t, field) for t in traces])

    return RunTrace(
        array_id=cat("array_id", np.int8),
        elem=cat("elem", np.int64),
        addr=cat("addr", np.int64),
        block=cat("block", np.int64),
        src_vertex=cat("src_vertex", np.int64),
        iter_bounds=bounds,
        directions=list(run.iteration_directions()),
    )


def _pull_body_addressed(graph: CSRGraph, cfg: TraceConfig) -> tuple:
    """The dense-iteration body with its (addr, block) arrays, cached per
    (graph, layout).  A direction-optimizing run replays the same dense
    body every pull iteration; the reference emitter recomputes its
    addresses each time, the batched emitter maps them exactly once."""
    key = (cfg.num_vertices, cfg.num_edges, cfg.base)
    # Single-slot per graph: one address layout is live at a time (a graph
    # is traced under one TraceConfig), and the slot is ~40 B per access —
    # an unbounded per-layout dict would pin that for every layout ever
    # used on a long-lived graph.
    cached = graph.__dict__.get("_pull_trace_addressed")
    if cached is not None and cached[0] == key:
        return cached[1]
    array_id, elem, src_vertex = _pull_body(graph)
    addr = cfg.addr(array_id, elem)
    hit = (array_id, elem, src_vertex, addr, addr >> BLOCK_BITS)
    object.__setattr__(graph, "_pull_trace_addressed", (key, hit))
    return hit


def _trace_run_batched(run: AppRun, cfg: TraceConfig) -> RunTrace:
    """One vectorized pass over the whole run.

    Push iterations are emitted from ONE concatenated frontier: a single
    cumsum assigns every active vertex its block start, then the same
    header/edge fill as the per-iteration path runs once over all
    iterations, and one address-mapping pass covers every push access.
    Dense (pull) iterations tile the cached, pre-addressed per-graph body
    — their addresses are computed once per (graph, layout) instead of
    once per iteration.  Bit-identical to the reference emitter by
    construction and by test.
    """
    g = run.graph
    offsets = g.offsets
    neighbors = g.neighbors
    frontiers = run.frontiers
    dirs = run.iteration_directions()
    iters = len(frontiers)

    pull_iters = [i for i, d in enumerate(dirs) if d == "pull"]
    push_iters = [i for i, d in enumerate(dirs) if d != "pull"]

    pull = _pull_body_addressed(g, cfg) if pull_iters else None
    pull_len = len(pull[0]) if pull is not None else 0

    # Concatenate every push frontier; per-iteration boundaries via cumsum.
    k_per = np.array([len(frontiers[i]) for i in push_iters], dtype=np.int64)
    active_all = (
        np.concatenate([frontiers[i] for i in push_iters])
        if push_iters
        else np.zeros(0, dtype=np.int64)
    ).astype(np.int64)
    deg_all = (offsets[active_all + 1] - offsets[active_all]).astype(np.int64)
    lengths_v = 3 + 2 * deg_all  # per-vertex block length (push)
    # Exclusive cumsum over all push vertices and per-iteration vertex bounds.
    cum_v = np.zeros(len(active_all) + 1, dtype=np.int64)
    np.cumsum(lengths_v, out=cum_v[1:])
    f_bounds = np.zeros(len(push_iters) + 1, dtype=np.int64)
    np.cumsum(k_per, out=f_bounds[1:])
    push_total = int(cum_v[-1])

    # Global iteration boundary offsets.
    iter_sizes = np.zeros(iters, dtype=np.int64)
    for rank, i in enumerate(push_iters):
        iter_sizes[i] = cum_v[f_bounds[rank + 1]] - cum_v[f_bounds[rank]]
    for i in pull_iters:
        iter_sizes[i] = pull_len
    iter_bounds = np.zeros(iters + 1, dtype=np.int64)
    np.cumsum(iter_sizes, out=iter_bounds[1:])
    total = int(iter_bounds[-1])

    # --- one fill pass over the concatenated push iterations -------------
    # Addresses are scattered directly per segment (base[id] + elem * esz
    # fused into each write) — same arithmetic as ``cfg.addr``, without its
    # whole-stream array-id gather passes.
    bases, esize = cfg._addr_lut
    p_aid = np.empty(push_total, dtype=np.int8)
    p_elem = np.empty(push_total, dtype=np.int64)
    p_src = np.empty(push_total, dtype=np.int64)
    p_addr = np.empty(push_total, dtype=np.int64)
    if len(active_all):
        starts = cum_v[:-1]  # each vertex's block start in the push concat
        p_aid[starts] = F_ID
        p_aid[starts + 1] = T_ID
        p_aid[starts + 2] = V_ID
        for off, aid in zip(range(3), (F_ID, T_ID, V_ID)):
            p_elem[starts + off] = active_all
            p_src[starts + off] = active_all
            p_addr[starts + off] = bases[aid] + active_all * esize[aid]

        e_total = int(deg_all.sum())
        if e_total:
            owner = np.repeat(np.arange(len(active_all), dtype=np.int64), deg_all)
            e_rank = np.arange(e_total, dtype=np.int64)
            deg_cum = np.zeros(len(active_all), dtype=np.int64)
            np.cumsum(deg_all[:-1], out=deg_cum[1:])
            j = e_rank - deg_cum[owner]  # edge index within the vertex row
            edge_global = offsets[active_all[owner]] + j
            dsts = neighbors[edge_global].astype(np.int64)
            own_src = active_all[owner]
            pos_n = starts[owner] + 3 + 2 * j
            pos_p = pos_n + 1
            p_aid[pos_n] = N_ID
            p_elem[pos_n] = edge_global
            p_src[pos_n] = own_src
            p_addr[pos_n] = bases[N_ID] + edge_global * esize[N_ID]
            p_aid[pos_p] = P_ID
            p_elem[pos_p] = dsts
            p_src[pos_p] = own_src
            p_addr[pos_p] = bases[P_ID] + dsts * esize[P_ID]
    p_block = p_addr >> BLOCK_BITS

    if not pull_iters:
        # Pure push run: the concatenation IS the whole trace — no copy.
        return RunTrace(
            array_id=p_aid,
            elem=p_elem,
            addr=p_addr,
            block=p_block,
            src_vertex=p_src,
            iter_bounds=iter_bounds,
            directions=list(dirs),
        )

    # --- mixed-direction run: assemble iteration slices ------------------
    array_id = np.empty(total, dtype=np.int8)
    elem = np.empty(total, dtype=np.int64)
    addr = np.empty(total, dtype=np.int64)
    block = np.empty(total, dtype=np.int64)
    src_vertex = np.empty(total, dtype=np.int64)
    out_arrays = (array_id, elem, src_vertex, addr, block)
    push_src = (p_aid, p_elem, p_src, p_addr, p_block)
    for i in pull_iters:
        lo = iter_bounds[i]
        for dst, src in zip(out_arrays, pull):
            dst[lo : lo + pull_len] = src
    for rank, i in enumerate(push_iters):
        lo, hi = iter_bounds[i], iter_bounds[i + 1]
        slo = cum_v[f_bounds[rank]]
        for dst, src in zip(out_arrays, push_src):
            dst[lo:hi] = src[slo : slo + (hi - lo)]
    return RunTrace(
        array_id=array_id,
        elem=elem,
        addr=addr,
        block=block,
        src_vertex=src_vertex,
        iter_bounds=iter_bounds,
        directions=list(dirs),
    )


def concat_traces(traces: List[IterationTrace], epoch_of=None):
    """Flatten to (block, array_id, epoch_id, elem) arrays for the simulator.

    ``epoch_of`` maps an iteration index to its AMC epoch (identity by
    default; per-run-protocol kernels group a whole run into one epoch).
    """
    block = np.concatenate([t.block for t in traces])
    array_id = np.concatenate([t.array_id for t in traces])
    elem = np.concatenate([t.elem for t in traces])
    epoch_of = epoch_of or (lambda i: i)
    iter_id = np.concatenate(
        [np.full(len(t), epoch_of(t.iteration), dtype=np.int32) for t in traces]
    )
    return block, array_id, iter_id, elem


def iteration_access_counts(run: AppRun, cfg: TraceConfig | None = None) -> np.ndarray:
    """Exact per-iteration access counts of ``trace_run`` without emitting.

    Push iteration ``i`` touches ``3 * |frontier|`` vertex-array slots plus
    ``2`` per outgoing edge of the frontier; a pull iteration reads the
    whole ``(frontier byte, offsets, neighbor+value)`` body: ``3n + 2m``.
    Used by :func:`iter_run_trace_chunks` to group iterations, and by the
    sharded builder to locate run boundaries without a whole-run trace.
    """
    g = run.graph
    offsets = g.offsets.astype(np.int64)
    pull_len = 3 * g.num_vertices + 2 * g.num_edges
    sizes = np.zeros(len(run.frontiers), dtype=np.int64)
    for i, (f, d) in enumerate(zip(run.frontiers, run.iteration_directions())):
        if d == "pull":
            sizes[i] = pull_len
        else:
            deg = offsets[np.asarray(f) + 1] - offsets[np.asarray(f)]
            sizes[i] = 3 * len(f) + 2 * int(deg.sum())
    return sizes


def iter_run_trace_chunks(
    run: AppRun, cfg: TraceConfig | None = None, max_accesses: int = 1 << 22
) -> Iterator[tuple]:
    """Yield ``(start_iteration, RunTrace)`` chunks covering ``run``.

    Whole iterations are grouped greedily up to ``max_accesses`` (a single
    iteration larger than the cap forms its own chunk), and each group is
    emitted through the active emitter on an iteration-sliced copy of the
    run.  Both emitters are per-iteration independent (the batched path's
    concatenated-frontier gather produces each iteration's slice from that
    iteration's frontier alone, and the pull body is a per-graph constant),
    so the concatenation of the yielded chunk streams is bit-identical to
    ``trace_run(run, cfg)`` — the whole-run trace never has to exist in
    memory at once.
    """
    g = run.graph
    cfg = cfg or TraceConfig(num_vertices=g.num_vertices, num_edges=g.num_edges)
    n_iters = len(run.frontiers)
    if n_iters == 0:
        yield 0, trace_run(run, cfg)
        return
    sizes = iteration_access_counts(run, cfg)
    dirs = run.iteration_directions()
    i0 = 0
    while i0 < n_iters:
        i1 = i0 + 1
        acc = int(sizes[i0])
        while i1 < n_iters and acc + int(sizes[i1]) <= max_accesses:
            acc += int(sizes[i1])
            i1 += 1
        sub = dataclasses.replace(
            run,
            frontiers=run.frontiers[i0:i1],
            directions=None if run.directions is None else dirs[i0:i1],
            num_iters=i1 - i0,
        )
        yield i0, trace_run(sub, cfg)
        i0 = i1
