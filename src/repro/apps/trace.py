"""Memory-trace generation: app run -> per-iteration access streams (Fig 3).

For every active source vertex v (processed in frontier order, as Ligra's
sparse vertexSubset does) the per-vertex access pattern of a push-based
kernel is:

    F[v]          frontier check                   (frontier array)
    T[v]          target read (delta/label/dist)   (TARGET data structure)
    V[v], V[v+1]  CSR row bounds (same line or adjacent)
    for e in row(v):  N[e]   edge read
                      P[dst] neighbor property update   <- the misses

The paper's AMC registers mark T's range (AddrTBase) and F's range
(AddrFBase); everything is emitted as *addresses* so range filtering happens
exactly as in hardware. Element sizes: F 1B (ligra bool frontier), T 8B,
V 8B, N 4B, P 8B; arrays live in disjoint page-aligned regions.

Traces are numpy struct-of-arrays; the cache simulator consumes the 64-bit
block ids.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.apps.ligra import AppRun
from repro.graphs.csr import CSRGraph

BLOCK_BITS = 6  # 64B lines
PAGE_BITS = 12  # 4KB pages

# array id -> (symbol, element size in bytes)
ARRAYS: Dict[int, tuple] = {
    0: ("F", 1),  # frontier bitmap
    1: ("T", 8),  # target (delta / label / dist) -- AddrTBase range
    2: ("V", 8),  # CSR offsets
    3: ("N", 4),  # edge/neighbor array
    4: ("P", 8),  # vertex property (push destination)
}
F_ID, T_ID, V_ID, N_ID, P_ID = 0, 1, 2, 3, 4


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Address-space layout for one app instance."""

    num_vertices: int
    num_edges: int
    base: int = 0x1000_0000

    def region(self, array_id: int) -> tuple:
        """(base_addr, size_bytes) for an array, page aligned regions."""
        sizes = {
            F_ID: self.num_vertices * 1,
            T_ID: self.num_vertices * 8,
            V_ID: (self.num_vertices + 1) * 8,
            N_ID: self.num_edges * 4,
            P_ID: self.num_vertices * 8,
        }
        addr = self.base
        for aid in range(array_id):
            size = sizes[aid]
            pages = -(-size // (1 << PAGE_BITS)) + 1  # +1 guard page
            addr += pages << PAGE_BITS
        return addr, sizes[array_id]

    def addr(self, array_id: np.ndarray, elem: np.ndarray) -> np.ndarray:
        out = np.zeros(len(elem), dtype=np.int64)
        for aid, (_, esz) in ARRAYS.items():
            base, _ = self.region(aid)
            sel = array_id == aid
            out[sel] = base + elem[sel].astype(np.int64) * esz
        return out

    @property
    def target_range(self) -> tuple:
        return self.region(T_ID)

    @property
    def frontier_range(self) -> tuple:
        return self.region(F_ID)

    @property
    def input_bytes(self) -> int:
        """Application input footprint (V+N+P+F+T) for storage-overhead %."""
        return sum(self.region(a)[1] for a in ARRAYS)


@dataclasses.dataclass
class IterationTrace:
    """One iteration's access stream (struct of arrays)."""

    array_id: np.ndarray  # int8
    elem: np.ndarray  # int64 element index
    addr: np.ndarray  # int64 byte address
    block: np.ndarray  # int64 cache-line id (addr >> 6)
    src_vertex: np.ndarray  # int64: active source vertex owning this access
    iteration: int

    def __len__(self) -> int:
        return len(self.addr)

    @property
    def is_target(self) -> np.ndarray:
        return self.array_id == T_ID

    @property
    def is_frontier(self) -> np.ndarray:
        return self.array_id == F_ID


def _iteration_trace(
    graph: CSRGraph, active: np.ndarray, cfg: TraceConfig, iteration: int
) -> IterationTrace:
    offsets = graph.offsets
    neighbors = graph.neighbors
    k = len(active)
    deg = (offsets[active + 1] - offsets[active]).astype(np.int64)
    e_total = int(deg.sum())
    lengths = 3 + 2 * deg  # F,T,V headers + interleaved N,P
    starts = np.zeros(k, dtype=np.int64)
    np.cumsum(lengths[:-1], out=starts[1:])
    total = int(lengths.sum())

    array_id = np.empty(total, dtype=np.int8)
    elem = np.empty(total, dtype=np.int64)
    src_vertex = np.empty(total, dtype=np.int64)

    # Headers.
    array_id[starts] = F_ID
    array_id[starts + 1] = T_ID
    array_id[starts + 2] = V_ID
    for off in range(3):
        elem[starts + off] = active
        src_vertex[starts + off] = active

    if e_total:
        owner = np.repeat(np.arange(k, dtype=np.int64), deg)
        e_rank = np.arange(e_total, dtype=np.int64)
        deg_cum = np.zeros(k, dtype=np.int64)
        np.cumsum(deg[:-1], out=deg_cum[1:])
        j = e_rank - deg_cum[owner]  # edge index within the vertex row
        edge_global = offsets[active[owner]] + j  # position in N array
        dsts = neighbors[edge_global]
        pos_n = starts[owner] + 3 + 2 * j
        pos_p = pos_n + 1
        array_id[pos_n] = N_ID
        elem[pos_n] = edge_global
        src_vertex[pos_n] = active[owner]
        array_id[pos_p] = P_ID
        elem[pos_p] = dsts
        src_vertex[pos_p] = active[owner]

    addr = cfg.addr(array_id, elem)
    return IterationTrace(
        array_id=array_id,
        elem=elem,
        addr=addr,
        block=addr >> BLOCK_BITS,
        src_vertex=src_vertex,
        iteration=iteration,
    )


def trace_app_run(run: AppRun, cfg: TraceConfig | None = None) -> List[IterationTrace]:
    """Generate the per-iteration traces for an app run."""
    g = run.graph
    cfg = cfg or TraceConfig(num_vertices=g.num_vertices, num_edges=g.num_edges)
    return [
        _iteration_trace(g, f, cfg, i) for i, f in enumerate(run.frontiers)
    ]


def concat_traces(traces: List[IterationTrace], epoch_of=None):
    """Flatten to (block, array_id, epoch_id, elem) arrays for the simulator.

    ``epoch_of`` maps an iteration index to its AMC epoch (identity by
    default; BFS/BellmanFord group a whole run into one epoch).
    """
    block = np.concatenate([t.block for t in traces])
    array_id = np.concatenate([t.array_id for t in traces])
    elem = np.concatenate([t.elem for t in traces])
    epoch_of = epoch_of or (lambda i: i)
    iter_id = np.concatenate(
        [np.full(len(t), epoch_of(t.iteration), dtype=np.int32) for t in traces]
    )
    return block, array_id, iter_id, elem
