"""Breadth-First Search (Ligra BFS) — frontier-parallel parent assignment.

For the evolving-graph protocol the kernel is run twice (run-1 / run-2
inputs from :mod:`repro.graphs.evolve`); the paper evaluates the second run.

Registered as ``bfs`` (push) with a ``bfs_do`` variant running Ligra's
direction-optimizing switch: wide middle levels go dense (pull over
in-edges), narrow head/tail levels stay sparse (push) — the hybrid whose
modality changes mid-run are exactly what phase-aware prefetcher analysis
targets.  Parents are identical in every direction (min-id offer wins).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ligra import AppRun, edge_endpoints, run_iterations, step_directions
from repro.apps.registry import register_kernel, register_kernel_variant
from repro.graphs.csr import CSRGraph


def pick_root(graph: CSRGraph, present_mask: np.ndarray | None = None) -> int:
    """Deterministic root: highest out-degree present vertex."""
    deg = graph.degrees.copy()
    if present_mask is not None:
        deg = np.where(present_mask, deg, -1)
    return int(np.argmax(deg))


@register_kernel(
    "bfs",
    epoch_protocol="per_run",
    needs_root=True,
    directions=("push", "pull", "auto"),
    description="Breadth-First Search (run twice on evolving inputs)",
)
def bfs(
    graph: CSRGraph,
    root: int | None = None,
    max_iters: int = 200,
    present_mask: np.ndarray | None = None,
    direction: str = "push",
) -> AppRun:
    n = graph.num_vertices
    if root is None:
        root = pick_root(graph, present_mask)

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.ones(n, dtype=bool)
    )
    big = jnp.float32(n + 1)

    def make_step(src_e, dst_e, _w):
        @partial(jax.jit, donate_argnums=())
        def step(state, frontier_mask):
            (parent,) = state
            # Active sources offer themselves as parent; min-id wins (Ligra's
            # CAS winner is arbitrary; min makes it deterministic — and
            # direction-independent).
            msg = jnp.where(
                frontier_mask[src_e], src_e.astype(jnp.float32), big
            )
            offer = jax.ops.segment_min(msg, dst_e, num_segments=n)
            unvisited = parent >= big
            newly = unvisited & (offer < big) & present
            new_parent = jnp.where(newly, offer, parent)
            return (new_parent,), newly, ~jnp.any(newly)

        return step

    steps = {
        d: make_step(*edge_endpoints(graph, d)) for d in step_directions(direction)
    }

    parent0 = jnp.full(n, big, dtype=jnp.float32)
    parent0 = parent0.at[root].set(root)
    init_mask = np.zeros(n, dtype=bool)
    init_mask[root] = True

    return run_iterations(
        name="bfs",
        graph=graph,
        init_state=(parent0,),
        init_frontier_mask=init_mask,
        max_iters=max_iters,
        extract_values=lambda s: s[0],
        steps=steps,
        direction=direction,
    )


register_kernel_variant(
    "bfs_do",
    base="bfs",
    direction="auto",
    description="Direction-optimizing BFS (Ligra dense/sparse switch)",
)
