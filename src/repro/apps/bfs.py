"""Breadth-First Search (Ligra BFS) — push-based parent assignment.

For the evolving-graph protocol the kernel is run twice (run-1 / run-2
inputs from :mod:`repro.graphs.evolve`); the paper evaluates the second run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ligra import AppRun, run_iterations
from repro.graphs.csr import CSRGraph


def pick_root(graph: CSRGraph, present_mask: np.ndarray | None = None) -> int:
    """Deterministic root: highest out-degree present vertex."""
    deg = graph.degrees.copy()
    if present_mask is not None:
        deg = np.where(present_mask, deg, -1)
    return int(np.argmax(deg))


def bfs(
    graph: CSRGraph,
    root: int | None = None,
    max_iters: int = 200,
    present_mask: np.ndarray | None = None,
) -> AppRun:
    n = graph.num_vertices
    offsets, neighbors, _, edge_src = graph.device()
    if root is None:
        root = pick_root(graph, present_mask)

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.ones(n, dtype=bool)
    )
    big = jnp.float32(n + 1)

    @partial(jax.jit, donate_argnums=())
    def step(state, frontier_mask):
        (parent,) = state
        # Active sources offer themselves as parent; min-id wins (Ligra's CAS
        # winner is arbitrary; min makes it deterministic).
        msg = jnp.where(frontier_mask[edge_src], edge_src.astype(jnp.float32), big)
        offer = jax.ops.segment_min(msg, neighbors, num_segments=n)
        unvisited = parent >= big
        newly = unvisited & (offer < big) & present
        new_parent = jnp.where(newly, offer, parent)
        return (new_parent,), newly, ~jnp.any(newly)

    parent0 = jnp.full(n, big, dtype=jnp.float32)
    parent0 = parent0.at[root].set(root)
    init_mask = np.zeros(n, dtype=bool)
    init_mask[root] = True

    return run_iterations(
        name="bfs",
        graph=graph,
        init_state=(parent0,),
        init_frontier_mask=init_mask,
        step_fn=step,
        max_iters=max_iters,
        extract_values=lambda s: s[0],
    )
