"""PageRankDelta (PGD) — Algorithm 1 of the paper (from Ligra [53]).

Early-convergence PageRank: only vertices whose delta moved by more than a
threshold stay active, so the frontier shrinks and shifts across iterations
— the "non-repetitive irregular" pattern that defeats record-once
prefetchers (RnR) and that AMC's per-iteration re-recording tracks.

Registered as ``pgd`` (push) with a ``pgd_pull`` variant that traverses
in-edges dense-style every iteration — the same ranks, a different access
modality for AMC to train on.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ligra import AppRun, edge_endpoints, run_iterations, step_directions
from repro.apps.registry import register_kernel, register_kernel_variant
from repro.graphs.csr import CSRGraph


@register_kernel(
    "pgd",
    epoch_protocol="per_iteration",
    directions=("push", "pull", "auto"),
    description="PageRankDelta (early-convergence iterative; Ligra)",
)
def pagerank_delta(
    graph: CSRGraph,
    alpha: float = 0.85,
    delta_threshold: float = 0.01,  # δ: active iff |Δ[v]| > δ·PR[v] (Ligra)
    epsilon: float = 1e-6,
    max_iters: int = 30,
    present_mask: np.ndarray | None = None,
    direction: str = "push",
) -> AppRun:
    n = graph.num_vertices
    # Contributions normalize by the *out*-degree of the source regardless
    # of traversal direction.
    deg = jnp.maximum(jnp.asarray(graph.degrees).astype(jnp.float32), 1.0)

    present = (
        jnp.asarray(present_mask)
        if present_mask is not None
        else jnp.asarray(graph.degrees > 0)
    )
    n_present = jnp.maximum(jnp.sum(present.astype(jnp.float32)), 1.0)

    def make_step(src_e, dst_e, _w):
        @partial(jax.jit, donate_argnums=())
        def step(state, frontier_mask):
            delta, pr = state
            contrib = jnp.where(
                frontier_mask[src_e], delta[src_e] / deg[src_e], 0.0
            )
            ngh_sum = jax.ops.segment_sum(contrib, dst_e, num_segments=n)
            touched = ngh_sum != 0.0
            new_delta = jnp.where(touched, alpha * ngh_sum, 0.0)
            new_pr = pr + new_delta
            # Ligra-style early convergence: a vertex stays active only while
            # its rank still moves by more than a δ fraction of its rank.
            new_mask = (
                touched
                & (jnp.abs(new_delta) > delta_threshold * jnp.abs(new_pr))
                & present
            )
            error = jnp.sum(jnp.abs(ngh_sum))
            return (new_delta, new_pr), new_mask, error < epsilon

        return step

    steps = {
        d: make_step(*edge_endpoints(graph, d)) for d in step_directions(direction)
    }

    delta0 = jnp.where(present, 1.0 / n_present, 0.0).astype(jnp.float32)
    pr0 = jnp.zeros(n, dtype=jnp.float32) + delta0
    init_mask = np.asarray(present)

    return run_iterations(
        name="pgd",
        graph=graph,
        init_state=(delta0, pr0),
        init_frontier_mask=init_mask,
        max_iters=max_iters,
        extract_values=lambda s: s[1],
        steps=steps,
        direction=direction,
    )


register_kernel_variant(
    "pgd_pull",
    base="pgd",
    direction="pull",
    description="PageRankDelta, dense pull traversal every iteration",
)
