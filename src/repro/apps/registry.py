"""First-class kernel registry: the declarative half of the apps layer.

The paper's workloads are Ligra kernels, and each one carries protocol
metadata that used to live as string special-cases scattered through the
driver and the stream protocol: whether the input graph is weighted
(``kernel == "bellmanford"``), whether the paper's two-run evolving
protocol applies (``TWO_RUN_KERNELS``), whether a traversal root must be
shared across runs, and which traversal directions the kernel supports.
Here those properties are carried as a declarative :class:`KernelSpec`
attached at definition site, mirroring the prefetcher registry
(:mod:`repro.core.registry`):

    @register_kernel(
        "pgd", epoch_protocol="per_iteration", directions=("push", "pull"),
    )
    def pagerank_delta(graph, *, direction="push", ...) -> AppRun: ...

Direction *variants* register the same implementation under a new name with
a different default traversal mode — this is how the direction-optimizing
BFS and the pull-mode PageRankDelta become first-class grid scenarios:

    register_kernel_variant("bfs_do", base="bfs", direction="auto")

Lookup is by name (``get_kernel("bfs_do")``); the workload driver, the
experiment builder, the stream protocol, and the artifact cache all
dispatch on the spec's metadata instead of on kernel-name strings.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Callable, Dict, List, Optional, Tuple

# Traversal directions a kernel step can run in.  "push" is Ligra's sparse
# EDGEMAP (iterate out-edges of active sources), "pull" its dense EDGEMAP
# (iterate in-edges of every destination), "auto" the direction-optimizing
# frontier-threshold switch between the two.
DIRECTIONS = ("push", "pull", "auto")

# AMC epoch protocols (paper §VI): "per_iteration" gives each kernel
# iteration its own epoch (PGD/CC); "per_run" runs the kernel twice on an
# evolving input pair, one epoch per run, evaluating the second (BFS/BF).
EPOCH_PROTOCOLS = ("per_iteration", "per_run")


class DuplicateKernelError(ValueError):
    """A kernel name was registered twice without ``replace=True``."""


class UnknownKernelError(KeyError):
    """Requested kernel name is not in the registry."""


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declarative description of one graph kernel.

    ``fn`` is the kernel implementation ``(graph, **kw) -> AppRun``;
    :meth:`run` applies the spec's traversal ``direction`` and threads the
    present-mask / shared-root protocol arguments the metadata calls for.
    A *variant* spec (``bfs_do``, ``pgd_pull``) shares its base kernel's
    ``fn`` and differs only in ``direction``.
    """

    name: str
    fn: Callable
    weighted: bool = False  # input graph carries edge weights (BellmanFord)
    epoch_protocol: str = "per_iteration"
    directions: Tuple[str, ...] = ("push",)  # modes the implementation supports
    direction: str = "push"  # mode this spec runs in
    needs_root: bool = False  # traversal kernel: share one root across runs
    description: str = ""

    def __post_init__(self):
        if self.epoch_protocol not in EPOCH_PROTOCOLS:
            raise ValueError(
                f"epoch_protocol must be one of {EPOCH_PROTOCOLS}; "
                f"got {self.epoch_protocol!r}"
            )
        bad = set(self.directions) - set(DIRECTIONS)
        if bad or not self.directions:
            raise ValueError(
                f"directions must be a non-empty subset of {DIRECTIONS}; "
                f"got {self.directions!r}"
            )
        if self.direction not in self.directions:
            raise ValueError(
                f"direction {self.direction!r} not among supported "
                f"directions {self.directions!r}"
            )

    @property
    def two_run(self) -> bool:
        """The §VI two-run evolving protocol applies to this kernel."""
        return self.epoch_protocol == "per_run"

    def run(self, graph, present_mask=None, root=None, **overrides):
        """Run the kernel on ``graph`` under this spec's protocol.

        ``present_mask`` and ``root`` are threaded only when given /
        relevant, so push-only kernels registered without those parameters
        keep working.
        """
        kw = dict(overrides)
        if present_mask is not None:
            kw["present_mask"] = present_mask
        if self.needs_root and root is not None:
            kw["root"] = root
        if self.directions != ("push",):
            kw.setdefault("direction", self.direction)
        return self.fn(graph, **kw)


_REGISTRY: Dict[str, KernelSpec] = {}
_BUILTINS_LOADED = False  # False | "loading" | True


def _ensure_builtins_loaded() -> None:
    """Import the kernel modules so their decorators have run."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:  # True, or "loading" during the import below
        return
    _BUILTINS_LOADED = "loading"
    before = set(_REGISTRY)
    modules_before = set(sys.modules)
    try:
        # Each kernel module self-registers at import time (including the
        # direction variants declared next to their base kernels).
        import repro.apps.pagerank_delta  # noqa: F401
        import repro.apps.connected_components  # noqa: F401
        import repro.apps.bfs  # noqa: F401
        import repro.apps.bellman_ford  # noqa: F401
    except BaseException:
        # Roll back this attempt's registrations and evict the modules it
        # imported, so a retry re-executes the decorators instead of dying
        # on DuplicateKernelError or silently losing kernels.
        for name in set(_REGISTRY) - before:
            del _REGISTRY[name]
        for mod in set(sys.modules) - modules_before:
            if mod.startswith("repro.apps."):
                del sys.modules[mod]
        _BUILTINS_LOADED = False
        raise
    _BUILTINS_LOADED = True


def register_kernel(
    name: str,
    *,
    weighted: bool = False,
    epoch_protocol: str = "per_iteration",
    directions: Tuple[str, ...] = ("push",),
    direction: str = "push",
    needs_root: bool = False,
    description: Optional[str] = None,
    replace: bool = False,
) -> Callable:
    """Decorator: register ``fn`` under ``name`` with its declarative spec.

    The decorated function is returned unchanged (with a ``.kernel_spec``
    attribute), so plain-function call sites keep working.
    """

    def decorate(fn: Callable) -> Callable:
        _ensure_builtins_loaded()
        if name in _REGISTRY and not replace:
            raise DuplicateKernelError(
                f"kernel {name!r} already registered "
                f"(by {_REGISTRY[name].fn!r}); pass replace=True to override"
            )
        desc = description
        if desc is None:
            doc_lines = (fn.__doc__ or "").strip().splitlines()
            desc = doc_lines[0] if doc_lines else ""
        spec = KernelSpec(
            name=name,
            fn=fn,
            weighted=weighted,
            epoch_protocol=epoch_protocol,
            directions=tuple(directions),
            direction=direction,
            needs_root=needs_root,
            description=desc,
        )
        _REGISTRY[name] = spec
        fn.kernel_spec = spec
        return fn

    return decorate


def register_kernel_variant(
    name: str,
    base: str,
    *,
    direction: str,
    description: str = "",
    replace: bool = False,
) -> KernelSpec:
    """Register ``base``'s implementation under a new name with a different
    default traversal direction (e.g. ``bfs_do`` = ``bfs`` with the
    direction-optimizing switch).  Protocol metadata is inherited."""
    b = get_kernel(base)
    if name in _REGISTRY and not replace:
        raise DuplicateKernelError(
            f"kernel {name!r} already registered; pass replace=True to override"
        )
    spec = dataclasses.replace(
        b,
        name=name,
        direction=direction,
        description=description or f"{b.description} [{direction} traversal]",
    )
    _REGISTRY[name] = spec
    return spec


def get_kernel(name: str) -> KernelSpec:
    """Look up a registered kernel spec by name."""
    _ensure_builtins_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownKernelError(
            f"unknown kernel {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def has_kernel(name: str) -> bool:
    _ensure_builtins_loaded()
    return name in _REGISTRY


def list_kernels() -> List[str]:
    """All registered names, in registration order."""
    _ensure_builtins_loaded()
    return list(_REGISTRY)


def kernel_traits(name: str) -> KernelSpec:
    """The spec for ``name``, or a default push/per-iteration spec for
    ad-hoc names (the driver allows caller-supplied runs under a purely
    descriptive kernel name — those get the plain protocol, exactly what
    unknown names got under the old string checks)."""
    _ensure_builtins_loaded()
    spec = _REGISTRY.get(name)
    if spec is None:
        return KernelSpec(name=name, fn=_no_kernel)
    return spec


def _no_kernel(graph, **kw):  # pragma: no cover - traits-only placeholder
    raise UnknownKernelError("ad-hoc kernel spec has no implementation")


__all__ = [
    "DIRECTIONS",
    "EPOCH_PROTOCOLS",
    "DuplicateKernelError",
    "KernelSpec",
    "UnknownKernelError",
    "get_kernel",
    "has_kernel",
    "kernel_traits",
    "list_kernels",
    "register_kernel",
    "register_kernel_variant",
]
