"""Evolving-graph applications (Ligra-style, JAX) + memory-trace generation.

Four kernels from the paper's evaluation:
  PGD  -- PageRankDelta (early-convergence iterative; Ligra)
  CC   -- Connected Components (label propagation; Ligra)
  BFS  -- Breadth-First Search (run twice on evolving inputs)
  BF   -- BellmanFord SSSP (run twice on evolving inputs)

Each app is written against the ``edge_map``/``vertex_map`` primitives in
:mod:`repro.apps.ligra` (jitted ``jnp`` segment ops) and returns an
:class:`repro.apps.ligra.AppRun` carrying per-iteration frontiers, which the
tracer (:mod:`repro.apps.trace`) turns into the V/N/P/F memory access
streams of the paper's Fig 3.
"""
from repro.apps.ligra import AppRun, edge_map_sum, edge_map_min
from repro.apps.pagerank_delta import pagerank_delta
from repro.apps.connected_components import connected_components
from repro.apps.bfs import bfs
from repro.apps.bellman_ford import bellman_ford
from repro.apps.trace import TraceConfig, IterationTrace, trace_app_run, ARRAYS

KERNELS = {
    "pgd": pagerank_delta,
    "cc": connected_components,
    "bfs": bfs,
    "bellmanford": bellman_ford,
}

__all__ = [
    "AppRun",
    "edge_map_sum",
    "edge_map_min",
    "pagerank_delta",
    "connected_components",
    "bfs",
    "bellman_ford",
    "TraceConfig",
    "IterationTrace",
    "trace_app_run",
    "ARRAYS",
    "KERNELS",
]
