"""Evolving-graph applications (Ligra-style, JAX) + memory-trace generation.

Kernels register declaratively (:mod:`repro.apps.registry`): the paper's
four evaluation kernels plus two direction variants —

  pgd      -- PageRankDelta (early-convergence iterative; Ligra)
  cc       -- Connected Components (label propagation; Ligra)
  bfs      -- Breadth-First Search (run twice on evolving inputs)
  bellmanford -- BellmanFord SSSP (run twice on evolving inputs)
  bfs_do   -- direction-optimizing BFS (Ligra dense/sparse switch)
  pgd_pull -- PageRankDelta, dense pull traversal every iteration

Each :class:`~repro.apps.registry.KernelSpec` carries the protocol metadata
the engine dispatches on (weighted input, two-run epoch protocol, shared
traversal root, traversal directions).  Kernels are written against the
``edge_map``/``run_iterations`` primitives in :mod:`repro.apps.ligra`
(jitted ``jnp`` segment ops over push/pull edge orders) and return an
:class:`repro.apps.ligra.AppRun` carrying per-iteration frontiers and
directions, which the tracer (:mod:`repro.apps.trace`) turns into the
V/N/P/F (push) and F/T/V/NI/P (pull) access streams.
"""
from collections.abc import Mapping as _Mapping

from repro.apps.ligra import AppRun, edge_map_sum, edge_map_min
from repro.apps.registry import (
    KernelSpec,
    get_kernel,
    has_kernel,
    kernel_traits,
    list_kernels,
    register_kernel,
    register_kernel_variant,
)
from repro.apps.pagerank_delta import pagerank_delta
from repro.apps.connected_components import connected_components
from repro.apps.bfs import bfs
from repro.apps.bellman_ford import bellman_ford
from repro.apps.trace import (
    ARRAYS,
    IterationTrace,
    RunTrace,
    TraceConfig,
    trace_app_run,
    trace_run,
)


class _KernelsView(_Mapping):
    """Legacy ``KERNELS`` name->callable view, live over the registry
    (kernels registered later appear; direction variants run their
    declared direction).  Read-only: register kernels through
    ``register_kernel``, not by mutating this mapping."""

    def __getitem__(self, name):
        try:
            return get_kernel(name).run
        except KeyError:
            raise KeyError(name) from None

    def __iter__(self):
        return iter(list_kernels())

    def __len__(self):
        return len(list_kernels())


KERNELS = _KernelsView()


__all__ = [
    "AppRun",
    "KernelSpec",
    "edge_map_sum",
    "edge_map_min",
    "pagerank_delta",
    "connected_components",
    "bfs",
    "bellman_ford",
    "get_kernel",
    "has_kernel",
    "kernel_traits",
    "list_kernels",
    "register_kernel",
    "register_kernel_variant",
    "TraceConfig",
    "IterationTrace",
    "RunTrace",
    "trace_app_run",
    "trace_run",
    "ARRAYS",
    "KERNELS",
]
