"""Ligra-style frontier primitives in JAX, in both traversal directions.

``edge_map_*`` applies a per-edge message from *active sources* and
segment-reduces into destinations — Ligra's [53] EDGEMAP.  The same
segment reduction runs in either direction; what changes is the per-edge
array order it runs over (and therefore the memory-access modality the
tracer emits):

- **push** (sparse): edges in CSR (out-edge) order — active sources
  scatter into destination properties.
- **pull** (dense): edges in CSC (in-edge) order via
  :meth:`~repro.graphs.csr.CSRGraph.transpose` — every destination scans
  its in-edge row sequentially and gathers source properties.
- **auto**: Ligra's direction-optimizing switch — an iteration goes dense
  when the frontier plus its out-edges exceed ``|E| / dense_threshold``
  (Ligra's default denominator is 20).

The reduction runs over the full edge set with an activity mask (O(E) work
but one fused XLA kernel per iteration; for the graph sizes here this is
faster on CPU than gather-based sparse iteration and is exactly shardable
under pjit).  Push and pull compute identical values per iteration — the
contributions are the same multiset, only reduced in a different edge
order — which the property tests assert kernel by kernel.

Apps drive a Python iteration loop around jitted step functions and collect
per-iteration frontiers (and directions) on the host for the tracer.  The
loop itself is host-side because the *number* of iterations is
data-dependent and each iteration's frontier must be exported anyway
(trace generation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph

# Ligra's dense/sparse threshold denominator: an iteration runs dense
# (pull) when |frontier| + outdeg(frontier) > |E| / DENSE_THRESHOLD.
DENSE_THRESHOLD = 20


@dataclasses.dataclass
class AppRun:
    """Result of running one kernel on one input graph."""

    name: str
    graph: CSRGraph
    frontiers: List[np.ndarray]  # iteration -> sorted active vertex ids
    values: np.ndarray  # final property array (rank / comp / parent / dist)
    num_iters: int
    stats: dict
    directions: Optional[List[str]] = None  # per-iteration "push" | "pull"

    @property
    def total_active(self) -> int:
        return int(sum(len(f) for f in self.frontiers))

    def iteration_directions(self) -> List[str]:
        """Per-iteration traversal direction ("push" for legacy runs)."""
        if self.directions is not None:
            return self.directions
        return ["push"] * len(self.frontiers)

    def frontier_masks(self, n: Optional[int] = None) -> List[np.ndarray]:
        n = n or self.graph.num_vertices
        out = []
        for f in self.frontiers:
            m = np.zeros(n, dtype=bool)
            m[f] = True
            out.append(m)
        return out


@partial(jax.jit, static_argnames=("num_segments",))
def _segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments",))
def _segment_min(data, segment_ids, num_segments):
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


def edge_map_sum(edge_src, neighbors, per_edge_value, frontier_mask, n):
    """sum_{(s,d) in E, s active} value[e] into dest slots; 0 elsewhere."""
    contrib = jnp.where(frontier_mask[edge_src], per_edge_value, 0.0)
    return _segment_sum(contrib, neighbors, n)


def edge_map_min(edge_src, neighbors, per_edge_value, frontier_mask, n, big):
    """min over active in-edges per destination; ``big`` where none."""
    contrib = jnp.where(frontier_mask[edge_src], per_edge_value, big)
    return _segment_min(contrib, neighbors, n)


def edge_endpoints(graph: CSRGraph, direction: str):
    """Per-edge ``(source, destination, weight)`` jnp arrays for one
    traversal direction.

    Push uses CSR (out-edge) order; pull uses the cached CSC transpose, so
    edges appear in in-edge order — same (source, destination, weight)
    multiset, different traversal order.  Kernels build one jitted step per
    direction over these arrays; the step math is direction-agnostic.
    """
    if direction == "push":
        _, neighbors, weights, edge_src = graph.device()
        return edge_src, neighbors, weights
    if direction == "pull":
        t = graph.transpose()
        _, in_sources, weights, edge_dst = t.device()
        return in_sources, edge_dst, weights
    raise ValueError(f"direction must be 'push' or 'pull'; got {direction!r}")


def step_directions(direction: str) -> tuple:
    """The concrete step directions a kernel must compile for ``direction``."""
    if direction == "auto":
        return ("push", "pull")
    if direction in ("push", "pull"):
        return (direction,)
    raise ValueError(f"unknown traversal direction {direction!r}")


def run_iterations(
    name: str,
    graph: CSRGraph,
    init_state: tuple,
    init_frontier_mask: np.ndarray,
    step_fn: Optional[Callable] = None,
    max_iters: int = 100,
    extract_values: Callable = None,
    min_frontier: int = 1,
    *,
    steps: Optional[Dict[str, Callable]] = None,
    direction: str = "push",
    dense_threshold: int = DENSE_THRESHOLD,
) -> AppRun:
    """Generic host loop: step(state, frontier_mask) -> (state, new_mask, done).

    ``steps`` maps a traversal direction to its jitted step function (push
    and pull steps compute identical values over differently-ordered edge
    arrays); a bare ``step_fn`` is shorthand for ``steps={"push": step_fn}``.
    Under ``direction="auto"`` each iteration picks dense (pull) or sparse
    (push) by Ligra's frontier threshold; the per-iteration choices are
    recorded on the returned :class:`AppRun` for the tracer, which emits a
    different access pattern per direction.
    """
    if steps is None:
        if step_fn is None:
            raise ValueError("pass step_fn or steps={direction: fn}")
        steps = {"push": step_fn}
    for d in step_directions(direction):
        if d not in steps:
            raise ValueError(
                f"direction {direction!r} needs a {d!r} step; have {sorted(steps)}"
            )
    out_deg = np.asarray(graph.degrees, dtype=np.int64)
    dense_cut = graph.num_edges / dense_threshold
    frontiers: List[np.ndarray] = []
    directions: List[str] = []
    mask = jnp.asarray(init_frontier_mask)
    state = init_state
    iters = 0
    for _ in range(max_iters):
        active = np.flatnonzero(np.asarray(mask))
        if len(active) < min_frontier:
            break
        if direction == "auto":
            d = (
                "pull"
                if len(active) + int(out_deg[active].sum()) > dense_cut
                else "push"
            )
        else:
            d = direction
        frontiers.append(active.astype(np.int64))
        directions.append(d)
        state, mask, done = steps[d](state, mask)
        iters += 1
        if bool(done):
            # Converged: stop here instead of evaluating further steps.
            # For the registered kernels at their shipped configurations
            # the done flag fires only alongside an emptying frontier, so
            # counts match the old ignore-done loop (test-asserted); a
            # kernel whose convergence test is independent of the frontier
            # (e.g. PGD with a loose epsilon) now stops at convergence
            # instead of iterating on.
            break
    values = np.asarray(extract_values(state))
    dense_iters = directions.count("pull")
    return AppRun(
        name=name,
        graph=graph,
        frontiers=frontiers,
        values=values,
        num_iters=iters,
        stats={
            "iters": iters,
            "total_active": int(sum(len(f) for f in frontiers)),
            "dense_iters": dense_iters,
        },
        directions=directions,
    )
