"""Ligra-style frontier primitives in JAX.

``edge_map_*`` applies a per-edge message from *active sources* and
segment-reduces into destinations — the push-based EDGEMAP of Ligra [53],
which is what PGD/CC/BFS/BellmanFord in the paper use. The reduction runs
over the full edge set with an activity mask (O(E) work but one fused XLA
kernel per iteration; for the graph sizes here this is faster on CPU than
gather-based sparse iteration and is exactly shardable under pjit).

Apps drive a Python iteration loop around jitted step functions and collect
per-iteration frontiers on the host for the tracer. The loop itself is
host-side because the *number* of iterations is data-dependent and each
iteration's frontier must be exported anyway (trace generation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph


@dataclasses.dataclass
class AppRun:
    """Result of running one kernel on one input graph."""

    name: str
    graph: CSRGraph
    frontiers: List[np.ndarray]  # iteration -> sorted active vertex ids
    values: np.ndarray  # final property array (rank / comp / parent / dist)
    num_iters: int
    stats: dict

    @property
    def total_active(self) -> int:
        return int(sum(len(f) for f in self.frontiers))

    def frontier_masks(self, n: Optional[int] = None) -> List[np.ndarray]:
        n = n or self.graph.num_vertices
        out = []
        for f in self.frontiers:
            m = np.zeros(n, dtype=bool)
            m[f] = True
            out.append(m)
        return out


@partial(jax.jit, static_argnames=("num_segments",))
def _segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments",))
def _segment_min(data, segment_ids, num_segments):
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


def edge_map_sum(edge_src, neighbors, per_edge_value, frontier_mask, n):
    """sum_{(s,d) in E, s active} value[e] into dest slots; 0 elsewhere."""
    contrib = jnp.where(frontier_mask[edge_src], per_edge_value, 0.0)
    return _segment_sum(contrib, neighbors, n)


def edge_map_min(edge_src, neighbors, per_edge_value, frontier_mask, n, big):
    """min over active in-edges per destination; ``big`` where none."""
    contrib = jnp.where(frontier_mask[edge_src], per_edge_value, big)
    return _segment_min(contrib, neighbors, n)


def run_iterations(
    name: str,
    graph: CSRGraph,
    init_state: tuple,
    init_frontier_mask: np.ndarray,
    step_fn: Callable,
    max_iters: int,
    extract_values: Callable,
    min_frontier: int = 1,
) -> AppRun:
    """Generic host loop: step_fn(state, frontier_mask) -> (state, new_mask, done)."""
    frontiers: List[np.ndarray] = []
    mask = jnp.asarray(init_frontier_mask)
    state = init_state
    iters = 0
    for _ in range(max_iters):
        active = np.flatnonzero(np.asarray(mask))
        if len(active) < min_frontier:
            break
        frontiers.append(active.astype(np.int64))
        state, mask, done = step_fn(state, mask)
        iters += 1
        if bool(done):
            # Record the final frontier's work having run; loop exits next
            # check anyway if mask is empty.
            pass
    values = np.asarray(extract_values(state))
    return AppRun(
        name=name,
        graph=graph,
        frontiers=frontiers,
        values=values,
        num_iters=iters,
        stats={"iters": iters, "total_active": int(sum(len(f) for f in frontiers))},
    )
