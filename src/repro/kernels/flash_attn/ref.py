"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (BH, Sq, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: int = 0,
) -> jnp.ndarray:
    hd = q.shape[-1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window:
        mask &= k_pos > q_pos - sliding_window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
