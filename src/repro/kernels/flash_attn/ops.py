"""Jitted public wrapper: (B, S, H, hd) attention via the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_attention


def mha(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: int = 0,
    interpret: bool = False,
    block_q: int = 128,
    block_kv: int = 128,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    kr = jnp.repeat(k, groups, axis=2) if groups > 1 else k
    vr = jnp.repeat(v, groups, axis=2) if groups > 1 else v
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kf = jnp.moveaxis(kr, 2, 1).reshape(b * h, -1, hd)
    vf = jnp.moveaxis(vr, 2, 1).reshape(b * h, -1, hd)
    o = flash_attention(
        qf, kf, vf,
        causal=causal,
        sliding_window=sliding_window,
        interpret=interpret,
        block_q=block_q,
        block_kv=block_kv,
    )
    return jnp.moveaxis(o.reshape(b, h, sq, hd), 1, 2)
