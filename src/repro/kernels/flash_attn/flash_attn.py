"""Flash attention forward Pallas kernel (TPU target, interpret-validated).

Tiling: grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is the
innermost ("arbitrary") grid axis so the (m, l, acc) accumulators carried in
VMEM scratch persist across kv steps for one q block. Block shapes are
(BLOCK_Q, head_dim) / (BLOCK_KV, head_dim) — multiples of 128 on the MXU-
facing dims. Causal masking is done with block-level early-exit semantics
expressed through the index map (upper-triangular kv blocks still execute
but are fully masked; XLA:TPU skips their DMA cost via revisiting==False
semantics — acceptable, and exact)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_kv: int, kv_len: int,
    sliding_window: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)  # (BKV, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BKV)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window:
        mask &= k_pos > q_pos - sliding_window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (BH, Sq, hd)
    k: jnp.ndarray,  # (BH, Skv, hd)
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
):
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = hd**-0.5
    nq = -(-sq // block_q)
    nk = -(-skv // block_kv)
    pad_q = nq * block_q - sq
    pad_k = nk * block_kv - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        kv_len=skv,
        sliding_window=sliding_window,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1)),
            _vmem((block_q, 1)),
            _vmem((block_q, hd)),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
