"""AMC recorded-stream gather kernel — the paper's mechanism, TPU-native.

The CPU prefetcher records "the misses that follow a target access" and
replays them one iteration later. On TPU the memory system is software
managed, so the analogue is: the *gather index stream recorded in iteration
k* drives HBM->VMEM row DMA for iteration k+1 *ahead of use* (DESIGN.md
§2.2 table). Pallas expresses exactly this: the recorded index stream is a
scalar-prefetch operand, and each grid step's input BlockSpec ``index_map``
selects the next recorded row — the pipeline emitter double-buffers the row
DMA against the previous step's compute, which IS the prefetch.

Grid: one step per index block. The index stream lives in SMEM (scalar
prefetch); rows stream through VMEM tiles of (block_rows, row_width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, out_ref, *, block_rows: int):
    # table_ref block: (block_rows, D) rows selected by the index_map —
    # i.e. the DMA already fetched the recorded rows; just write through.
    out_ref[...] = table_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def amc_gather(
    table: jnp.ndarray,  # (V, D) vertex-property rows in HBM
    indices: jnp.ndarray,  # (N,) int32 recorded miss/index stream
    block_rows: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather ``table[indices]`` with recorded-stream pipelining.

    The row dimension is blocked one row per grid step within a
    ``block_rows``-wide super-step; the scalar-prefetched ``indices`` feed
    the table BlockSpec's index_map so the Pallas pipeline issues each row's
    DMA one step ahead (double buffering) — the AMC replay.
    """
    n = indices.shape[0]
    v, d = table.shape
    grid = (n,)

    def table_index_map(i, idx_ref):
        return (idx_ref[i], 0)

    def out_index_map(i, idx_ref):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), table_index_map)],
        out_specs=pl.BlockSpec((1, d), out_index_map),
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), table)


def _gather_accum_kernel(idx_ref, seg_ref, table_ref, out_ref, acc_ref):
    """Gather + segment-sum: the push-mode edgeMap consumer (nghSum)."""
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += table_ref[...].astype(jnp.float32)

    @pl.when((i == n - 1) | (seg_ref[i] != seg_ref[jnp.minimum(i + 1, n - 1)]))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)
        acc_ref[...] = jnp.zeros_like(acc_ref)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def amc_gather_segment_sum(
    table: jnp.ndarray,  # (V, D)
    indices: jnp.ndarray,  # (N,) recorded gather stream
    segments: jnp.ndarray,  # (N,) non-decreasing destination segment ids
    num_segments: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """out[s] = sum_{i: segments[i]=s} table[indices[i]] (frontier push)."""
    n = indices.shape[0]
    v, d = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx, seg: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx, seg: (seg[i], 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        _gather_accum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, d), table.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), segments.astype(jnp.int32), table)
