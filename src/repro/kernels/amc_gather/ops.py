"""Public API: AMC recorded-stream property gather for the graph apps.

``AMCGatherSession`` carries the two recorded index streams and swaps roles
at every iteration boundary, mirroring ``AMC.update()``: the stream
recorded during iteration k drives the pipelined gather of iteration k+1.
A mismatch mask (current frontier vs recorded stream) falls back to a plain
gather for the changed rows — prefetch-for-the-stable-part, demand-for-the-
changed-part, exactly the paper's coverage behavior.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.amc_gather.amc_gather import amc_gather, amc_gather_segment_sum
from repro.kernels.amc_gather.ref import gather_ref

__all__ = ["AMCGatherSession", "amc_gather", "amc_gather_segment_sum", "gather_ref"]


class AMCGatherSession:
    def __init__(self, interpret: bool = True):
        self.recorded: Optional[np.ndarray] = None
        self.recording: Optional[np.ndarray] = None
        self.interpret = interpret
        self.stats = {"replayed": 0, "fallback": 0}

    def update(self):
        """Iteration boundary: role swap (AMC.update())."""
        self.recorded = self.recording
        self.recording = None

    def gather(self, table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
        """Gather rows; replay the recorded stream where it still matches."""
        idx_np = np.asarray(indices)
        self.recording = idx_np  # record this iteration's stream
        rec = self.recorded
        if rec is not None and len(rec) == len(idx_np) and np.array_equal(rec, idx_np):
            self.stats["replayed"] += 1
            return amc_gather(table, jnp.asarray(rec), interpret=self.interpret)
        if rec is not None and len(rec) == len(idx_np):
            # Partial match: replay recorded stream, fix changed rows.
            self.stats["replayed"] += 1
            out = amc_gather(table, jnp.asarray(rec), interpret=self.interpret)
            changed = rec != idx_np
            if changed.any():
                self.stats["fallback"] += 1
                fix = gather_ref(table, jnp.asarray(idx_np[changed]))
                out = out.at[jnp.asarray(np.flatnonzero(changed))].set(fix)
            return out
        self.stats["fallback"] += 1
        return gather_ref(table, indices)
