"""Pure-jnp oracles for the AMC gather kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_ref(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    return table[indices]


def gather_segment_sum_ref(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    segments: jnp.ndarray,
    num_segments: int,
) -> jnp.ndarray:
    rows = table[indices].astype(jnp.float32)
    out = jax.ops.segment_sum(rows, segments, num_segments=num_segments)
    return out.astype(table.dtype)
