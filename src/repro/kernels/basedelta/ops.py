"""Wrapper: compress/roundtrip AMC entry tables through the tile kernels.

Block-line ids in this system fit int32 (46-bit physical addresses in the
paper map to <2^26 line ids at our scale); the 46-bit base is carried
exactly on the host side, the kernel handles the delta lanes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.basedelta.basedelta import (
    basedelta_compress_tiles,
    basedelta_decompress_tiles,
)

MODE_BYTES = np.array([1, 2, 4, 8])


def pack_ragged(miss_blocks: np.ndarray, offsets: np.ndarray, width: int = 32):
    """Ragged entries -> fixed (E, width) tiles + counts (host-side I/O).

    Entries must fit the tile width — the AMC binder splits at 20 misses
    (paper Fig 16), so width 32 always holds."""
    e = len(offsets) - 1
    counts = np.diff(offsets).astype(np.int32)
    assert counts.max(initial=0) <= width, (
        f"entry of {counts.max()} misses exceeds tile width {width}; "
        "split entries first (AMC caps at 20)"
    )
    tiles = np.zeros((e, width), np.int32)
    rows = np.repeat(np.arange(e), counts)
    lanes = np.arange(len(rows)) - np.repeat(offsets[:-1], counts) + np.repeat(
        offsets[:-1] - offsets[:-1], counts
    )
    # per-row lane index
    lane_start = np.zeros(e, np.int64)
    np.cumsum(counts[:-1], out=lane_start[1:])
    lanes = np.arange(int(counts.sum())) - np.repeat(lane_start, counts)
    src = np.concatenate(
        [miss_blocks[offsets[i] : offsets[i] + counts[i]] for i in range(e)]
    ) if e else np.zeros(0, np.int64)
    tiles[rows, lanes] = src.astype(np.int32)
    return tiles, counts


def compress_entries(
    miss_blocks: np.ndarray, offsets: np.ndarray, width: int = 32, interpret=True
):
    """Returns (bases, deltas, modes, counts, compressed_bytes)."""
    tiles, counts = pack_ragged(miss_blocks, offsets, width)
    deltas, modes = basedelta_compress_tiles(
        jnp.asarray(tiles), jnp.asarray(counts), interpret=interpret
    )
    modes_np = np.asarray(modes)
    nbytes = 7 + np.maximum(counts - 1, 0) * MODE_BYTES[modes_np]
    return tiles[:, 0], np.asarray(deltas), modes_np, counts, int(nbytes.sum())


def roundtrip(miss_blocks: np.ndarray, offsets: np.ndarray, width=32, interpret=True):
    """Compress + decompress; returns the reconstructed ragged stream."""
    base, deltas, modes, counts, _ = compress_entries(
        miss_blocks, offsets, width, interpret
    )
    rec = np.asarray(
        basedelta_decompress_tiles(
            jnp.asarray(base), jnp.asarray(deltas), interpret=interpret
        )
    )
    if not len(counts):
        return np.zeros(0, np.int64)
    return np.concatenate(
        [rec[i, : counts[i]] for i in range(len(counts))]
    ).astype(np.int64)
