"""Pure-jnp oracle for the BaseΔ tile kernels."""
from __future__ import annotations

import jax.numpy as jnp


def compress_ref(blocks: jnp.ndarray, counts: jnp.ndarray):
    e, w = blocks.shape
    lane = jnp.arange(w)[None, :]
    valid = lane < counts[:, None]
    base = blocks[:, 0:1]
    deltas = jnp.where(valid, blocks - base, 0).astype(jnp.int32)
    absmax = jnp.max(jnp.abs(deltas), axis=1)
    mode = jnp.where(
        absmax <= 127,
        0,
        jnp.where(absmax <= 32767, 1, jnp.where(absmax <= 2**31 - 1, 2, 3)),
    ).astype(jnp.int32)
    return deltas, mode


def decompress_ref(base: jnp.ndarray, deltas: jnp.ndarray) -> jnp.ndarray:
    return (base[:, None] + deltas).astype(jnp.int32)
