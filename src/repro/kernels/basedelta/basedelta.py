"""BaseΔ compressor Pallas kernel (paper Fig 5/6, TPU-native).

The hardware compressor tests three delta widths in parallel with a row of
subtractors and picks the smallest that fits (Fig 5). The TPU analogue is a
vectorized tile kernel: entries are rows of a (block_entries, width) int32
tile; per row it computes base, deltas, and the 1/2/4-byte mode via lane
reductions. Packing to the byte stream is host-side plumbing (the kernel's
product is the subtract+select dataflow, which is what runs per-entry at
line rate in hardware).

Layout: width lanes per entry (max 20 misses used, padded), int32 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SENTINEL = jnp.int32(-(2**31) + 1)


def _compress_kernel(blocks_ref, count_ref, delta_ref, mode_ref):
    x = blocks_ref[...]  # (BE, W) int32 block addresses (low bits)
    cnt = count_ref[...]  # (BE, 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = lane < cnt
    base = x[:, 0:1]
    deltas = jnp.where(valid, x - base, 0)
    absmax = jnp.max(jnp.abs(deltas), axis=1, keepdims=True)
    mode = jnp.where(
        absmax <= 127,
        0,
        jnp.where(absmax <= 32767, 1, jnp.where(absmax <= 2**31 - 1, 2, 3)),
    ).astype(jnp.int32)
    delta_ref[...] = deltas
    mode_ref[...] = mode


@functools.partial(jax.jit, static_argnames=("block_entries", "interpret"))
def basedelta_compress_tiles(
    blocks: jnp.ndarray,  # (E, W) int32, entry rows (padded with anything)
    counts: jnp.ndarray,  # (E,) valid miss counts per entry
    block_entries: int = 8,
    interpret: bool = False,
):
    """Returns (deltas (E, W) int32, mode (E,) int32)."""
    e, w = blocks.shape
    ne = -(-e // block_entries)
    pad = ne * block_entries - e
    if pad:
        blocks = jnp.pad(blocks, ((0, pad), (0, 0)))
        counts = jnp.pad(counts, (0, pad))
    cnt2 = counts.astype(jnp.int32)[:, None]
    deltas, mode = pl.pallas_call(
        _compress_kernel,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((block_entries, w), lambda i: (i, 0)),
            pl.BlockSpec((block_entries, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_entries, w), lambda i: (i, 0)),
            pl.BlockSpec((block_entries, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ne * block_entries, w), jnp.int32),
            jax.ShapeDtypeStruct((ne * block_entries, 1), jnp.int32),
        ],
        interpret=interpret,
    )(blocks.astype(jnp.int32), cnt2)
    return deltas[:e], mode[:e, 0]


def _decompress_kernel(base_ref, delta_ref, out_ref):
    out_ref[...] = base_ref[...] + delta_ref[...]


@functools.partial(jax.jit, static_argnames=("block_entries", "interpret"))
def basedelta_decompress_tiles(
    base: jnp.ndarray,  # (E,) int32 entry bases
    deltas: jnp.ndarray,  # (E, W) int32
    block_entries: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    e, w = deltas.shape
    ne = -(-e // block_entries)
    pad = ne * block_entries - e
    if pad:
        base = jnp.pad(base, (0, pad))
        deltas = jnp.pad(deltas, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _decompress_kernel,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((block_entries, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_entries, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_entries, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ne * block_entries, w), jnp.int32),
        interpret=interpret,
    )(base.astype(jnp.int32)[:, None], deltas.astype(jnp.int32))
    return out[:e]
