"""Pure-Python oracle for the set-parallel LRU kernel.

Simulates each padded substream row with an explicit LRU dict — the same
machine as :func:`repro.memsim.scan_cache.cache_pass` restricted to one
set, written for obviousness rather than speed (tests use tiny shapes).
"""
from __future__ import annotations

import numpy as np


def lru_hits_ref(padded: np.ndarray, ways: int) -> np.ndarray:
    """Hit mask (int32 0/1) per cell of a ``(sets, L)`` substream matrix.

    Pad cells (block ``-1``) are skipped (reported as 0).  The kernel
    instead runs its machine over them, so kernel and oracle only agree on
    real-access cells — pads are tail-only by construction, can therefore
    never influence a real cell, and are never consumed by callers;
    comparisons must mask to ``padded >= 0``.
    """
    sets, length = padded.shape
    hits = np.zeros((sets, length), dtype=np.int32)
    for s in range(sets):
        state: dict = {}  # block -> last-use time
        for t in range(length):
            b = int(padded[s, t])
            if b < 0:
                continue
            if b in state:
                hits[s, t] = 1
            elif len(state) >= ways:
                lru = min(state, key=state.get)
                del state[lru]
            state[b] = t + 1
    return hits
