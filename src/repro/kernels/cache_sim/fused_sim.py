"""Fused multi-level LRU hierarchy simulation as a Pallas TPU kernel.

The fused engine (:mod:`repro.memsim.fused`) carries every hierarchy
level's tag/age lanes in one scan over group substreams.  On TPU the
grouped layout maps the same way the single-level kernel does: groups
tile the grid's sublane dimension, the time axis lives in lanes of the
substream block, and each grid step walks its tile's time axis with all
levels' carries resident in VMEM — the L1/L2/LLC update is pure VPU work
per step, and the emitted value is the *hit level* (0 = outermost level
… K = missed everywhere) rather than a single level's hit bit.

Unlike the host-side fused scan (which gathers only the accessed set's
ways per step — the right trade on CPU), the kernel keeps each level's
full ``R·ways`` lane vector live and masks by the accessed relative set:
lanes are what the VPU gives away for free, and one-hot selects avoid
dynamic scatters exactly as in :mod:`repro.kernels.cache_sim.cache_sim`.
State enters and leaves through refs, so chunked passes resume exactly
where the previous chunk stopped; pads (``b == -1``) emit a
(never-gathered) level but are masked out of every update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_tile_kernel(levels, blocks_ref, *refs):
    # refs: (tags_in, age_in) per level, then lvl_ref, (tags_out, age_out)
    # per level.  blocks_ref block: (group_tile, L) padded group substreams.
    k = len(levels)
    groups = min(sets for sets, _ in levels)
    lg = groups.bit_length() - 1
    ins = refs[: 2 * k]
    lvl_ref = refs[2 * k]
    outs = refs[2 * k + 1 :]
    for j in range(2 * k):
        outs[j][...] = ins[j][...]
    steps = blocks_ref.shape[1]
    intmax = jnp.int32(jnp.iinfo(jnp.int32).max)

    def body(t, carry):
        b = blocks_ref[:, pl.ds(t, 1)]  # (group_tile, 1)
        alive = b >= 0
        lvl = jnp.full(b.shape, k, jnp.int32)
        for i, (sets, ways) in enumerate(levels):
            tags = outs[2 * i][...]
            age = outs[2 * i + 1][...]
            lanes = jax.lax.broadcasted_iota(jnp.int32, (1, tags.shape[1]), 1)
            rel = (b >> lg) & ((sets // groups) - 1)
            lanemask = (lanes // ways) == rel
            hitv = (tags == b) & lanemask
            hit = hitv.any(axis=1, keepdims=True)
            sel = jnp.where(
                hit,
                jnp.argmax(hitv, axis=1, keepdims=True),
                jnp.argmin(
                    jnp.where(lanemask, age, intmax), axis=1, keepdims=True
                ),
            ).astype(jnp.int32)
            onehot = (sel == lanes) & alive
            outs[2 * i][...] = jnp.where(onehot, b, tags)
            outs[2 * i + 1][...] = jnp.where(onehot, t + 1, age)
            lvl = jnp.where(alive & hit, jnp.int32(i), lvl)
            alive = alive & ~hit
        lvl_ref[:, pl.ds(t, 1)] = lvl
        return carry

    jax.lax.fori_loop(0, steps, body, 0)


@functools.partial(
    jax.jit, static_argnames=("levels", "group_tile", "interpret")
)
def fused_levels_pallas(
    padded: jnp.ndarray,  # (groups, L) int32 group substreams, tail-padded -1
    levels,  # ((sets, ways), ...) outer→inner, static
    *state,  # tags0, age0 per level, each (groups, R·ways) int32
    group_tile: int = 8,
    interpret: bool = False,
):
    """Hit levels plus final (raw) per-level states, resuming from carries.

    Returns ``(lvls, tags_0, age_0, …)`` with ``lvls`` ``(groups, L)``
    int32 — the same tuple layout as the host scan, so the engine's
    scatter/canonicalize epilogue is shared between backends.
    """
    groups, length = padded.shape
    group_tile = min(group_tile, groups)
    assert groups % group_tile == 0, (groups, group_tile)
    grid = (groups // group_tile,)
    stream_spec = pl.BlockSpec((group_tile, length), lambda i: (i, 0))
    state_specs = [
        pl.BlockSpec((group_tile, st.shape[1]), lambda i: (i, 0))
        for st in state
    ]
    state_shapes = [
        jax.ShapeDtypeStruct((groups, st.shape[1]), jnp.int32) for st in state
    ]
    out = pl.pallas_call(
        functools.partial(_fused_tile_kernel, tuple(levels)),
        grid=grid,
        in_specs=[stream_spec] + state_specs,
        out_specs=[stream_spec] + state_specs,
        out_shape=[jax.ShapeDtypeStruct((groups, length), jnp.int32)]
        + state_shapes,
        interpret=interpret,
    )(padded, *state)
    return tuple(out)
