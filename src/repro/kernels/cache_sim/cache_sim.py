"""Set-parallel LRU cache simulation as a Pallas TPU kernel.

The set-parallel engine (:mod:`repro.memsim.engine`) turns the cache pass
into ``sets`` independent short simulations over a padded ``(sets, L)``
substream matrix.  On TPU that shape maps directly onto the hardware: sets
tile the grid's sublane dimension, the time axis lives in lanes, and each
grid step walks its tile's time axis with the tag/age carry held in VMEM
scratch — the per-step compare/select work is pure VPU.  One grid step per
set tile; tiles are independent, so the pipeline overlaps each tile's
substream DMA with the previous tile's simulation.

The update avoids dynamic per-row scatters: the victim way is turned into a
one-hot lane mask and the carry is advanced with ``jnp.where`` — identical
semantics to the reference scan's ``.at[s, way].set``, expressed as
vectorized selects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_tile_kernel(blocks_ref, hits_ref, tags_ref, age_ref):
    # blocks_ref block: (set_tile, L) — this tile's padded substreams.
    ways = tags_ref.shape[1]
    tags_ref[...] = jnp.full(tags_ref.shape, -1, jnp.int32)
    age_ref[...] = jnp.zeros(age_ref.shape, jnp.int32)
    steps = blocks_ref.shape[1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def body(t, carry):
        b = blocks_ref[:, pl.ds(t, 1)]  # (set_tile, 1)
        tags = tags_ref[...]
        age = age_ref[...]
        hitv = tags == b
        hit = hitv.any(axis=1, keepdims=True)
        way = jnp.where(
            hit,
            jnp.argmax(hitv, axis=1, keepdims=True),
            jnp.argmin(age, axis=1, keepdims=True),
        ).astype(jnp.int32)
        onehot = way == lanes  # (set_tile, ways)
        tags_ref[...] = jnp.where(onehot, b, tags)
        age_ref[...] = jnp.where(onehot, t + 1, age)
        hits_ref[:, pl.ds(t, 1)] = hit.astype(jnp.int32)
        return carry

    jax.lax.fori_loop(0, steps, body, 0)


def _lru_tile_kernel_carry(
    blocks_ref, tags_in_ref, age_in_ref, hits_ref, tags_out_ref, age_out_ref
):
    # Carry variant: tag/age state enters as inputs and leaves as outputs,
    # so chunked passes resume exactly where the previous chunk stopped.
    # Pad steps (b == -1) still emit a (never-gathered) hit bit but are
    # masked out of the update — a pad must not evict a carried line or
    # refresh an empty way's age in the state handed back to the host.
    ways = tags_in_ref.shape[1]
    tags_out_ref[...] = tags_in_ref[...]
    age_out_ref[...] = age_in_ref[...]
    steps = blocks_ref.shape[1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def body(t, carry):
        b = blocks_ref[:, pl.ds(t, 1)]  # (set_tile, 1)
        tags = tags_out_ref[...]
        age = age_out_ref[...]
        hitv = tags == b
        hit = hitv.any(axis=1, keepdims=True)
        way = jnp.where(
            hit,
            jnp.argmax(hitv, axis=1, keepdims=True),
            jnp.argmin(age, axis=1, keepdims=True),
        ).astype(jnp.int32)
        onehot = (way == lanes) & (b >= 0)  # (set_tile, ways)
        tags_out_ref[...] = jnp.where(onehot, b, tags)
        age_out_ref[...] = jnp.where(onehot, t + 1, age)
        hits_ref[:, pl.ds(t, 1)] = hit.astype(jnp.int32)
        return carry

    jax.lax.fori_loop(0, steps, body, 0)


@functools.partial(jax.jit, static_argnames=("set_tile", "interpret"))
def lru_hits_carry(
    padded: jnp.ndarray,  # (sets, L) int32 substream matrix, tail-padded -1
    tags0: jnp.ndarray,  # (sets, ways) int32 carried tags (-1 empty)
    age0: jnp.ndarray,  # (sets, ways) int32 carried ages
    set_tile: int = 8,
    interpret: bool = False,
):
    """Hit mask plus final (raw) tag/age state, resuming from a carry."""
    sets, length = padded.shape
    ways = tags0.shape[1]
    assert sets % set_tile == 0, (sets, set_tile)
    grid = (sets // set_tile,)
    state_spec = pl.BlockSpec((set_tile, ways), lambda i: (i, 0))
    return pl.pallas_call(
        _lru_tile_kernel_carry,
        grid=grid,
        in_specs=[
            pl.BlockSpec((set_tile, length), lambda i: (i, 0)),
            state_spec,
            state_spec,
        ],
        out_specs=[
            pl.BlockSpec((set_tile, length), lambda i: (i, 0)),
            state_spec,
            state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sets, length), jnp.int32),
            jax.ShapeDtypeStruct((sets, ways), jnp.int32),
            jax.ShapeDtypeStruct((sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )(padded, tags0, age0)


@functools.partial(jax.jit, static_argnames=("ways", "set_tile", "interpret"))
def lru_hits(
    padded: jnp.ndarray,  # (sets, L) int32 substream matrix, tail-padded -1
    ways: int,
    set_tile: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-cell hit mask (int32 0/1) of the padded substream matrix."""
    sets, length = padded.shape
    assert sets % set_tile == 0, (sets, set_tile)
    grid = (sets // set_tile,)
    return pl.pallas_call(
        _lru_tile_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((set_tile, length), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((set_tile, length), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sets, length), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((set_tile, ways), jnp.int32),  # tags
            pltpu.VMEM((set_tile, ways), jnp.int32),  # ages
        ],
        interpret=interpret,
    )(padded)
