"""Public API: full-stream cache pass through the Pallas set-parallel kernel.

Reuses the engine's stable group-by-set partitioning so the kernel, the
batched-scan engine, and the serial reference all consume identical padded
substreams — the kernel only changes *where* the per-set machines run.

Backend gating: on TPU the kernel compiles natively; off-TPU it falls back
to interpret mode, which validates semantics (tests) but is not a fast
path — the default ``set_parallel`` engine is the CPU production path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cache_sim.cache_sim import lru_hits
from repro.kernels.cache_sim.ref import lru_hits_ref

__all__ = ["cache_pass_pallas", "lru_hits", "lru_hits_ref"]


def cache_pass_pallas(
    blocks: np.ndarray,
    sets: int,
    ways: int,
    set_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> np.ndarray:
    """Hit mask of one cache level, computed by the Pallas kernel.

    Same contract (and bit-identical output) as
    :func:`repro.memsim.engine.cache_pass`.
    """
    if len(blocks) == 0:
        return np.zeros(0, dtype=bool)
    from repro.memsim.engine import group_by_set  # lazy: avoids import cycle

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if set_tile is None:
        set_tile = min(sets, 8)
    padded, order, col, row = group_by_set(blocks, sets)
    mat = np.ascontiguousarray(padded.T)  # (sets, L): sets->sublanes
    hits = np.asarray(
        lru_hits(jnp.asarray(mat), ways, set_tile=set_tile, interpret=interpret)
    )
    out = np.zeros(len(blocks), dtype=bool)
    out[order] = hits[row, col].astype(bool)
    return out
