"""Public API: full-stream cache pass through the Pallas set-parallel kernel.

Reuses the engine's stable group-by-set partitioning so the kernel, the
batched-scan engine, and the serial reference all consume identical padded
substreams — the kernel only changes *where* the per-set machines run.

Backend gating: on TPU the kernel compiles natively; off-TPU it falls back
to interpret mode, which validates semantics (tests) but is not a fast
path — the default ``set_parallel`` engine is the CPU production path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cache_sim.cache_sim import lru_hits, lru_hits_carry
from repro.kernels.cache_sim.ref import lru_hits_ref

__all__ = ["cache_pass_pallas", "lru_hits", "lru_hits_carry", "lru_hits_ref"]


def cache_pass_pallas(
    blocks: np.ndarray,
    sets: int,
    ways: int,
    set_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
    state=None,
    return_state: bool = False,
):
    """Hit mask of one cache level, computed by the Pallas kernel.

    Same contract (and bit-identical output) as
    :func:`repro.memsim.engine.cache_pass`, including the canonical
    :class:`~repro.memsim.engine.CacheState` carry for chunked passes.
    """
    from repro.memsim import engine  # lazy: avoids import cycle

    if len(blocks) == 0:
        hits = np.zeros(0, dtype=bool)
        if not return_state:
            return hits
        st = state if state is not None else engine.init_state(sets, ways)
        return hits, engine.CacheState(st.tags.copy(), st.age.copy())
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if set_tile is None:
        set_tile = min(sets, 8)
    padded, order, col, row = engine.group_by_set(blocks, sets)
    mat = np.ascontiguousarray(padded.T)  # (sets, L): sets->sublanes
    if state is None and not return_state:
        hits = np.asarray(
            lru_hits(
                jnp.asarray(mat), ways, set_tile=set_tile, interpret=interpret
            )
        )
    else:
        st = state if state is not None else engine.init_state(sets, ways)
        hits, tags1, age1 = lru_hits_carry(
            jnp.asarray(mat),
            jnp.asarray(st.tags),
            jnp.asarray(st.age),
            set_tile=set_tile,
            interpret=interpret,
        )
        hits = np.asarray(hits)
    out = np.zeros(len(blocks), dtype=bool)
    out[order] = hits[row, col].astype(bool)
    if not return_state:
        return out
    return out, engine.canonicalize_state(np.asarray(tags1), np.asarray(age1))
