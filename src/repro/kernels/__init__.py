"""Pallas TPU kernels for the compute hot-spots.

  flash_attn  -- blocked attention (MXU tiles, online softmax) for the
                 prefill_32k cells
  amc_gather  -- the paper's technique on TPU: recorded-index-stream gather
                 with double-buffered HBM->VMEM pipelining (DESIGN.md §2.2)
  basedelta   -- BaseΔ compression of recorded index/miss streams (Fig 5/6)
  cache_sim   -- set-parallel LRU cache simulation (the memsim engine's
                 per-set machines: sets tile the grid, tag/age carry in
                 VMEM scratch) for TPU-side trace evaluation
  ssd_scan    -- Mamba2 SSD chunk kernel (intra-chunk MXU matmuls + carried
                 state) for the ssm/hybrid archs

Each kernel ships with ``ops.py`` (jitted wrapper with shape plumbing) and
``ref.py`` (pure-jnp oracle); tests sweep shapes/dtypes in interpret mode
(this container is CPU-only; TPU is the *target*).
"""
