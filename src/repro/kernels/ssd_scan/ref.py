"""Oracle: the model stack's chunked SSD (itself tested against a naive
sequential recurrence in tests/test_ssm.py)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, a, b, c, chunk=128):
    """Same layout as the kernel: (BH, S, P) x per-BH scalar a."""
    bh, s, p = x.shape
    # route through ssd_chunked with H=1 per (batch*head) slice
    outs = []
    for i in range(bh):
        y, _ = ssd_chunked(
            x[i][None, :, None, :],  # (1, S, 1, P)
            dt[i][None, :, None],  # (1, S, 1)
            a[i][None],  # (1,)
            b[i][None],  # (1, S, N)
            c[i][None],
            chunk=chunk,
        )
        outs.append(y[0, :, 0])
    return jnp.stack(outs)


def ssd_naive(x, dt, a, b, c):
    """O(S) sequential recurrence, the ground truth for both."""
    bh, s, p = x.shape
    n = b.shape[-1]
    y = np.zeros((bh, s, p), np.float32)
    for i in range(bh):
        state = np.zeros((p, n), np.float32)
        for t in range(s):
            decay = np.exp(float(dt[i, t]) * float(a[i]))
            state = state * decay + np.outer(
                x[i, t] * dt[i, t], b[i, t]
            )
            y[i, t] = state @ np.asarray(c[i, t])
    return y
