"""Mamba2 SSD chunk kernel (TPU target, interpret-validated).

One grid step per (batch*head, chunk). The inter-chunk recurrent state
(P x N, float32) lives in VMEM scratch and is carried across the chunk
dimension (innermost grid axis), so the kernel computes

  intra:  Y = ((C Bᵀ) ⊙ decay ⊙ causal) · (dt ⊙ X)       (MXU matmuls)
  state:  S' = S * seg_decay + Bᵀ · (w ⊙ X)
  inter:  Y += (C · S) ⊙ in_decay

matching :func:`repro.models.ssm.ssd_chunked` (the oracle) exactly.
Chunk length and head dim are the MXU-facing tile dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L, 1)
    a = a_ref[0, 0]  # scalar decay rate for this head
    b = b_ref[0].astype(jnp.float32)  # (L, N)
    c = c_ref[0].astype(jnp.float32)  # (L, N)

    da = dt[:, 0] * a  # (L,)
    cum = jnp.cumsum(da)  # within-chunk cumulative log decay
    # intra-chunk
    decay = jnp.exp(cum[:, None] - cum[None, :])  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (li >= lj).astype(jnp.float32)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (L, L)
    w = cb * decay * causal
    xdt = x * dt  # (L, P)
    y = jnp.dot(w, xdt, preferred_element_type=jnp.float32)
    # inter-chunk: contribution of incoming state
    state = state_scr[...]  # (P, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        c, state.T, preferred_element_type=jnp.float32
    )
    # state update
    sw = jnp.exp(cum[-1] - cum) * dt[:, 0]  # (L,)
    new_contrib = jnp.dot((x * sw[:, None]).T, b, preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cum[-1]) + new_contrib
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,  # (BH, S, P)
    dt: jnp.ndarray,  # (BH, S)
    a: jnp.ndarray,  # (BH,)
    b: jnp.ndarray,  # (BH, S, N)
    c: jnp.ndarray,  # (BH, S, N)
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, p = x.shape
    n = b.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, 1), lambda h, i: (h, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc * chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], a[:, None], b, c)
    return out[:, :s]
