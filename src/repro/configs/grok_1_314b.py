"""grok-1-314b [moe]: 64L d=6144 48H (GQA kv=8) d_ff=32768 v=131072,
MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe_experts=8,
    moe_top_k=2,
    supports_long_context=False,  # full attention
    notes="AMC-technique applicable: recorded-dispatch MoE gathers.",
)
