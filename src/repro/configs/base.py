"""Model + run configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``src/repro/configs/<id>.py``), selectable via ``--arch <id>`` in the
launchers. ``reduced()`` gives the CPU smoke-test variant (same family,
tiny dims); the full config is exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False  # M-RoPE 3-section rotary (qwen2-vl)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w (half-dims)
    sliding_window: int = 0  # 0 = full attention
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # Hybrid (Zamba2-style): shared attention block every N ssm layers
    hybrid_attn_every: int = 0
    # Encoder-decoder (Whisper backbone)
    encoder_layers: int = 0
    frontend: str = "none"  # none | audio | vision (stub embeddings)
    # Training
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Perf iteration 2 (EXPERIMENTS §5): bf16 compute weights halve the
    # FSDP weight-gather traffic; AdamW keeps fp32 math and m/v state.
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for llama3-405b (memory note)
    remat_policy: str = "dots"  # none | dots | full
    # scan-over-layers keeps compile time O(1) in depth; the layer-probe
    # unrolls (False) because XLA cost_analysis does not descend into
    # while-loop bodies (see launch/layer_probe.py).
    scan_layers: bool = True
    # Attention applicability notes
    supports_long_context: bool = False  # sub-quadratic path exists
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def q_groups(self) -> int:
        return max(self.num_heads // max(self.num_kv_heads, 1), 1)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        mlp = 3 * d * dff  # SwiGLU
        if self.moe_experts:
            mlp = self.moe_experts * 3 * d * dff + d * self.moe_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            ssm = d * (2 * d_in + 2 * self.ssm_state * nh // max(nh, 1) + nh) + d_in * d
            ssm += 2 * self.ssm_state * d_in  # B,C projections approx
        per_layer = {
            "dense": attn + mlp,
            "moe": attn + mlp,
            "vlm": attn + mlp,
            "encdec": attn + mlp,
            "ssm": ssm + 0,
            "hybrid": ssm,
        }[self.family]
        total = self.num_layers * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += attn + mlp  # one shared block
        if self.family == "encdec":
            total += self.encoder_layers * (2 * attn + mlp)  # self+cross approx
        total += v * d * (1 if self.tie_embeddings else 2)
        total += 2 * d * self.num_layers  # norms
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k)."""
        if not self.moe_experts:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        full_mlp = self.moe_experts * 3 * d * dff
        active_mlp = self.moe_top_k * 3 * d * dff
        return self.param_count() - self.num_layers * (full_mlp - active_mlp)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 + (2 if self.hybrid_attn_every else 0)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            moe_experts=min(self.moe_experts, 4),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            ssm_chunk=16,
            encoder_layers=min(self.encoder_layers, 2),
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            mrope_sections=(4, 6, 6),
            dtype="float32",
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "mixtral_8x22b",
    "grok_1_314b",
    "zamba2_1p2b",
    "whisper_tiny",
    "qwen3_4b",
    "llama3_405b",
    "glm4_9b",
    "smollm_360m",
    "mamba2_780m",
    "qwen2_vl_7b",
]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, with the DESIGN.md skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention (DESIGN.md §4)"
    return True, ""
