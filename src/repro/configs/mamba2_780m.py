"""mamba2-780m [ssm]: 48L d=1536 (attn-free) v=50280, ssm_state=128, SSD
[arXiv:2405.21060; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,  # padded to 50288 for 16-way vocab sharding
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    supports_long_context=True,  # O(1)/token decode state
    notes="Attention-free: AMC technique inapplicable (DESIGN.md §4).",
)
