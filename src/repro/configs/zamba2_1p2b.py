"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 v=32000,
ssm_state=64, Mamba2 + shared attn blocks [arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    hybrid_attn_every=6,  # shared transformer block after every 6 mamba blocks
    supports_long_context=True,  # SSM backbone; attn decodes vs sharded cache
    notes=(
        "Shared-block LoRA adapters of the HF release omitted (DESIGN.md); "
        "AMC technique applies to embedding gathers only."
    ),
)
