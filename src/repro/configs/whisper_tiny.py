"""whisper-tiny [audio]: 4L d=384 6H d_ff=1536 v=51865, enc-dec, conv
frontend STUB (input_specs supplies precomputed frame embeddings)
[arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,  # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,  # padded to 51872 for 16-way vocab sharding
    head_dim=64,
    frontend="audio",
    supports_long_context=False,
    notes="Conv frontend stubbed per assignment; AMC technique inapplicable (dense).",
)
