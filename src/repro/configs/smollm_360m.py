"""smollm-360m [dense]: 32L d=960 15H (GQA kv=5) d_ff=2560 v=49152,
llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    tie_embeddings=True,
    supports_long_context=False,
    notes="15 heads not divisible by model axis: attention replicated, MLP/vocab sharded.",
)
