"""llama3-405b [dense]: 126L d=16384 128H (GQA kv=8) d_ff=53248 v=128256
[arXiv:2407.21783; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    opt_state_dtype="bfloat16",  # 405B fp32 m/v does not fit 256x16GB
    supports_long_context=False,
    notes="FSDP(data)+TP(model) sharding; bf16 optimizer state (EXPERIMENTS §3).",
)
