"""Architecture configs: one module per assigned arch + the paper's own."""
from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_supported,
    get_config,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "cell_supported",
    "get_config",
]
