"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) d_ff=9728 v=151936, qk_norm
[hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    supports_long_context=False,
    notes="AMC technique inapplicable (dense); embedding gathers only.",
)
