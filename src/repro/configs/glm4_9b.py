"""glm4-9b [dense]: 40L d=4096 32H (GQA kv=2) d_ff=13696 v=151552, RoPE
[hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    supports_long_context=False,
    notes="Extreme GQA (kv=2): KV replicated across model shards.",
)
