"""qwen2-vl-7b [vlm]: 28L d=3584 28H (GQA kv=4) d_ff=18944 v=152064,
M-RoPE, dynamic resolution (patch frontend STUB) [arXiv:2409.12191; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision",
    supports_long_context=False,
    notes="28 heads not divisible by model axis: attention replicated, MLP/vocab sharded; patch frontend stubbed.",
)
