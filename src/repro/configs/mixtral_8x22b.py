"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) d_ff=16384 v=32768,
MoE 8e top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1e6,
    sliding_window=4096,
    moe_experts=8,
    moe_top_k=2,
    supports_long_context=True,  # SWA bounds the KV cache
    notes="AMC-technique applicable: recorded-dispatch MoE gathers.",
)
