"""Stream protocol: multi-epoch evolving-graph evaluation on the
Experiment engine.

A :class:`StreamSpec` declares one evolving-graph scenario — kernel,
dataset, churn model, epoch count, AMC table-lifecycle policy — and plugs
into the existing machinery like a :class:`~repro.core.driver.WorkloadSpec`:

- **Per-epoch traces, built once, cached.**  The spec expands into E
  :class:`StreamEpochSpec` workload specs (hashable, content-addressable),
  each building the kernel run on snapshot ``g_e`` of the deterministic
  :func:`~repro.stream.snapshots.snapshot_sequence`.  They duck-type
  ``WorkloadSpec`` everywhere it matters, so the
  :class:`~repro.core.exec.artifacts.ArtifactCache` persists them and the
  parallel scheduler materializes epochs of one stream as independent
  chunks across the pool.
- **Shared address layout.**  All epochs are traced in one address space
  (``num_edges`` = the stream's maximum), so a vertex's property/frontier
  addresses — and therefore AMC's recorded correlations — are
  commensurable across the whole stream.  The §VI caveat generalizes: one
  root, present in every epoch, is picked for the traversal kernels.
- **Epoch = graph version.**  Each epoch trace is a single AMC epoch with
  the iteration index as the within-epoch key: epoch ``e`` replays what
  epoch ``e-1`` recorded (BFS level *j* against the previous version's
  level *j*), exactly the two-run protocol stretched to E runs.  The
  :class:`~repro.stream.lifecycle.TableLifecycle` owns the carry policy at
  each boundary; stateless baselines score each epoch independently.
- **Drift curves.**  :func:`drift_payload` aggregates per-epoch metrics
  against the sequence's overlap/churn statistics into the
  ``stream-drift`` JSON schema consumed by ``benchmarks/figures.py``'s
  ``fig_drift`` and the CI smoke artifact.

The scoring path is deliberately identical for serial and parallel runs —
workers only ever *materialize* epoch traces; the lifecycle walk happens
in the parent, so ``workers=N`` results are byte-identical to serial.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.apps import get_kernel, has_kernel, list_kernels
from repro.apps.trace import TraceConfig
from repro.core.driver import (
    WorkloadTrace,
    _build_workload,
    make_session,
)
from repro.core.exec.timers import stage
from repro.core.obs import spans as obs
from repro.graphs import DATASETS, make_dataset
from repro.memsim import SCALED, HierarchyConfig, PrefetchMetrics
from repro.memsim.metrics import summarize_epochs
from repro.stream.lifecycle import LIFECYCLE_POLICIES, TableLifecycle
from repro.stream.snapshots import SnapshotSequence, snapshot_sequence


def _validate_elem_sizes(target: int, frontier: int) -> None:
    if target < 1 or frontier < 1:
        raise ValueError("element sizes must be >= 1 byte")
    if target % frontier:
        raise ValueError(
            f"target_elem_size ({target}) must be an integer multiple of "
            f"frontier_elem_size ({frontier})"
        )


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Declarative multi-epoch evolving-graph scenario.

    Epoch traces are lifecycle-agnostic (the policy only steers scoring),
    so streams differing only in ``lifecycle`` share every cached epoch
    trace — comparing ``persist`` vs ``reset`` costs one extra scoring
    pass, not a rebuild.
    """

    kernel: str
    dataset: str
    churn: object  # a churn model from repro.stream.updates
    epochs: int = 4
    lifecycle: str = "persist"
    max_age: int = 2  # for the "age" policy
    hierarchy: HierarchyConfig = SCALED
    seed: int = 0
    target_elem_size: int = 8
    frontier_elem_size: int = 1

    # Duck-typing marker: Experiment routes these through the stream
    # protocol without importing it at declaration time.
    is_stream: ClassVar[bool] = True

    def __post_init__(self):
        if self.epochs < 2:
            raise ValueError(f"a stream needs >= 2 epochs, got {self.epochs}")
        if self.lifecycle not in LIFECYCLE_POLICIES:
            raise ValueError(
                f"unknown lifecycle {self.lifecycle!r}; "
                f"available: {list(LIFECYCLE_POLICIES)}"
            )
        if not hasattr(self.churn, "generate"):
            raise TypeError(
                f"churn must be a churn model (see repro.stream.updates); "
                f"got {self.churn!r}"
            )
        _validate_elem_sizes(self.target_elem_size, self.frontier_elem_size)

    def validate_names(self) -> None:
        if not has_kernel(self.kernel):
            raise ValueError(
                f"unknown kernel {self.kernel!r}; "
                f"available: {sorted(list_kernels())}"
            )
        if self.dataset not in DATASETS:
            raise ValueError(
                f"unknown dataset {self.dataset!r}; available: {sorted(DATASETS)}"
            )

    def epoch_specs(self) -> List["StreamEpochSpec"]:
        return [
            StreamEpochSpec(
                kernel=self.kernel,
                dataset=self.dataset,
                churn=self.churn,
                epochs=self.epochs,
                epoch=e,
                hierarchy=self.hierarchy,
                seed=self.seed,
                target_elem_size=self.target_elem_size,
                frontier_elem_size=self.frontier_elem_size,
            )
            for e in range(self.epochs)
        ]

    def sequence(self) -> SnapshotSequence:
        """The (memoized) snapshot sequence behind this stream."""
        return _sequence_for(
            self.kernel, self.dataset, self.churn, self.epochs, self.seed
        )


@dataclasses.dataclass(frozen=True)
class StreamEpochSpec:
    """One epoch of a stream as a cacheable, schedulable workload spec.

    Field-compatible with :class:`~repro.core.driver.WorkloadSpec` where
    the engine cares (kernel/dataset/hierarchy/seed/element sizes), plus
    the stream identity (churn, total epochs) and the epoch index — all of
    which land in the artifact content hash, so epoch traces are
    content-addressed like any workload.
    """

    kernel: str
    dataset: str
    churn: object
    epochs: int
    epoch: int
    hierarchy: HierarchyConfig = SCALED
    seed: int = 0
    target_elem_size: int = 8
    frontier_elem_size: int = 1

    def __post_init__(self):
        if not (0 <= self.epoch < self.epochs):
            raise ValueError(f"epoch {self.epoch} outside [0, {self.epochs})")
        _validate_elem_sizes(self.target_elem_size, self.frontier_elem_size)

    def validate_names(self) -> None:
        StreamSpec.validate_names(self)  # same name checks

    def build(self) -> WorkloadTrace:
        """Run the kernel on snapshot ``epoch`` and trace it in the
        stream's shared address layout (timed as ``trace_epoch``)."""
        self.validate_names()
        with obs.span(
            "build_epoch",
            kernel=self.kernel,
            dataset=self.dataset,
            epoch=self.epoch,
            churn=self.churn,
        ), stage("trace_epoch"):
            seq = _sequence_for(
                self.kernel, self.dataset, self.churn, self.epochs, self.seed
            )
            run = _run_epoch(self.kernel, seq, self.epoch)
            cfg_trace = TraceConfig(
                num_vertices=seq.base.num_vertices, num_edges=seq.max_edges
            )
            return _build_workload(
                self, runs=[run], cfg_trace=cfg_trace, epoch_mode="single"
            )

    def content_key(self) -> dict:
        """Identity of the trace this spec *builds*, not how it was declared.

        Everything the emitted trace is determined by: the epoch's graph
        content (CSR arrays + presence mask, as a SHA-256), the shared
        root, the stream-wide address layout, and the kernel/hierarchy/
        element-size configuration.  The artifact cache keys content-keyed
        specs on this document, so an epoch whose graph the churn model
        left unchanged — or the same graph version reached through
        different stream parameters — resolves to the *same* artifact and
        is reused instead of re-emitted (delta-aware trace reuse).
        """
        ks = get_kernel(self.kernel)
        seq = _sequence_for(
            self.kernel, self.dataset, self.churn, self.epochs, self.seed
        )
        key = _seq_key(self.kernel, self.dataset, self.churn, self.epochs, self.seed)
        return {
            "kind": "stream-epoch",
            "kernel": self.kernel,
            "direction": ks.direction,
            "hierarchy": dataclasses.asdict(self.hierarchy),
            "elem_sizes": [self.target_elem_size, self.frontier_elem_size],
            "layout": [int(seq.base.num_vertices), int(seq.max_edges)],
            "root": _epoch_root(self.kernel, seq),
            "graph_sha256": _epoch_fingerprint(key, seq, self.epoch),
        }


# Snapshot sequences are deterministic in (kernel's weightedness, dataset,
# churn, epochs, seed); memoize per process so E epoch builds and the
# scoring walk share one sequence.
_SEQ_CACHE: Dict[tuple, SnapshotSequence] = {}

# Per-epoch graph fingerprints, memoized alongside the sequence: hashing
# the CSR arrays costs milliseconds but runs once per (sequence, epoch)
# per process, not once per cache probe.
_FP_CACHE: Dict[tuple, str] = {}


def _seq_key(kernel: str, dataset: str, churn, epochs: int, seed: int) -> tuple:
    return (dataset, get_kernel(kernel).weighted, churn, epochs, seed)


def _sequence_for(
    kernel: str, dataset: str, churn, epochs: int, seed: int
) -> SnapshotSequence:
    key = _seq_key(kernel, dataset, churn, epochs, seed)
    if key not in _SEQ_CACHE:
        base = make_dataset(dataset, weighted=get_kernel(kernel).weighted)
        _SEQ_CACHE[key] = snapshot_sequence(base, churn, epochs, seed=seed)
    return _SEQ_CACHE[key]


def _epoch_fingerprint(seq_key: tuple, seq: SnapshotSequence, epoch: int) -> str:
    """SHA-256 over epoch ``epoch``'s graph content: CSR offsets,
    neighbors, weights (when present) and the vertex presence mask —
    exactly the inputs the kernel run sees."""
    key = (seq_key, epoch)
    if key not in _FP_CACHE:
        g = seq.graphs[epoch]
        h = hashlib.sha256()
        for arr in (g.offsets, g.neighbors, g.weights, seq.masks[epoch]):
            if arr is None:
                h.update(b"|none")
                continue
            a = np.ascontiguousarray(arr)
            h.update(f"|{a.dtype}{a.shape}|".encode())
            h.update(a.tobytes())
        _FP_CACHE[key] = h.hexdigest()
    return _FP_CACHE[key]


def _epoch_root(kernel: str, seq: SnapshotSequence) -> Optional[int]:
    """The stream's shared traversal root (None for rootless kernels).

    The paper's BFS caveat, stretched to E epochs: one root, present in
    every epoch, so the traversals stay correlated end to end.
    """
    ks = get_kernel(kernel)
    if not ks.needs_root:
        return None
    from repro.apps.bfs import pick_root

    always = np.logical_and.reduce(seq.masks)
    return int(
        pick_root(seq.graphs[0], always if always.any() else seq.masks[0])
    )


def _run_epoch(kernel: str, seq: SnapshotSequence, epoch: int):
    """One kernel run on snapshot ``epoch`` (shared root for traversals)."""
    ks = get_kernel(kernel)
    return ks.run(
        seq.graphs[epoch],
        present_mask=seq.masks[epoch],
        root=_epoch_root(kernel, seq),
    )


# --------------------------------------------------------------- scoring


@dataclasses.dataclass(frozen=True)
class EpochCell:
    """One (epoch, prefetcher) score within a stream."""

    epoch: int
    prefetcher: str
    lifecycle: Optional[str]  # None for stateless (per-epoch) baselines
    metrics: PrefetchMetrics
    spec: StreamEpochSpec


def _is_amc_generator(gen) -> bool:
    from repro.core.amc.prefetcher import AMCPrefetcher

    return isinstance(getattr(gen, "__self__", None), AMCPrefetcher)


def score_stream(
    spec: StreamSpec,
    prefetchers: Sequence[Tuple[str, object]],
    traces: Sequence[WorkloadTrace],
) -> List[EpochCell]:
    """Score every prefetcher over the epoch sequence.

    AMC-family generators (bound ``AMCPrefetcher.generate`` methods) walk
    the epochs with one carried :class:`TableLifecycle`; everything else is
    stateless and scores each epoch independently.  Deterministic given the
    traces — the serial/parallel parity of the stream protocol rests here.
    """
    from repro.core.experiment import score_prefetcher

    seq = spec.sequence()
    epoch_specs = spec.epoch_specs()
    cells: List[EpochCell] = []
    for name, gen in prefetchers:
        if _is_amc_generator(gen):
            cfg = gen.__self__.config
            # A fresh session per scoring walk: the lifecycle advances its
            # graph-version counter, and the cached trace's session must
            # stay pristine so repeat runs score identically.
            lc = TableLifecycle(
                spec.lifecycle,
                capacity_bytes=int(cfg.storage_fraction * traces[0].input_bytes),
                max_age=spec.max_age,
                session=make_session(spec, traces[0].cfg_trace),
            )
            for e, trace in enumerate(traces):
                with obs.span(
                    "stream_epoch",
                    epoch=e,
                    prefetcher=name,
                    lifecycle=spec.lifecycle,
                    churn=spec.churn,
                ):
                    storage = lc.begin_epoch(e)

                    def with_carry(workload, _gen=gen, _storage=storage):
                        return _gen(workload, storage=_storage)

                    m = score_prefetcher(trace, name, with_carry)
                    changed = (
                        seq.changed_vertices(e + 1)
                        if e + 1 < spec.epochs
                        else None
                    )
                    report = lc.end_epoch(e, changed_vids=changed)
                    m.info.update(lifecycle=spec.lifecycle, table=report.row())
                cells.append(
                    EpochCell(
                        epoch=e,
                        prefetcher=name,
                        lifecycle=spec.lifecycle,
                        metrics=m,
                        spec=epoch_specs[e],
                    )
                )
        else:
            for e, trace in enumerate(traces):
                with obs.span(
                    "stream_epoch", epoch=e, prefetcher=name, churn=spec.churn
                ):
                    m = score_prefetcher(trace, name, gen)
                cells.append(
                    EpochCell(
                        epoch=e,
                        prefetcher=name,
                        lifecycle=None,
                        metrics=m,
                        spec=epoch_specs[e],
                    )
                )
    return cells


def run_stream(
    spec: StreamSpec,
    prefetchers,
    cache=None,
    workers: Optional[int] = None,
    verbose: bool = False,
) -> "StreamResult":
    """Convenience wrapper: one stream through the Experiment engine."""
    from repro.core.experiment import Experiment

    exp = Experiment(workloads=[spec], prefetchers=prefetchers, cache=cache)
    result = exp.run(workers=workers, verbose=verbose)
    return StreamResult(
        spec=spec,
        sequence=spec.sequence(),
        cells=[
            EpochCell(
                epoch=c.epoch,
                prefetcher=c.prefetcher,
                lifecycle=c.lifecycle,
                metrics=c.metrics,
                spec=c.spec,
            )
            for c in result.cells
        ],
    )


@dataclasses.dataclass
class StreamResult:
    """Per-epoch cells + the snapshot sequence they were scored against."""

    spec: StreamSpec
    sequence: SnapshotSequence
    cells: List[EpochCell]

    def epoch_metrics(self, prefetcher: str) -> List[PrefetchMetrics]:
        out = [c.metrics for c in self.cells if c.prefetcher == prefetcher]
        if not out:
            raise KeyError(
                f"prefetcher {prefetcher!r} not in stream result; "
                f"have {sorted({c.prefetcher for c in self.cells})}"
            )
        return out

    def drift(self) -> dict:
        return drift_payload(self.spec, self.sequence, self.cells)


def drift_payload(
    spec: StreamSpec, seq: SnapshotSequence, cells: Sequence[EpochCell]
) -> dict:
    """The ``stream-drift`` JSON document: per-epoch metric curves per
    prefetcher against the stream's overlap/churn trajectory."""
    by_pf: Dict[str, List[EpochCell]] = {}
    for c in cells:
        by_pf.setdefault(c.prefetcher, []).append(c)
    prefetchers = {}
    for name, pf_cells in by_pf.items():
        pf_cells = sorted(pf_cells, key=lambda c: c.epoch)
        ms = [c.metrics for c in pf_cells]
        prefetchers[name] = {
            "lifecycle": pf_cells[0].lifecycle,
            "summary": summarize_epochs(ms),
            "per_epoch": [
                {
                    "epoch": c.epoch,
                    "speedup": c.metrics.speedup,
                    "coverage": c.metrics.coverage,
                    "accuracy": c.metrics.accuracy,
                    "useful": c.metrics.useful,
                    "issued": c.metrics.issued,
                    "baseline_l2_misses": c.metrics.baseline_l2_misses,
                    "table": c.metrics.info.get("table"),
                }
                for c in pf_cells
            ],
        }
    return {
        "schema": "stream-drift",
        "kernel": spec.kernel,
        "dataset": spec.dataset,
        "epochs": spec.epochs,
        "seed": spec.seed,
        "lifecycle": spec.lifecycle,
        "churn": {
            "kind": type(spec.churn).kind,
            **dataclasses.asdict(spec.churn),
        },
        "overlap": {
            "vertex_overlap": [s.vertex_overlap for s in seq.stats],
            "cumulative_overlap": [s.cumulative_overlap for s in seq.stats],
            "edge_churn": [s.edge_churn for s in seq.stats],
            "num_edges": [s.num_edges for s in seq.stats],
            "active_vertices": [s.active_vertices for s in seq.stats],
        },
        "prefetchers": prefetchers,
    }


__all__ = [
    "EpochCell",
    "StreamEpochSpec",
    "StreamResult",
    "StreamSpec",
    "drift_payload",
    "run_stream",
    "score_stream",
]
