"""Multi-epoch evolving-graph streaming subsystem.

Turns the paper's §VI two-snapshot protocol into a scenario engine: churn
models generate deterministic batched update streams (:mod:`updates`),
delta application yields an epoch sequence of CSR snapshots with churn
stats (:mod:`snapshots`), the AMC correlation tables are carried across
epoch boundaries under pluggable lifecycle policies (:mod:`lifecycle`),
and :mod:`protocol` ties it into the ``Experiment`` grid — per-epoch
traces cached as workload artifacts, per-epoch metrics, drift-curve
aggregates.

The update/snapshot layers depend only on the graph substrate; the
lifecycle and protocol layers (which pull in the AMC core and the
execution engine) load lazily on first attribute access, so
``repro.graphs`` can build on snapshots without a circular import.
"""
from repro.stream.snapshots import (
    EpochStats,
    SnapshotSequence,
    apply_delta,
    snapshot_sequence,
)
from repro.stream.updates import (
    CHURN_MODELS,
    CommunityChurn,
    DeltaBatch,
    PreferentialGrowth,
    SlidingWindow,
    UniformChurn,
    UpdateStream,
)

_LAZY = {
    "LIFECYCLE_POLICIES": "repro.stream.lifecycle",
    "TableLifecycle": "repro.stream.lifecycle",
    "EpochTableReport": "repro.stream.lifecycle",
    "EpochCell": "repro.stream.protocol",
    "StreamEpochSpec": "repro.stream.protocol",
    "StreamResult": "repro.stream.protocol",
    "StreamSpec": "repro.stream.protocol",
    "drift_payload": "repro.stream.protocol",
    "run_stream": "repro.stream.protocol",
    "score_stream": "repro.stream.protocol",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))


__all__ = [
    "CHURN_MODELS",
    "CommunityChurn",
    "DeltaBatch",
    "EpochCell",
    "EpochStats",
    "EpochTableReport",
    "LIFECYCLE_POLICIES",
    "PreferentialGrowth",
    "SlidingWindow",
    "SnapshotSequence",
    "StreamEpochSpec",
    "StreamResult",
    "StreamSpec",
    "TableLifecycle",
    "UniformChurn",
    "UpdateStream",
    "apply_delta",
    "drift_payload",
    "run_stream",
    "score_stream",
    "snapshot_sequence",
]
