"""Batched update-stream generators for multi-epoch evolving graphs.

Real evolving-graph deployments are long streams of batched edge/vertex
updates, not the single snapshot pair of the paper's §VI protocol.  This
module generates those streams *deterministically from a seed*: a churn
model turns a base :class:`~repro.graphs.csr.CSRGraph` into an epoch-0 edge
set plus an ordered sequence of :class:`DeltaBatch` objects (edge inserts +
deletes), one per epoch boundary.  Everything is plain vectorized numpy; the
same ``(model, base, epochs, seed)`` always reproduces the same stream, so
update streams can participate in content-addressed artifact keys.

Churn models (all frozen/hashable, so they embed in ``StreamSpec``):

``SlidingWindow``
    The base edge list in a seeded arrival order, observed through a
    sliding window — epoch ``e`` holds the ``window_frac·m`` most recent
    arrivals, advancing ``step_frac·m`` per epoch (circular, so every epoch
    has the same edge count).  Models timestamped edge streams.
``PreferentialGrowth``
    Pure growth: each epoch inserts ``growth_frac·m`` new edges whose
    endpoints are sampled proportionally to current degree (+1) —
    rich-get-richer densification, no deletions.
``CommunityChurn``
    Vertices are hashed into communities; each epoch toggles a few whole
    communities in/out of the active set.  Models subgraph-level churn
    (tenants, partitions, regions appearing and disappearing).
``UniformChurn``
    The §VI protocol generalized to E epochs: epoch 0 activates
    ``init_frac`` of the vertices, then every boundary deletes
    ``del_frac`` of the active set and adds ``add_frac·n`` fresh vertices.
    For ``epochs=2`` the rng call sequence is exactly the legacy
    ``make_evolving_pair`` one, so the pair protocol is the E=2 special
    case, bit for bit.

Vertex-churn models also publish their per-epoch presence masks
(``UpdateStream.masks``); edge-stream models leave ``masks`` as ``None``
and presence is derived from degree.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, List, Optional, Tuple

import numpy as np

from repro.graphs.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One epoch boundary's worth of edge updates (insert + delete sets)."""

    epoch: int  # the epoch this batch produces (1-based)
    add_src: np.ndarray  # int64
    add_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    add_w: Optional[np.ndarray] = None  # float32 weights for inserted edges

    @property
    def num_inserts(self) -> int:
        return int(len(self.add_src))

    @property
    def num_deletes(self) -> int:
        return int(len(self.del_src))

    @property
    def num_updates(self) -> int:
        return self.num_inserts + self.num_deletes

    def touched_vertices(self) -> np.ndarray:
        """Sorted unique vertex ids incident to any update in this batch."""
        return np.unique(
            np.concatenate(
                [self.add_src, self.add_dst, self.del_src, self.del_dst]
            ).astype(np.int64)
        )


@dataclasses.dataclass(frozen=True)
class UpdateStream:
    """Epoch-0 edge set + one :class:`DeltaBatch` per epoch boundary."""

    num_vertices: int
    init_src: np.ndarray
    init_dst: np.ndarray
    init_w: Optional[np.ndarray]
    batches: Tuple[DeltaBatch, ...]
    # Per-epoch active-vertex masks for vertex-churn models (len = epochs);
    # None for edge-stream models (presence is then degree-derived).
    masks: Optional[Tuple[np.ndarray, ...]] = None

    @property
    def num_epochs(self) -> int:
        return len(self.batches) + 1


def _mask_stream(base: CSRGraph, masks: List[np.ndarray]) -> UpdateStream:
    """Derive the edge-level update stream induced by a mask sequence.

    An edge is live in epoch ``e`` iff both endpoints are active; the batch
    into epoch ``e`` inserts edges that became live and deletes edges that
    stopped being live.  Weights of inserted edges come from the base graph.
    """
    src = base.edge_sources().astype(np.int64)
    dst = base.neighbors.astype(np.int64)
    w = base.weights
    prev = masks[0][src] & masks[0][dst]
    init_w = w[prev] if w is not None else None
    batches = []
    for e, m in enumerate(masks[1:], start=1):
        cur = m[src] & m[dst]
        add = cur & ~prev
        delete = prev & ~cur
        batches.append(
            DeltaBatch(
                epoch=e,
                add_src=src[add],
                add_dst=dst[add],
                del_src=src[delete],
                del_dst=dst[delete],
                add_w=w[add] if w is not None else None,
            )
        )
        prev = cur
    return UpdateStream(
        num_vertices=base.num_vertices,
        init_src=src[masks[0][src] & masks[0][dst]],
        init_dst=dst[masks[0][src] & masks[0][dst]],
        init_w=init_w,
        batches=tuple(batches),
        masks=tuple(masks),
    )


@dataclasses.dataclass(frozen=True)
class UniformChurn:
    """§VI vertex churn generalized to E epochs (E=2 == the paper pair)."""

    init_frac: float = 0.8
    del_frac: float = 0.10
    add_frac: float = 0.10
    kind: ClassVar[str] = "uniform_churn"

    def __post_init__(self):
        if not (0.0 < self.init_frac <= 1.0):
            raise ValueError(f"init_frac must be in (0, 1], got {self.init_frac}")
        if self.del_frac < 0 or self.add_frac < 0:
            raise ValueError("del_frac/add_frac must be >= 0")

    def masks(self, base: CSRGraph, epochs: int, seed: int) -> List[np.ndarray]:
        # The rng call sequence below (one choice for the initial mask, then
        # a delete-choice + add-choice per boundary) reproduces the legacy
        # make_evolving_pair draws exactly when epochs == 2.
        rng = np.random.default_rng(seed)
        n = base.num_vertices
        mask = np.zeros(n, dtype=bool)
        mask[rng.choice(n, size=int(self.init_frac * n), replace=False)] = True
        out = [mask]
        for _ in range(epochs - 1):
            cur = out[-1].copy()
            in_cur = np.flatnonzero(cur)
            out_cur = np.flatnonzero(~cur)
            n_del = int(self.del_frac * len(in_cur))
            n_add = min(int(self.add_frac * n), len(out_cur))
            cur[rng.choice(in_cur, size=n_del, replace=False)] = False
            cur[rng.choice(out_cur, size=n_add, replace=False)] = True
            out.append(cur)
        return out

    def generate(self, base: CSRGraph, epochs: int, seed: int) -> UpdateStream:
        return _mask_stream(base, self.masks(base, epochs, seed))


@dataclasses.dataclass(frozen=True)
class CommunityChurn:
    """Whole communities of vertices toggle in/out of the active set."""

    communities: int = 16
    active_frac: float = 0.75
    swap: int = 2  # communities toggled (each way) per epoch boundary
    kind: ClassVar[str] = "community_churn"

    def __post_init__(self):
        if self.communities < 2:
            raise ValueError("need at least 2 communities")
        if not (0.0 < self.active_frac < 1.0):
            raise ValueError("active_frac must be in (0, 1)")

    def masks(self, base: CSRGraph, epochs: int, seed: int) -> List[np.ndarray]:
        rng = np.random.default_rng(seed)
        comm = rng.integers(0, self.communities, size=base.num_vertices)
        active = np.zeros(self.communities, dtype=bool)
        n_active = max(int(round(self.active_frac * self.communities)), 1)
        active[rng.choice(self.communities, size=n_active, replace=False)] = True
        out = [active[comm]]
        for _ in range(epochs - 1):
            act = np.flatnonzero(active)
            inact = np.flatnonzero(~active)
            k = min(self.swap, len(act), len(inact))
            active = active.copy()
            active[rng.choice(act, size=k, replace=False)] = False
            active[rng.choice(inact, size=k, replace=False)] = True
            out.append(active[comm])
        return out

    def generate(self, base: CSRGraph, epochs: int, seed: int) -> UpdateStream:
        return _mask_stream(base, self.masks(base, epochs, seed))


@dataclasses.dataclass(frozen=True)
class SlidingWindow:
    """Timestamped edge stream seen through a sliding window."""

    window_frac: float = 0.75
    step_frac: float = 0.05
    kind: ClassVar[str] = "sliding_window"

    def __post_init__(self):
        if not (0.0 < self.window_frac <= 1.0):
            raise ValueError("window_frac must be in (0, 1]")
        if self.step_frac <= 0:
            raise ValueError("step_frac must be > 0")
        if self.window_frac + self.step_frac > 1.0:
            raise ValueError(
                "window_frac + step_frac must be <= 1 (the circular window "
                "may not lap itself within one epoch step)"
            )

    def generate(self, base: CSRGraph, epochs: int, seed: int) -> UpdateStream:
        rng = np.random.default_rng(seed)
        m = base.num_edges
        if m == 0:
            empty = np.zeros(0, np.int64)
            batches = tuple(
                DeltaBatch(e, empty, empty, empty, empty) for e in range(1, epochs)
            )
            return UpdateStream(base.num_vertices, empty, empty, None, batches)
        order = rng.permutation(m)  # seeded arrival order of the base edges
        src = base.edge_sources().astype(np.int64)[order]
        dst = base.neighbors.astype(np.int64)[order]
        w = base.weights[order] if base.weights is not None else None
        step = max(int(round(self.step_frac * m)), 1)
        # The fraction guard in __post_init__ bounds window+step on the
        # *fractions*; after integer rounding the sum can still exceed m
        # (e.g. 0.95+0.05 on m=10 rounds to 10+1), which would make leave
        # and enter indices coincide — a window that silently never moves
        # while the stats report churn.  Clamp so the window always slides.
        window = min(max(int(round(self.window_frac * m)), 1), max(m - step, 1))
        batches = []
        for e in range(1, epochs):
            start_prev = ((e - 1) * step) % m
            # Leaving: the ``step`` oldest arrivals of the previous window;
            # entering: the ``step`` arrivals past its end (circular).
            leave = (start_prev + np.arange(step)) % m
            enter = (start_prev + window + np.arange(step)) % m
            batches.append(
                DeltaBatch(
                    epoch=e,
                    add_src=src[enter],
                    add_dst=dst[enter],
                    del_src=src[leave],
                    del_dst=dst[leave],
                    add_w=w[enter] if w is not None else None,
                )
            )
        init = np.arange(window)
        return UpdateStream(
            num_vertices=base.num_vertices,
            init_src=src[init],
            init_dst=dst[init],
            init_w=w[init] if w is not None else None,
            batches=tuple(batches),
        )


@dataclasses.dataclass(frozen=True)
class PreferentialGrowth:
    """Rich-get-richer densification: insert-only preferential attachment."""

    growth_frac: float = 0.05  # new edges per epoch, as a fraction of base m
    kind: ClassVar[str] = "preferential_growth"

    def __post_init__(self):
        if self.growth_frac <= 0:
            raise ValueError("growth_frac must be > 0")

    def generate(self, base: CSRGraph, epochs: int, seed: int) -> UpdateStream:
        rng = np.random.default_rng(seed)
        n, m = base.num_vertices, base.num_edges
        deg = base.degrees.astype(np.float64) + 1.0
        k = max(int(round(self.growth_frac * max(m, 1))), 1)
        empty = np.zeros(0, np.int64)
        batches = []
        for e in range(1, epochs):
            p = deg / deg.sum()
            add_src = rng.choice(n, size=k, p=p).astype(np.int64)
            add_dst = rng.choice(n, size=k, p=p).astype(np.int64)
            keep = add_src != add_dst  # self loops would be dropped anyway
            add_src, add_dst = add_src[keep], add_dst[keep]
            np.add.at(deg, add_src, 1.0)
            np.add.at(deg, add_dst, 1.0)
            add_w = None
            if base.weights is not None:
                add_w = rng.integers(1, 16, size=len(add_src)).astype(np.float32)
            batches.append(
                DeltaBatch(
                    epoch=e,
                    add_src=add_src,
                    add_dst=add_dst,
                    del_src=empty,
                    del_dst=empty,
                    add_w=add_w,
                )
            )
        return UpdateStream(
            num_vertices=n,
            init_src=base.edge_sources().astype(np.int64),
            init_dst=base.neighbors.astype(np.int64),
            init_w=base.weights,
            batches=tuple(batches),
        )


CHURN_MODELS = {
    UniformChurn.kind: UniformChurn,
    CommunityChurn.kind: CommunityChurn,
    SlidingWindow.kind: SlidingWindow,
    PreferentialGrowth.kind: PreferentialGrowth,
}


__all__ = [
    "CHURN_MODELS",
    "CommunityChurn",
    "DeltaBatch",
    "PreferentialGrowth",
    "SlidingWindow",
    "UniformChurn",
    "UpdateStream",
]
