"""Cross-epoch AMC correlation-table lifecycle.

The paper carries AMC's metadata across the §VI two-run boundary implicitly
(the second run replays what the first recorded).  Over a long update
stream the policy governing that carry decides accuracy and coverage as
the graph drifts.  :class:`TableLifecycle` owns one
:class:`~repro.core.amc.storage.AMCStorage` across an epoch sequence and
applies one of four boundary policies between epochs:

``persist``
    The paper behavior generalized: ``swap()`` at every boundary — epoch
    ``e`` prefetches from what epoch ``e-1`` recorded, stale entries and
    all.  Coverage degrades gracefully with cumulative churn.
``reset``
    Cold tables each epoch (``AMC.end()`` + ``AMC.init()`` per version):
    the no-cross-epoch-memory baseline.  AMC records but never replays, so
    per-epoch metrics equal an independent cold run of that epoch
    (property-tested).
``age``
    ``swap_retaining(max_age)``: iterations not re-recorded keep their old
    table as an aged fallback for up to ``max_age`` epochs — trades
    staleness risk for coverage on epochs that run fewer iterations.
``invalidate_changed``
    ``swap()`` then drop entries whose trigger vertex was touched by the
    inbound update batch — their recorded miss streams describe a
    neighborhood that no longer exists.  Trades coverage for accuracy
    under churn.

Boundary work is timed under the ``table_carry`` stage (visible in
``benchmarks/bench.py`` schema v3), and every boundary emits an
:class:`EpochTableReport` with per-epoch lookup hit/miss/staleness counter
deltas — the drift observability the scenario engine is for.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.amc.api import AMCSession
from repro.core.amc.storage import AMCStorage
from repro.core.exec.timers import stage

LIFECYCLE_POLICIES = ("persist", "reset", "age", "invalidate_changed")


@dataclasses.dataclass(frozen=True)
class EpochTableReport:
    """Table accounting for one scored epoch + its outbound boundary."""

    epoch: int
    policy: str
    lookup_hits: int  # iteration lookups that found a table this epoch
    lookup_misses: int
    stale_hits: int  # hits on tables older than one epoch
    invalidated_entries: int  # dropped at the boundary (invalidate_changed)
    aged_out_tables: int  # dropped at the boundary (age cap)
    carried_tables: int  # prefetch-space tables entering the next epoch
    carried_entries: int
    graph_version: int

    def row(self) -> dict:
        return dataclasses.asdict(self)


class TableLifecycle:
    """Carries one AMC storage across an epoch sequence under a policy."""

    def __init__(
        self,
        policy: str,
        capacity_bytes: int,
        max_age: int = 2,
        session: Optional[AMCSession] = None,
    ):
        if policy not in LIFECYCLE_POLICIES:
            raise ValueError(
                f"unknown lifecycle policy {policy!r}; "
                f"available: {list(LIFECYCLE_POLICIES)}"
            )
        self.policy = policy
        self.capacity_bytes = int(capacity_bytes)
        self.max_age = int(max_age)
        self.session = session
        self.storage = AMCStorage(self.capacity_bytes)
        self.reports = []
        self._snap = self._counters()

    def _counters(self) -> dict:
        s = self.storage
        return dict(
            lookup_hits=s.lookup_hits,
            lookup_misses=s.lookup_misses,
            stale_hits=s.stale_hits,
            invalidated_entries=s.invalidated_entries,
            aged_out_tables=s.aged_out_tables,
        )

    def begin_epoch(self, epoch: int) -> AMCStorage:
        """Snapshot counters; returns the storage to score this epoch with."""
        self._snap = self._counters()
        return self.storage

    def end_epoch(
        self, epoch: int, changed_vids: Optional[np.ndarray] = None
    ) -> EpochTableReport:
        """Apply the boundary policy after scoring epoch ``epoch``.

        ``changed_vids`` is the invalidation set of the *inbound* batch of
        epoch ``epoch + 1`` (``SnapshotSequence.changed_vertices``); only
        the ``invalidate_changed`` policy consumes it.
        """
        before, after = self._snap, self._counters()
        with stage("table_carry"):
            if self.policy == "reset":
                # AMC.end()/AMC.init() per graph version: drop everything.
                self.storage = AMCStorage(self.capacity_bytes)
            elif self.policy == "age":
                self.storage.swap_retaining(self.max_age)
            else:  # persist | invalidate_changed: the paper's role swap
                self.storage.swap()
                if self.policy == "invalidate_changed" and changed_vids is not None:
                    self.storage.invalidate_triggers(changed_vids)
            if self.session is not None:
                self.session.new_graph_version()
        boundary = self._counters()
        report = EpochTableReport(
            epoch=epoch,
            policy=self.policy,
            lookup_hits=after["lookup_hits"] - before["lookup_hits"],
            lookup_misses=after["lookup_misses"] - before["lookup_misses"],
            stale_hits=after["stale_hits"] - before["stale_hits"],
            invalidated_entries=boundary["invalidated_entries"]
            - after["invalidated_entries"],
            aged_out_tables=boundary["aged_out_tables"] - after["aged_out_tables"],
            carried_tables=len(self.storage.prefetching),
            carried_entries=int(
                sum(t.num_entries for t in self.storage.prefetching.values())
            ),
            graph_version=(
                self.session.graph_version if self.session is not None else epoch + 1
            ),
        )
        self.reports.append(report)
        return report


__all__ = ["EpochTableReport", "LIFECYCLE_POLICIES", "TableLifecycle"]
