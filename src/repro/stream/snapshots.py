"""Delta-CSR snapshots: apply update batches, yielding an epoch sequence.

``snapshot_sequence`` turns (base graph, churn model, epochs, seed) into the
epoch graphs ``g_0, g_1, …, g_{E-1}`` plus per-epoch churn statistics —
the multi-epoch generalization of :class:`repro.graphs.evolve.
EvolvingGraphPair`.  Two construction paths, both fully vectorized:

- **Vertex-churn models** (those publishing presence masks) build each
  epoch with ``induced_subgraph`` on the *base* graph — the exact legacy
  §VI construction, so the E=2 uniform-churn sequence is bit-identical to
  ``make_evolving_pair`` (masks and CSR arrays).  The equivalent delta
  batches are still derived and, because every CSR here is canonically
  (src, dst)-sorted, :func:`apply_delta` reproduces the same arrays — a
  property the tests assert.
- **Edge-stream models** (sliding window, preferential growth) start from
  the stream's epoch-0 edge set and fold each :class:`DeltaBatch` in with
  :func:`apply_delta` (key-based vectorized delete + concatenated insert).

Vertex ids are never compacted: all epochs share the base id space, so the
property/frontier address layout — and therefore AMC's recorded
correlations — stay commensurable across the whole stream.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.graphs.csr import CSRGraph, from_edges, induced_subgraph
from repro.stream.updates import DeltaBatch, UpdateStream


def apply_delta(graph: CSRGraph, batch: DeltaBatch, name: str) -> CSRGraph:
    """Apply one update batch to ``graph``, returning the next snapshot.

    Deletes are matched by (src, dst) key with ``np.isin``; inserts are
    concatenated and the result re-canonicalized through ``from_edges``
    (sorted by (src, dst), deduped) — so the output is independent of how
    its edge set was reached, and delta application composes with the
    induced-subgraph construction bit for bit.
    """
    n = graph.num_vertices
    src = graph.edge_sources().astype(np.int64)
    dst = graph.neighbors.astype(np.int64)
    w = graph.weights
    if batch.num_deletes:
        key = src * n + dst
        del_key = batch.del_src * n + batch.del_dst
        keep = ~np.isin(key, del_key)
        src, dst = src[keep], dst[keep]
        if w is not None:
            w = w[keep]
    new_src = np.concatenate([src, batch.add_src])
    new_dst = np.concatenate([dst, batch.add_dst])
    new_w = None
    if w is not None:
        add_w = batch.add_w
        if add_w is None:
            add_w = np.ones(batch.num_inserts, dtype=np.float32)
        new_w = np.concatenate([w, add_w])
    return from_edges(new_src, new_dst, n, weights=new_w, dedup=True, name=name)


@dataclasses.dataclass(frozen=True)
class EpochStats:
    """Churn accounting for one epoch of a snapshot sequence."""

    epoch: int
    active_vertices: int
    num_edges: int
    edges_added: int  # via the batch producing this epoch (0 for epoch 0)
    edges_deleted: int
    vertex_overlap: float  # |active_e ∩ active_{e-1}| / |active_{e-1}|
    cumulative_overlap: float  # |active_e ∩ active_0| / |active_0|
    edge_churn: float  # (added + deleted) / max(previous epoch edges, 1)

    def row(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SnapshotSequence:
    """E epoch graphs in a shared id space + the deltas between them."""

    base: CSRGraph
    seed: int
    graphs: List[CSRGraph]
    masks: List[np.ndarray]  # per-epoch active-vertex masks
    batches: List[DeltaBatch]  # len E-1; batches[e-1] produces graphs[e]
    stats: List[EpochStats]
    churn: object = None  # the generating churn model, when known

    @property
    def num_epochs(self) -> int:
        return len(self.graphs)

    @property
    def max_edges(self) -> int:
        """Edge-array size of the shared cross-epoch address layout."""
        return max(g.num_edges for g in self.graphs)

    def changed_vertices(self, epoch: int) -> np.ndarray:
        """Sorted unique vertex ids whose neighborhood or presence changed
        across the boundary into ``epoch`` (1 <= epoch < num_epochs).

        This is the invalidation set of the ``invalidate_changed`` table
        lifecycle policy: correlation entries triggered by these vertices
        were recorded against a neighborhood that no longer exists.
        """
        if not (1 <= epoch < self.num_epochs):
            raise IndexError(f"epoch {epoch} has no inbound boundary")
        touched = self.batches[epoch - 1].touched_vertices()
        toggled = np.flatnonzero(self.masks[epoch] != self.masks[epoch - 1])
        return np.unique(np.concatenate([touched, toggled.astype(np.int64)]))


def _active_mask(g: CSRGraph) -> np.ndarray:
    """Presence for edge-stream epochs: vertices with at least one edge."""
    mask = g.degrees > 0
    if g.num_edges:
        mask = mask.copy()
        mask[np.unique(g.neighbors)] = True
    return mask


def snapshot_sequence(
    base: CSRGraph,
    churn,
    epochs: int,
    seed: int = 0,
    stream: Optional[UpdateStream] = None,
) -> SnapshotSequence:
    """Materialize the epoch sequence of ``churn`` applied to ``base``.

    ``stream`` overrides the generated update stream (for caller-supplied
    update sequences); otherwise ``churn.generate(base, epochs, seed)``
    produces it.  Wrapped in the ``update_apply`` stage timer — the
    per-epoch graph construction cost shows up in the bench breakdown.
    """
    from repro.core.exec.timers import stage

    if epochs < 1:
        raise ValueError(f"epochs must be >= 1, got {epochs}")
    if stream is None:
        stream = churn.generate(base, epochs, seed)
    if stream.num_epochs != epochs:
        raise ValueError(
            f"update stream has {stream.num_epochs} epochs, expected {epochs}"
        )
    with stage("update_apply"):
        if stream.masks is not None:
            # Vertex churn: the legacy induced-subgraph construction (exact
            # §VI arrays); the delta path is equivalent and test-asserted.
            masks = [np.asarray(m) for m in stream.masks]
            graphs = [
                induced_subgraph(base, m, f"{base.name}@e{k}")
                for k, m in enumerate(masks)
            ]
        else:
            g = from_edges(
                stream.init_src,
                stream.init_dst,
                base.num_vertices,
                weights=stream.init_w,
                dedup=True,
                name=f"{base.name}@e0",
            )
            graphs = [g]
            for k, batch in enumerate(stream.batches, start=1):
                g = apply_delta(g, batch, name=f"{base.name}@e{k}")
                graphs.append(g)
            masks = [_active_mask(g) for g in graphs]

    stats: List[EpochStats] = []
    for k, g in enumerate(graphs):
        active = int(masks[k].sum())
        if k == 0:
            stats.append(
                EpochStats(
                    epoch=0,
                    active_vertices=active,
                    num_edges=g.num_edges,
                    edges_added=0,
                    edges_deleted=0,
                    vertex_overlap=1.0,
                    cumulative_overlap=1.0,
                    edge_churn=0.0,
                )
            )
            continue
        batch = stream.batches[k - 1]
        prev_active = masks[k - 1]
        stats.append(
            EpochStats(
                epoch=k,
                active_vertices=active,
                num_edges=g.num_edges,
                edges_added=batch.num_inserts,
                edges_deleted=batch.num_deletes,
                vertex_overlap=float(
                    (masks[k] & prev_active).sum() / max(prev_active.sum(), 1)
                ),
                cumulative_overlap=float(
                    (masks[k] & masks[0]).sum() / max(masks[0].sum(), 1)
                ),
                edge_churn=float(
                    batch.num_updates / max(graphs[k - 1].num_edges, 1)
                ),
            )
        )
    return SnapshotSequence(
        base=base,
        seed=seed,
        graphs=graphs,
        masks=masks,
        batches=list(stream.batches),
        stats=stats,
        churn=churn,
    )


__all__ = ["EpochStats", "SnapshotSequence", "apply_delta", "snapshot_sequence"]
