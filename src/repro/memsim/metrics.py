"""Prefetcher evaluation metrics (paper Figs 8-13) from simulation outcomes.

The paper's setup is *composite*: the baseline system already runs a
next-line L2 prefetcher, and every evaluated prefetcher runs alongside it
(§VII: "The baseline system uses the next-line prefetcher as the L2 data
prefetcher"). So:

  baseline run  = demand + next-line           (issuer 0)
  evaluated run = demand + next-line + X       (X = issuer 1)

``evaluate`` scores issuer X against the *baseline run*: coverage counts
X-attributed useful prefetches against the baseline run's L2 misses, speedup
compares composite cycles against baseline-run cycles, and traffic compares
total DRAM accesses. ``eval_from_pos`` restricts every count to accesses
at/after that position — the paper evaluates BFS/BellmanFord on the second
(post-change) run only.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.memsim.config import BLOCK_BITS
from repro.memsim.hierarchy import DemandProfile, PrefetchOutcome
from repro.memsim.timing import TimingModel, avg_miss_cost, estimate_cycles


@dataclasses.dataclass
class PrefetchMetrics:
    name: str
    accuracy: float  # useful / issued                     (Fig 10)
    coverage: float  # useful / baseline L2 misses         (Fig 9)
    speedup: float  # baseline cycles / prefetcher cycles  (Fig 8)
    ipc_baseline: float
    ipc_prefetch: float
    issued: int
    useful: int
    late: int
    evicted_early: int
    overpredicted: int  # issued with no future demand (Fig 11 breakdown)
    redundant: int
    baseline_l2_misses: int
    extra_traffic: float  # (PrefDram - DemandDram)/DemandDram   (Fig 12)
    metadata_traffic: float  # metadata DRAM / DemandDram        (Fig 13)
    dram_demand: int
    dram_total: int
    info: dict = dataclasses.field(default_factory=dict)

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _outcome_cycles(
    profile: DemandProfile,
    outcome: PrefetchOutcome,
    t0: int,
    tm: TimingModel,
    dram_baseline: int,
    late_miss_cost: float,
    extra_metadata_dram: int = 0,
):
    """(cycles, counts) of a run described by ``outcome`` within the window."""
    base = profile.baseline_counts(t0)
    demand_miss_sel = ~outcome.demand_l2_hit
    miss_pos = profile.l2_pos[demand_miss_sel]
    in_win = miss_pos >= t0
    l2_misses = int(in_win.sum())
    dram_flags = ~outcome.demand_llc_hit
    dram_demand = int((dram_flags & in_win).sum())
    pf_dram = int(
        (outcome.pf_llc_in_dram & (outcome.pf_llc_in_pos >= t0)).sum()
    )
    late = int((outcome.demand_late & (profile.l2_pos >= t0)).sum())
    dram_total = dram_demand + pf_dram + extra_metadata_dram
    dram_pos = miss_pos[dram_flags]
    cycles = estimate_cycles(
        num_accesses=base["accesses"],
        l1_misses=base["l1_miss"],
        l2_misses_demand=l2_misses,
        dram_demand=dram_demand,
        dram_total=dram_total,
        dram_baseline=dram_baseline,
        late_useful=late,
        l2_miss_pos=miss_pos[in_win],
        dram_pos=dram_pos[dram_pos >= t0],
        cfg=profile.cfg,
        tm=tm,
        late_miss_cost=late_miss_cost,
    )
    counts = dict(
        l2_misses=l2_misses,
        dram_demand=dram_demand,
        pf_dram=pf_dram,
        dram_total=dram_total,
        late=late,
    )
    return cycles, counts


def _raw_late_cost(profile: DemandProfile, t0: int, tm: TimingModel) -> float:
    key = ("latecost", t0, tm)
    cache = getattr(profile, "_timing_cache", None)
    if cache is None:
        cache = profile._timing_cache = {}
    if key not in cache:
        base = profile.baseline_counts(t0)
        mp = profile.l2_miss_pos
        dp = mp[~profile.llc_hit]
        cache[key] = avg_miss_cost(
            l2_misses=base["l2_miss"],
            dram_misses=base["dram"],
            l2_miss_pos=mp[mp >= t0],
            dram_pos=dp[dp >= t0],
            cfg=profile.cfg,
            tm=tm,
        )
    return cache[key]


def evaluate(
    name: str,
    profile: DemandProfile,
    outcome: PrefetchOutcome,
    baseline_outcome: PrefetchOutcome,
    tm: TimingModel = TimingModel(),
    eval_from_pos: int = 0,
    issuer: int = 1,
) -> PrefetchMetrics:
    """Score issuer ``issuer`` within ``outcome`` against ``baseline_outcome``."""
    t0 = eval_from_pos
    base = profile.baseline_counts(t0)
    late_cost = _raw_late_cost(profile, t0, tm)

    # Baseline-run cycles/misses (cached across the prefetchers sharing it).
    key = ("basecycles", t0, tm, id(baseline_outcome))
    cache = getattr(profile, "_timing_cache", None)
    if cache is None:
        cache = profile._timing_cache = {}
    if key not in cache:
        meta_dram_b = baseline_outcome.metadata_bytes >> BLOCK_BITS
        cache[key] = _outcome_cycles(
            profile, baseline_outcome, t0, tm, base["dram"], late_cost, meta_dram_b
        )
    base_cycles, base_counts = cache[key]

    meta_dram = outcome.metadata_bytes >> BLOCK_BITS
    run_cycles, run_counts = _outcome_cycles(
        profile, outcome, t0, tm, base["dram"], late_cost, meta_dram
    )

    # Issuer-attributed prefetch quality.
    sel_l2 = profile.l2_pos >= t0
    sel_pf = (outcome.pf_pos >= t0) & (outcome.pf_issuer == issuer)
    useful_mask = outcome.demand_useful & sel_l2 & (
        outcome.demand_fill_issuer == issuer
    )
    useful = int(useful_mask.sum())
    late = int((outcome.demand_late & useful_mask).sum())
    issued = int(sel_pf.sum())
    redundant = int((outcome.pf_redundant & sel_pf).sum())
    overpred = int((outcome.pf_no_future & sel_pf).sum())
    early = int((outcome.pf_early & sel_pf).sum())

    baseline_misses = base_counts["l2_misses"]
    dram_b = base_counts["dram_total"]
    dram_r = run_counts["dram_total"]
    extra = (dram_r - dram_b) / max(dram_b, 1)
    meta = meta_dram / max(dram_b, 1)
    # Hardware filters L2-resident candidates before issue (a cache probe),
    # so redundant prefetches don't count toward the issue total.
    issued_eff = issued - redundant
    return PrefetchMetrics(
        name=name,
        accuracy=useful / max(issued_eff, 1),
        coverage=useful / max(baseline_misses, 1),
        speedup=base_cycles / max(run_cycles, 1e-9),
        ipc_baseline=base["accesses"] / max(base_cycles, 1e-9),
        ipc_prefetch=base["accesses"] / max(run_cycles, 1e-9),
        issued=issued,
        useful=useful,
        late=late,
        evicted_early=early,
        overpredicted=overpred,
        redundant=redundant,
        baseline_l2_misses=baseline_misses,
        extra_traffic=float(extra),
        metadata_traffic=float(meta),
        dram_demand=run_counts["dram_demand"],
        dram_total=dram_r,
    )


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    xs = np.maximum(xs, 1e-12)
    return float(np.exp(np.log(xs).mean()))


def summarize_epochs(metrics) -> dict:
    """Drift-curve aggregates over an epoch-ordered metric sequence.

    Used by the streaming protocol (``repro.stream.protocol``): per-epoch
    accuracy/coverage/speedup arrays plus the tail means from epoch 2 on
    (0-indexed epoch 1) — epoch 1 is always cold for cross-epoch
    prefetchers, so the tail is where lifecycle policies differentiate.
    """
    ms = list(metrics)
    if not ms:
        raise ValueError("summarize_epochs needs at least one epoch")
    coverage = [float(m.coverage) for m in ms]
    accuracy = [float(m.accuracy) for m in ms]
    speedup = [float(m.speedup) for m in ms]
    tail = slice(1, None) if len(ms) > 1 else slice(None)
    return {
        "coverage": coverage,
        "accuracy": accuracy,
        "speedup": speedup,
        "geomean_speedup": geomean(speedup),
        "mean_coverage": float(np.mean(coverage)),
        "mean_accuracy": float(np.mean(accuracy)),
        "tail_mean_coverage": float(np.mean(coverage[tail])),
        "tail_mean_accuracy": float(np.mean(accuracy[tail])),
        "tail_geomean_speedup": geomean(speedup[tail]),
    }
