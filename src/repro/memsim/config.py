"""Cache-hierarchy configurations (paper Table VI + scaled variant).

The paper simulates: L1D 64KB/8-way 4cyc, L2 256KB/8-way 12cyc (next-line
prefetcher), LLC 8MB/16-way 42cyc, DDR4-2400 1ch (~tRCD+tCL ≈ 34 DRAM cycles
≈ 170+ core cycles with queueing).

``SCALED`` divides capacities by 16 (same associativity/latency) to pair
with the 1/32-scale synthetic graphs so miss ratios land in the paper's
regime; EXPERIMENTS.md §1 reports the calibration.
"""
from __future__ import annotations

import dataclasses

BLOCK_BITS = 6


@dataclasses.dataclass(frozen=True)
class CacheLevelConfig:
    size_bytes: int
    ways: int
    latency: int  # cycles
    mshr: int

    @property
    def lines(self) -> int:
        return self.size_bytes >> BLOCK_BITS

    @property
    def sets(self) -> int:
        s = self.lines // self.ways
        assert s & (s - 1) == 0, f"sets must be a power of two, got {s}"
        return s


@dataclasses.dataclass(frozen=True)
class HierarchyConfig:
    l1: CacheLevelConfig
    l2: CacheLevelConfig
    llc: CacheLevelConfig
    dram_latency: int  # cycles, loaded latency incl. queueing
    # Prefetch in-flight window measured in *accesses*: a prefetch issued at
    # access t is resident only after t + pf_fill_window accesses (used for
    # late-prefetch classification).
    pf_fill_window: int = 40
    name: str = "hierarchy"


PAPER = HierarchyConfig(
    l1=CacheLevelConfig(64 * 1024, 8, 4, 8),
    l2=CacheLevelConfig(256 * 1024, 8, 12, 16),
    llc=CacheLevelConfig(8 * 1024 * 1024, 16, 42, 128),
    dram_latency=170,
    name="table6",
)

# Pairs with the 1/8-scale graphs: L1/L2 scaled 1/8 (keeps >=16 sets so
# conflict behavior stays sane), LLC 1/16 so footprint/LLC lands at the
# paper's ~5-10x ratio (EXPERIMENTS.md §1 records measured ratios).
SCALED = HierarchyConfig(
    l1=CacheLevelConfig(8 * 1024, 8, 4, 8),
    l2=CacheLevelConfig(32 * 1024, 8, 12, 16),
    llc=CacheLevelConfig(256 * 1024, 16, 42, 128),
    dram_latency=170,
    pf_fill_window=30,
    name="table6-scaled",
)
