"""Set-parallel cache simulation engine.

Two exact equivalences let the serial one-access-per-step simulator become
a batch of short, concurrent per-set simulations:

1. **Set independence.** A set-associative cache partitions blocks by set
   index ``b & (sets - 1)`` and replacement state never crosses sets, so a
   stable group-by sort of the access stream by set yields ``sets``
   independent substreams whose hit masks compose (scatter back through
   the sort order) into the full-stream hit mask.
2. **Stack distance ≡ LRU.** Within one set under true LRU, an access hits
   iff its stack distance — the number of *distinct* blocks touched in the
   set since that block's previous access — is ``< ways`` (first touches
   are cold misses).  Hits are a property of each substream alone, so the
   per-set machines need no coordination: the ``(max_len, sets)`` padded
   matrix of substreams is advanced one access per step for *every* set at
   once, and the sequential dependence chain drops from N steps to
   ``max_len`` (~N/sets) steps of fully vectorized work.

Engines (pick with ``REPRO_CACHE_ENGINE``, :func:`set_engine`, or the
:func:`use_engine` context manager):

- ``set_parallel`` (default): the padded batched ``lax.scan`` described
  above.  Hit masks are bit-identical to the reference — the per-set age
  counters preserve the reference's relative LRU order and tie-breaking
  (``argmin``/``argmax`` pick the lowest way index in both) — so
  ``TRACE_CODE_VERSION`` and every persisted workload artifact stay valid.
- ``reference``: the original serial ``lax.scan``
  (:mod:`repro.memsim.scan_cache`), kept as the correctness oracle the
  property tests and the bench parity gate compare against.
- ``pallas``: the same set-parallel machine as a Pallas TPU kernel
  (:mod:`repro.kernels.cache_sim`), sets tiled across the grid with the
  tag/age carry in VMEM scratch.  Gated on backend: off-TPU it runs in
  interpret mode, which validates semantics but is not fast.
"""
from __future__ import annotations

import contextlib
import os
from functools import lru_cache
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsim import scan_cache

ENGINES = ("set_parallel", "reference", "pallas")
ENGINE_ENV = "REPRO_CACHE_ENGINE"
DEFAULT_ENGINE = "set_parallel"

_override: Optional[str] = None


def _check(name: str) -> str:
    if name not in ENGINES:
        raise ValueError(f"unknown cache engine {name!r}; choose from {ENGINES}")
    return name


def current_engine() -> str:
    """The active engine: ``set_engine`` override > env var > default."""
    if _override is not None:
        return _override
    return _check(os.environ.get(ENGINE_ENV, DEFAULT_ENGINE))


def set_engine(name: Optional[str]) -> None:
    """Select the cache engine process-wide (``None`` restores env/default)."""
    global _override
    _override = _check(name) if name is not None else None


@contextlib.contextmanager
def use_engine(name: str) -> Iterator[None]:
    """Run the enclosed block under a specific cache engine."""
    global _override
    prev, _override = _override, _check(name)
    try:
        yield
    finally:
        _override = prev


def group_by_set(
    blocks: np.ndarray, sets: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Partition a stream into padded per-set substream columns.

    Returns ``(padded, order, col, row)``: ``padded`` is ``(max_len, sets)``
    int32 with each set's substream (in stream order) occupying a column
    prefix, tail-padded with ``-1``; ``order`` is the stable group-by sort
    permutation, and ``padded[col, row]`` are the real accesses in sorted
    order — scatter per-cell results back with ``out[order] = res[col, row]``.

    Tail padding is harmless by construction: a pad cell can only perturb a
    set's tag/age state *after* that set's last real access, so no real hit
    bit depends on it (pad cells' outputs are simply never gathered).
    """
    blocks = np.asarray(blocks)
    # Guard here so every engine entry point (set-parallel, Pallas ops)
    # inherits it: an id >= 2**31 would wrap negative in int32, alias the
    # -1 empty-way/pad sentinel, and silently corrupt the hit mask.
    assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
    b32 = blocks.astype(np.int32)
    s = b32 & np.int32(sets - 1)
    order = np.argsort(s, kind="stable")
    counts = np.bincount(s, minlength=sets)
    max_len = _bucket_len(int(counts.max()))
    starts = np.zeros(sets, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    col = np.arange(len(b32), dtype=np.int64) - np.repeat(starts, counts)
    row = s[order].astype(np.int64)
    padded = np.full((max_len, sets), -1, dtype=np.int32)
    padded[col, row] = b32[order]
    return padded, order, col, row


def _bucket_len(n: int) -> int:
    """Round the padded substream length up to a power of two (min 128).

    The batched pass is jitted per ``(sets, ways, max_len)`` shape; pow2
    bucketing caps compile count at O(log N) per geometry instead of one
    compile per distinct trace length.
    """
    return max(128, 1 << (n - 1).bit_length())


@lru_cache(maxsize=32)
def _batched_pass(sets: int, ways: int):
    """Jitted batched scan: every step advances all ``sets`` machines."""

    def step(carry, b):
        tags, age, t = carry  # (sets, ways), (sets, ways), scalar
        hitv = tags == b[:, None]
        hit = hitv.any(axis=1)
        way = jnp.where(hit, jnp.argmax(hitv, axis=1), jnp.argmin(age, axis=1))
        onehot = way[:, None] == jnp.arange(tags.shape[1])[None, :]
        tags = jnp.where(onehot, b[:, None], tags)
        age = jnp.where(onehot, t, age)
        return (tags, age, t + 1), hit

    @jax.jit
    def run(padded):  # (max_len, sets) -> (max_len, sets) hits
        init = (
            jnp.full((sets, ways), -1, dtype=jnp.int32),
            jnp.zeros((sets, ways), dtype=jnp.int32),
            jnp.int32(1),
        )
        _, hits = jax.lax.scan(step, init, padded, unroll=4)
        return hits

    return run


# Skew guard: the padded matrix costs max_len x sets cells.  Balanced
# streams stay within ~2x of N (pow2 bucketing), so beyond PAD_FACTOR x N
# cells (with an absolute floor so tiny streams never trip it) the stream
# is set-skewed enough that the serial reference's O(N) machine wins —
# and a fully-degenerate stream (every access in one set at a large-sets
# geometry) would otherwise demand a max_len x sets allocation far larger
# than the stream itself.
_PAD_FACTOR = 4
_PAD_FLOOR_CELLS = 1 << 22


def cache_pass_set_parallel(blocks: np.ndarray, sets: int, ways: int) -> np.ndarray:
    counts = np.bincount(
        np.asarray(blocks, dtype=np.int64) & (sets - 1), minlength=sets
    )
    cells = _bucket_len(int(counts.max(initial=0))) * sets
    if cells > max(_PAD_FACTOR * len(blocks), _PAD_FLOOR_CELLS):
        return scan_cache.cache_pass(blocks, sets, ways)  # bit-identical
    padded, order, col, row = group_by_set(blocks, sets)
    hits = np.asarray(_batched_pass(sets, ways)(jnp.asarray(padded)))
    out = np.zeros(len(blocks), dtype=bool)
    out[order] = hits[col, row]
    return out


def cache_pass(blocks: np.ndarray, sets: int, ways: int) -> np.ndarray:
    """Run an access stream through one cache level; returns the hit mask.

    Dispatches to the active engine (see module docstring); every engine
    honors the same contract and produces bit-identical masks.
    """
    if len(blocks) == 0:
        return np.zeros(0, dtype=bool)
    assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
    engine = current_engine()
    if engine == "reference":
        return scan_cache.cache_pass(blocks, sets, ways)
    if engine == "pallas":
        from repro.kernels.cache_sim.ops import cache_pass_pallas

        return cache_pass_pallas(blocks, sets, ways)
    return cache_pass_set_parallel(blocks, sets, ways)


__all__ = [
    "ENGINES",
    "ENGINE_ENV",
    "cache_pass",
    "cache_pass_set_parallel",
    "current_engine",
    "group_by_set",
    "set_engine",
    "use_engine",
]
