"""Set-parallel cache simulation engine.

Two exact equivalences let the serial one-access-per-step simulator become
a batch of short, concurrent per-set simulations:

1. **Set independence.** A set-associative cache partitions blocks by set
   index ``b & (sets - 1)`` and replacement state never crosses sets, so a
   stable group-by sort of the access stream by set yields ``sets``
   independent substreams whose hit masks compose (scatter back through
   the sort order) into the full-stream hit mask.
2. **Stack distance ≡ LRU.** Within one set under true LRU, an access hits
   iff its stack distance — the number of *distinct* blocks touched in the
   set since that block's previous access — is ``< ways`` (first touches
   are cold misses).  Hits are a property of each substream alone, so the
   per-set machines need no coordination: the ``(max_len, sets)`` padded
   matrix of substreams is advanced one access per step for *every* set at
   once, and the sequential dependence chain drops from N steps to
   ``max_len`` (~N/sets) steps of fully vectorized work.

Engines (pick with ``REPRO_CACHE_ENGINE``, :func:`set_engine`, or the
:func:`use_engine` context manager):

- ``fused``: the set-parallel machine with *all* hierarchy levels carried
  in one scan (:mod:`repro.memsim.fused`) — ``simulate_demand`` runs
  L1→L2→LLC as a single launch emitting per-access hit levels when the
  cost-based plan chooser finds run collapse shrank the padded bucket
  (otherwise the bit-identical per-level cascade), and the *batched*
  scoring entry points (``simulate_with_prefetch_batch``,
  ``cache_pass_batch``) collapse a prefetcher family's per-stream level
  passes into one vmapped launch per level with a fused victim select.
  Single-stream scoring and single-level ``cache_pass`` calls have
  nothing to batch and run the set-parallel cascade.
- ``set_parallel``: the padded batched ``lax.scan`` described above.  Hit
  masks are bit-identical to the reference — the per-set age counters
  preserve the reference's relative LRU order and tie-breaking
  (``argmin``/``argmax`` pick the lowest way index in both) — so
  ``TRACE_CODE_VERSION`` and every persisted workload artifact stay valid.
- ``reference``: the original serial ``lax.scan``
  (:mod:`repro.memsim.scan_cache`), kept as the correctness oracle the
  property tests and the bench parity gate compare against — including
  across shard seams (see *carried state* below).
- ``pallas``: the same set-parallel machine as a Pallas TPU kernel
  (:mod:`repro.kernels.cache_sim`), sets tiled across the grid with the
  tag/age carry in VMEM scratch.  Off-TPU it runs in interpret mode,
  which validates semantics but is not fast.

The default engine is resolved per backend: ``pallas`` on TPU (the kernel
is the native scoring path on accelerator), ``set_parallel`` everywhere
else.  ``REPRO_CACHE_ENGINE`` overrides the resolution either way.

**Carried state.**  Sharded traces stream through the simulator one chunk
at a time, so every engine can resume a pass exactly where the previous
chunk left off: ``cache_pass(..., state=..., return_state=True)`` threads a
:class:`CacheState` in and out.  The returned state is *canonical* — per
set, ways are re-aged to ``-ways..-1`` with empty ways first (in way-index
order) and filled ways in LRU→MRU order — which makes it engine-independent
(every engine emits the same canonical state for the same stream prefix)
and makes resuming bit-identical to an uninterrupted pass: carried lines
are strictly older than any new access (new passes count age from 1), and
``argmin`` tie-breaking still prefers the lowest-index empty way.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from functools import lru_cache
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsim import scan_cache

ENGINES = ("fused", "set_parallel", "reference", "pallas")
ENGINE_ENV = "REPRO_CACHE_ENGINE"
# CPU/GPU default; see default_engine() for the backend-aware resolution.
DEFAULT_ENGINE = "fused"

_override: Optional[str] = None


def _check(name: str) -> str:
    if name not in ENGINES:
        raise ValueError(f"unknown cache engine {name!r}; choose from {ENGINES}")
    return name


@lru_cache(maxsize=1)
def default_engine() -> str:
    """Backend-resolved default: the Pallas kernel on TPU, the fused
    hierarchy engine elsewhere (where the Pallas kernel would run in slow
    interpret mode)."""
    try:
        backend = jax.default_backend()
    except Exception:  # backend discovery failed -> portable default
        backend = "cpu"
    return "pallas" if backend == "tpu" else DEFAULT_ENGINE


def current_engine() -> str:
    """The active engine: ``set_engine`` override > env var > default."""
    if _override is not None:
        return _override
    env = os.environ.get(ENGINE_ENV)
    return _check(env) if env is not None else default_engine()


def set_engine(name: Optional[str]) -> None:
    """Select the cache engine process-wide (``None`` restores env/default)."""
    global _override
    _override = _check(name) if name is not None else None


@contextlib.contextmanager
def use_engine(name: str) -> Iterator[None]:
    """Run the enclosed block under a specific cache engine."""
    global _override
    prev, _override = _override, _check(name)
    try:
        yield
    finally:
        _override = prev


@dataclasses.dataclass
class CacheState:
    """Canonical tag/LRU carry of one cache level between chunked passes.

    ``tags`` is ``(sets, ways)`` int32 (-1 = empty way); ``age`` is
    ``(sets, ways)`` int32 in the canonical form produced by
    :func:`canonicalize_state`.  Engine-independent: resuming any engine
    from this state is bit-identical to an uninterrupted pass.
    """

    tags: np.ndarray
    age: np.ndarray

    @property
    def sets(self) -> int:
        return self.tags.shape[0]

    @property
    def ways(self) -> int:
        return self.tags.shape[1]


def init_state(sets: int, ways: int) -> CacheState:
    """Canonical all-empty state (what a cold pass starts from)."""
    tags = np.full((sets, ways), -1, dtype=np.int32)
    age = np.tile(np.arange(-ways, 0, dtype=np.int32), (sets, 1))
    return CacheState(tags, age)


def canonicalize_state(tags: np.ndarray, age: np.ndarray) -> CacheState:
    """Re-age raw engine tag/age arrays into the canonical carry form.

    Per set, ways are ranked empties-first (in way-index order, preserving
    the ``argmin`` tie-break of a fresh pass) then filled ways by ascending
    raw age (LRU -> MRU), and assigned ages ``rank - ways`` — all negative,
    so a resumed pass (ages counted from 1) always sees carried lines as
    older than anything it inserts.  Only the per-set *order* of the raw
    ages matters, which is why engines with different age-counter schedules
    (serial stream counter vs padded step counter) canonicalize to the
    same state.
    """
    tags = np.asarray(tags, dtype=np.int32)
    ways = tags.shape[1]
    key = np.where(
        tags == -1, np.iinfo(np.int64).min, np.asarray(age, dtype=np.int64)
    )
    order = np.argsort(key, axis=1, kind="stable")
    rank = np.empty_like(order)
    np.put_along_axis(rank, order, np.arange(ways, dtype=order.dtype)[None, :], axis=1)
    return CacheState(tags.copy(), (rank - ways).astype(np.int32))


def group_by_set(
    blocks: np.ndarray, sets: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Partition a stream into padded per-set substream columns.

    Returns ``(padded, order, col, row)``: ``padded`` is ``(max_len, sets)``
    int32 with each set's substream (in stream order) occupying a column
    prefix, tail-padded with ``-1``; ``order`` is the stable group-by sort
    permutation, and ``padded[col, row]`` are the real accesses in sorted
    order — scatter per-cell results back with ``out[order] = res[col, row]``.

    Tail padding is harmless by construction: pad cells are masked out of
    the tag/age update (``b >= 0`` guard), so they neither perturb a set's
    state nor the carried state returned to the caller, and their hit bits
    are never gathered.
    """
    blocks = np.asarray(blocks)
    # Guard here so every engine entry point (set-parallel, Pallas ops)
    # inherits it: an id >= 2**31 would wrap negative in int32, alias the
    # -1 empty-way/pad sentinel, and silently corrupt the hit mask.
    assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
    assert sets <= 1 << 16, "set index must fit the uint16 radix-sort key"
    b32 = blocks.astype(np.int32)
    s = b32 & np.int32(sets - 1)
    # uint16 sort key routes numpy's stable argsort to its O(N) radix
    # path (stable sorts of >16-bit ints fall back to timsort) — same
    # permutation, ~4x faster on paper-scale streams.
    order = np.argsort(s.astype(np.uint16), kind="stable")
    counts = np.bincount(s, minlength=sets)
    max_len = _bucket_len(int(counts.max()))
    starts = np.zeros(sets, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    col = np.arange(len(b32), dtype=np.int64) - np.repeat(starts, counts)
    row = s[order].astype(np.int64)
    padded = np.full((max_len, sets), -1, dtype=np.int32)
    padded[col, row] = b32[order]
    return padded, order, col, row


def _bucket_len(n: int) -> int:
    """Round the padded substream length up to a power of two (min 128).

    The batched pass is jitted per ``(sets, ways, max_len)`` shape; pow2
    bucketing caps compile count at O(log N) per geometry instead of one
    compile per distinct trace length.
    """
    return max(128, 1 << (n - 1).bit_length())


@lru_cache(maxsize=32)
def _batched_pass(sets: int, ways: int):
    """Jitted batched scan: every step advances all ``sets`` machines.

    Takes the carried tag/age arrays as traced inputs and returns the
    final state alongside the hit matrix; pad steps (``b == -1``) emit a
    (never-gathered) bit but are masked out of the state update.
    """

    def step(carry, b):
        tags, age, t = carry  # (sets, ways), (sets, ways), scalar
        hitv = tags == b[:, None]
        hit = hitv.any(axis=1)
        way = jnp.where(hit, jnp.argmax(hitv, axis=1), jnp.argmin(age, axis=1))
        onehot = (way[:, None] == jnp.arange(tags.shape[1])[None, :]) & (
            b >= 0
        )[:, None]
        tags = jnp.where(onehot, b[:, None], tags)
        age = jnp.where(onehot, t, age)
        return (tags, age, t + 1), hit

    @jax.jit
    def run(padded, tags0, age0):  # (max_len, sets) -> hits + final state
        init = (tags0, age0, jnp.int32(1))
        (tags1, age1, _), hits = jax.lax.scan(step, init, padded, unroll=4)
        return hits, tags1, age1

    return run


# Skew guard: the padded matrix costs max_len x sets cells.  Balanced
# streams stay within ~2x of N (pow2 bucketing), so beyond PAD_FACTOR x N
# cells (with an absolute floor so tiny streams never trip it) the stream
# is set-skewed enough that the serial reference's O(N) machine wins —
# and a fully-degenerate stream (every access in one set at a large-sets
# geometry) would otherwise demand a max_len x sets allocation far larger
# than the stream itself.
_PAD_FACTOR = 4
_PAD_FLOOR_CELLS = 1 << 22


def cache_pass_set_parallel(
    blocks: np.ndarray,
    sets: int,
    ways: int,
    state: Optional[CacheState] = None,
    return_state: bool = False,
):
    counts = np.bincount(
        np.asarray(blocks, dtype=np.int64) & (sets - 1), minlength=sets
    )
    cells = _bucket_len(int(counts.max(initial=0))) * sets
    if cells > max(_PAD_FACTOR * len(blocks), _PAD_FLOOR_CELLS):
        # bit-identical fallback (canonical states compose across engines)
        return scan_cache.cache_pass(blocks, sets, ways, state, return_state)
    padded, order, col, row = group_by_set(blocks, sets)
    st = state if state is not None else init_state(sets, ways)
    hits, tags1, age1 = _batched_pass(sets, ways)(
        jnp.asarray(padded), jnp.asarray(st.tags), jnp.asarray(st.age)
    )
    hits = np.asarray(hits)
    out = np.zeros(len(blocks), dtype=bool)
    out[order] = hits[col, row]
    if not return_state:
        return out
    return out, canonicalize_state(np.asarray(tags1), np.asarray(age1))


def _fused_select_pass(sets: int, ways: int):
    """Set-parallel scan with a *fused victim select* — the fused
    engine's pass machine (batched scoring and the cascade plan).

    :func:`_batched_pass` picks the touched way with three vector ops
    (``argmax`` over the hit lanes, ``argmin`` over ages, a ``where``
    select).  Here they collapse into one reduction::

        way = argmin(where(hitv, INT32_MIN, age))

    Bit-identical by construction: tags are unique within a set, so
    ``hitv`` has at most one lane set — on a hit that lane's ``INT32_MIN``
    beats every age (ages are ``>= -ways``), on a miss the expression *is*
    ``argmin(age)``, and ages are pairwise distinct per set so both forms
    share the same unique minimum (no tie-break to preserve).  One
    reduction instead of two plus a select cuts the per-step cost ~2x at
    L2 geometry and ~3x at LLC geometry on CPU.  The per-level
    ``set_parallel`` path keeps the original formulation: it is this PR's
    frozen comparator for the fused-vs-per-level bench cell.
    """

    def step(carry, b):
        tags, age, t = carry
        hitv = tags == b[:, None]
        hit = hitv.any(axis=1)
        way = jnp.argmin(
            jnp.where(hitv, jnp.iinfo(jnp.int32).min, age), axis=1
        )
        onehot = (way[:, None] == jnp.arange(tags.shape[1])[None, :]) & (
            b >= 0
        )[:, None]
        tags = jnp.where(onehot, b[:, None], tags)
        age = jnp.where(onehot, t, age)
        return (tags, age, t + 1), hit

    def run(padded, tags0, age0):
        init = (tags0, age0, jnp.int32(1))
        (tags1, age1, _), hits = jax.lax.scan(step, init, padded, unroll=4)
        return hits, tags1, age1

    return run


@lru_cache(maxsize=32)
def _fused_select_vmapped(sets: int, ways: int):
    """:func:`_fused_select_pass` vmapped over a leading stream axis — one
    launch advances a whole family of same-geometry streams."""
    return jax.jit(jax.vmap(_fused_select_pass(sets, ways)))


@lru_cache(maxsize=32)
def _fused_select_single(sets: int, ways: int):
    """:func:`_fused_select_pass` jitted for one stream — the fused
    engine's per-level machine when its plan chooser picks the cascade."""
    return jax.jit(_fused_select_pass(sets, ways))


def cache_pass_fused_select(
    blocks: np.ndarray,
    sets: int,
    ways: int,
    state: Optional[CacheState] = None,
    return_state: bool = False,
):
    """One-level pass on the fused-select machine (fused engine only).

    Same contract and bit-identical output as
    :func:`cache_pass_set_parallel` (see :func:`_fused_select_pass` for
    the identity argument); kept separate so the ``set_parallel`` engine
    — this PR's frozen A/B comparator — is never touched by fused-path
    optimizations.  Skewed streams fall back to the serial reference.
    """
    if _pad_skewed(blocks, sets):
        return scan_cache.cache_pass(blocks, sets, ways, state, return_state)
    padded, order, col, row = group_by_set(blocks, sets)
    st = state if state is not None else init_state(sets, ways)
    hits, tags1, age1 = _fused_select_single(sets, ways)(
        jnp.asarray(padded), jnp.asarray(st.tags), jnp.asarray(st.age)
    )
    hits = np.asarray(hits)
    out = np.zeros(len(blocks), dtype=bool)
    out[order] = hits[col, row]
    if not return_state:
        return out
    return out, canonicalize_state(np.asarray(tags1), np.asarray(age1))


def _pad_skewed(blocks: np.ndarray, sets: int) -> bool:
    counts = np.bincount(
        np.asarray(blocks, dtype=np.int64) & (sets - 1), minlength=sets
    )
    cells = _bucket_len(int(counts.max(initial=0))) * sets
    return cells > max(_PAD_FACTOR * len(blocks), _PAD_FLOOR_CELLS)


def cache_pass_batch(streams, sets: int, ways: int):
    """One cold-state pass per stream through one level, vmapped over the
    family.

    ``streams`` may differ in length; each is grouped by set
    independently, then streams whose padded substreams land in the same
    pow2 bucket share one vmapped :func:`_fused_select_pass` launch —
    batching never pads a short stream to a longer member's bucket, so the
    batched scan does exactly the work of the per-stream loop, minus the
    per-stream dispatches.  Returns one hit mask per stream, bit-identical
    to looping :func:`cache_pass` — which is also the fallback for empty
    or set-skewed members.  This is the scoring path's batching primitive:
    the per-prefetcher level passes of one workload family collapse into
    one dispatch per level per bucket instead of one per stream.
    """
    n = len(streams)
    if n == 0:
        return []
    if n == 1 or any(len(s) == 0 for s in streams) or any(
        _pad_skewed(s, sets) for s in streams
    ):
        return [cache_pass(s, sets, ways) for s in streams]
    grouped = [group_by_set(s, sets) for s in streams]
    st = init_state(sets, ways)
    by_bucket: dict = {}
    for i, g in enumerate(grouped):
        by_bucket.setdefault(g[0].shape[0], []).append(i)
    outs: list = [None] * n
    for idxs in by_bucket.values():
        k = len(idxs)
        padded = np.stack([grouped[i][0] for i in idxs])
        tags0 = jnp.asarray(np.broadcast_to(st.tags, (k,) + st.tags.shape))
        age0 = jnp.asarray(np.broadcast_to(st.age, (k,) + st.age.shape))
        hits, _, _ = _fused_select_vmapped(sets, ways)(
            jnp.asarray(padded), tags0, age0
        )
        hits = np.asarray(hits)
        for j, i in enumerate(idxs):
            _, order, col, row = grouped[i]
            out = np.zeros(len(streams[i]), dtype=bool)
            out[order] = hits[j][col, row]
            outs[i] = out
    return outs


def cache_pass(
    blocks: np.ndarray,
    sets: int,
    ways: int,
    state: Optional[CacheState] = None,
    return_state: bool = False,
):
    """Run an access stream through one cache level; returns the hit mask.

    Dispatches to the active engine (see module docstring); every engine
    honors the same contract and produces bit-identical masks.  With
    ``state=`` the pass resumes from a carried :class:`CacheState` (as
    returned by a prior ``return_state=True`` call) and is bit-identical
    to one uninterrupted pass over the concatenated stream.
    """
    if len(blocks) == 0:
        hits = np.zeros(0, dtype=bool)
        if not return_state:
            return hits
        st = state if state is not None else init_state(sets, ways)
        return hits, CacheState(st.tags.copy(), st.age.copy())
    assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
    engine = current_engine()
    if engine == "reference":
        return scan_cache.cache_pass(blocks, sets, ways, state, return_state)
    if engine == "pallas":
        from repro.kernels.cache_sim.ops import cache_pass_pallas

        return cache_pass_pallas(blocks, sets, ways, state=state,
                                 return_state=return_state)
    # "fused" only changes multi-level simulation (repro.memsim.hierarchy
    # routes whole hierarchies through repro.memsim.fused); a single-level
    # pass has nothing to fuse, so it runs on the set-parallel machine.
    return cache_pass_set_parallel(blocks, sets, ways, state, return_state)


__all__ = [
    "ENGINES",
    "ENGINE_ENV",
    "CacheState",
    "cache_pass",
    "cache_pass_batch",
    "cache_pass_fused_select",
    "cache_pass_set_parallel",
    "canonicalize_state",
    "current_engine",
    "default_engine",
    "group_by_set",
    "init_state",
    "set_engine",
    "use_engine",
]
