"""Reference cache pass: a serial one-access-per-step ``lax.scan``.

This is the *correctness oracle* of the simulator, not its hot path — the
default production engine is the set-parallel batched pass in
:mod:`repro.memsim.engine` (4-8x faster on CPU), whose hit masks are
required to be bit-identical to this one (property-tested, and gated in the
bench harness).  Select this path explicitly with
``REPRO_CACHE_ENGINE=reference`` or ``engine.use_engine("reference")``.

Each pass is compiled once per (sets, ways) geometry and reused across all
traces/prefetchers — the scan carry is the full tag/LRU state, each step is
one access. True-LRU replacement via a monotone age counter.

Performance note: every engine emits ONLY the per-access hit bit.  Emitting
values derived from the gathered set row (way metadata etc.) de-optimizes
XLA's CPU while-loop by ~40x on this serial path and bloats the batched
engine's carry, so prefetch-classification state (pf bits, fill times) is
NOT tracked here — it is reconstructed exactly from the hit mask by a
segmented chain analysis in :func:`classify_prefetch_events` below (a hit
implies continuous residency since the previous same-block event, so
per-line state is a function of the block's event chain alone).
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


@lru_cache(maxsize=32)
def _plain_pass(sets: int, ways: int):
    mask = sets - 1

    def step(carry, b):
        tags, age, t = carry
        s = b & mask
        row = tags[s]
        hitv = row == b
        hit = hitv.any()
        way = jnp.where(hit, jnp.argmax(hitv), jnp.argmin(age[s]))
        tags = tags.at[s, way].set(b)
        age = age.at[s, way].set(t)
        return (tags, age, t + 1), hit

    @jax.jit
    def run(blocks, tags0, age0):
        init = (tags0, age0, jnp.int32(1))
        (tags1, age1, _), hits = jax.lax.scan(step, init, blocks)
        return hits, tags1, age1

    return run


def cache_pass(
    blocks: np.ndarray,
    sets: int,
    ways: int,
    state=None,
    return_state: bool = False,
):
    """Reference hit mask for one cache level (serial per-access scan).

    Prefer :func:`repro.memsim.engine.cache_pass`, which dispatches to the
    set-parallel engine by default and to this function under the
    ``reference`` engine.  ``state``/``return_state`` thread the canonical
    :class:`repro.memsim.engine.CacheState` carry across chunked passes.
    """
    from repro.memsim import engine  # deferred: engine imports this module

    if len(blocks) == 0:
        hits = np.zeros(0, dtype=bool)
        if not return_state:
            return hits
        st = state if state is not None else engine.init_state(sets, ways)
        return hits, engine.CacheState(st.tags.copy(), st.age.copy())
    assert blocks.max(initial=0) < 2**31, "block ids must fit in int32"
    run = _plain_pass(sets, ways)
    st = state if state is not None else engine.init_state(sets, ways)
    hits, tags1, age1 = run(
        jnp.asarray(blocks, dtype=jnp.int32),
        jnp.asarray(st.tags),
        jnp.asarray(st.age),
    )
    hits = np.asarray(hits)
    if not return_state:
        return hits
    return hits, engine.canonicalize_state(np.asarray(tags1), np.asarray(age1))


def classify_prefetch_events(
    blocks: np.ndarray,
    is_pf: np.ndarray,
    pos: np.ndarray,
    hit: np.ndarray,
    fill_window: int,
):
    """Reconstruct per-event prefetch semantics from the hit mask.

    Within one block's event chain (events already in stream order):
      - every chain segment starts at a fill (miss);
      - the line's pf bit after event e is ``is_pf[e] & (miss[e] | pf_before)``
        which unrolls to "every event since the last fill was a prefetch";
      - the fill time is set by the fill event only (redundant prefetch hits
        do not refresh it), so lateness compares the *fill* event's position.

    Returns (useful, late, redundant, early_evicted, fill_origin) in the
    original event order. ``early_evicted`` marks prefetch fills whose line
    was evicted before the next same-block access (the next chain event is a
    miss). ``fill_origin[k]`` is the original index of the event that filled
    the line consumed by useful event ``k`` (-1 where not useful) — used to
    attribute useful prefetches to their issuer in composite setups.
    """
    n = len(blocks)
    if n == 0:
        z = np.zeros(0, dtype=bool)
        return z, z, z, z, np.full(0, -1, dtype=np.int64)
    # Chains contiguous, stream order inside: single-key sort on a packed
    # (block, stream-index) key is ~2x faster than lexsort at 10M+ events.
    key = (blocks.astype(np.int64) << np.int64(31)) | np.arange(n, dtype=np.int64)
    order = np.argsort(key)
    b = blocks[order]
    p = pos[order]
    f = is_pf[order]
    h = hit[order]

    idx = np.arange(n, dtype=np.int64)
    chain_start = np.ones(n, dtype=bool)
    chain_start[1:] = b[1:] != b[:-1]

    # Last fill (miss event) at or before each position. Chains start with a
    # miss (cold caches), so the accumulate never crosses chain boundaries.
    fill_idx = np.where(~h, idx, -1)
    last_fill = np.maximum.accumulate(fill_idx)

    # all(is_pf[last_fill .. k]) via prefix sums of ~is_pf.
    cnp = np.cumsum((~f).astype(np.int32))
    cnp_before = cnp - (~f)  # exclusive prefix
    all_pf_since_fill = (cnp - cnp_before[last_fill]) == 0  # inclusive of k

    # pf state *before* event k = all_pf over [last_fill .. k-1] and line
    # resident (h[k]); since h[k] implies last event before k is the chain
    # predecessor, this equals all_pf_since_fill evaluated at k-1 of chain.
    prev_all_pf = np.zeros(n, dtype=bool)
    prev_all_pf[1:] = all_pf_since_fill[:-1]
    prev_all_pf[chain_start] = False

    useful = h & ~f & prev_all_pf
    # A useful event is a hit, so its last_fill is the prefetch fill itself.
    late = useful & (p[np.maximum(last_fill, 0)] + fill_window > p)
    redundant = f & h

    # Early eviction: a prefetch *fill* whose next same-block event misses.
    next_is_miss = np.zeros(n, dtype=bool)
    next_is_miss[:-1] = ~h[1:] & ~chain_start[1:]
    early = (~h) & f & next_is_miss

    # Fill origin (original event index) for useful events.
    fill_origin_sorted = np.where(useful, order[np.maximum(last_fill, 0)], -1)

    out = np.zeros((4, n), dtype=bool)
    out[0][order] = useful
    out[1][order] = late
    out[2][order] = redundant
    out[3][order] = early
    fill_origin = np.full(n, -1, dtype=np.int64)
    fill_origin[order] = fill_origin_sorted
    return out[0], out[1], out[2], out[3], fill_origin
